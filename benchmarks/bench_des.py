"""DES microbenchmark: event loops AND sweep dispatch layouts, tracked per PR.

Two sections, both recorded to ``benchmarks/results/BENCH_des.json`` (or
``--out PATH``):

  * ``headline`` / ``scaling_with_n`` — ms/experiment for the simulator
    cores dispatched sequentially:

      - ``reference`` — the seed implementation
        (`simulate_packet_reference`: per-event O(N) masked metric writes,
        fixed 512-slot ring),
      - ``group_log`` — the production while-loop path (`simulate_packet`:
        O(1) log appends + vectorized post-pass, ring = min(M, N)).

  * ``engine_ab`` — the sweep-layout A/B on the same grid through
    `repro.core.sweep`: ``seq`` (cached per-experiment dispatch) vs
    ``chunked`` (sorted fixed-width lanes through the event-budget scan
    engine) vs ``fused`` (all lanes, one program, padded + sharded on
    multi-device backends) vs ``pallas`` (the fused layout on the Pallas
    event-step engine — interpret mode on CPU, recorded with a
    ``pallas_interpret`` flag and exempt from the ratio gate there).
    ``batched_vs_seq_ratio`` is the headline regression number: PR 1's
    vmapped-while fused engine sat at ~16x on a single CPU device; the
    scan engine must stay under ``REGRESSION_BAR`` (2.0), which
    `--smoke` (the CI gate) enforces via the exit code. The ``headline``
    block also carries ``event_step_model`` — the analytic bytes/flops
    per event and the predicted HBM-streaming vs state-resident ceilings
    from `benchmarks.roofline.event_step_roofline`.

  * ``chaos_ab`` — the fault-injection A/B: the same fused grid with
    chaos off (normalized to the exact pre-chaos program) vs a live
    fault sweep (failures + stragglers + requeues, R = N requeue rounds,
    the sized event budget). ``chaos_vs_zero_ratio`` is gated at
    ``REGRESSION_BAR`` in ``--smoke``: fault semantics may not make the
    batched engine more than 2x slower per experiment.

  * ``cohort_ab`` — the workload-axis A/B: a 3-workload study run the
    pre-cohort way (one `run_packet_grid` per workload, Python loop) vs as
    ONE stacked cohort through `run_cohort_grid` (chunked [W, width]
    dispatches and the all-lanes fused program). End-to-end study wall
    clock through the public entry points, so packing/unstacking overhead
    counts on both sides. ``cohort_vs_per_workload_ratio`` (best cohort
    layout / per-workload) is gated at the same ``REGRESSION_BAR`` in
    `--smoke`.

Usage:
    python -m benchmarks.bench_des            # full (5000-job headline)
    python -m benchmarks.bench_des --smoke    # <= ~60 s CI-budget variant
    python -m benchmarks.bench_des --smoke --out smoke.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.core import (pack_workload, resolve_ring, simulate_packet,
                        simulate_packet_reference)
from repro.workload.lublin import WorkloadParams, generate_workload

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
BENCH_PATH = os.path.join(RESULTS_DIR, "BENCH_des.json")


REPEATS = 5         # best-of-R to shed scheduler/allocator noise
REGRESSION_BAR = 2.0  # best batched layout must stay within 2x of seq


def _bench_sequential(sim_fn, pw, ks, s, m_nodes, **kw):
    """Best-of ms/experiment for jitted per-k sequential dispatch."""
    f = jax.jit(lambda k: sim_fn(pw, k, s, m_nodes, **kw).makespan)
    f(float(ks[0])).block_until_ready()                   # compile
    best = np.inf
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for k in ks:
            f(float(k)).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / len(ks) * 1e3


def _bench_mode(wl, ks, s_props, mode):
    """Best-of ms/experiment through the sweep layouts in the given mode.

    Inputs are packed once outside the timer (like _bench_sequential), so
    the recorded number is the engine itself, not per-call host repacking.
    Chunked includes its host-side sort/unsort — that is part of the
    layout's real cost. ``mode="pallas"`` runs the fused lane layout with
    the Pallas event-step engine (`step_impl="pallas"`) — on CPU that is
    the interpret-mode fallback, a correctness arm rather than a perf arm
    (the ratio gate skips it; see main()).
    """
    import jax.numpy as jnp
    from repro.core.sweep import (CHUNK_LANES, _packet_one, _run_lane_chunks,
                                  _run_lanes_fused)

    pw = pack_workload(wl)
    m = int(wl.params.nodes)
    ring = resolve_ring(m, pw.n_jobs)
    s_vals = jnp.asarray([wl.init_time_for_proportion(p) for p in s_props],
                         jnp.float32)
    ks_arr = jnp.asarray(ks, jnp.float32)
    k_lanes = jnp.repeat(ks_arr, len(s_props))
    s_lanes = jnp.tile(s_vals, len(ks))

    if mode == "pallas":
        run = lambda: _run_lanes_fused(pw, k_lanes, s_lanes, m, ring,
                                       None, "pallas")
    elif mode == "fused":
        run = lambda: _run_lanes_fused(pw, k_lanes, s_lanes, m, ring)
    elif mode == "chunked":
        run = lambda: _run_lane_chunks(pw, k_lanes, s_lanes, m, ring,
                                       CHUNK_LANES)
    else:
        def run():
            for k in ks_arr:
                for s in s_vals:
                    jax.block_until_ready(_packet_one(pw, k, s, m, ring))
            return None

    out = run()                                           # compile
    if out is not None:
        assert np.asarray(out.ok).all(), mode
    best = np.inf
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return best / (len(ks) * len(s_props)) * 1e3


def bench_engine_ab(n_jobs: int, ks, s_props, nodes=100) -> dict:
    """The sweep-layout A/B: seq vs chunked vs fused vs the pallas engine.

    The ``pallas`` arm runs the fused lane layout with the Pallas
    event-step kernel (`step_impl="pallas"`). On CPU the kernel is
    discharged through interpret mode (``pallas_interpret: true``) — a
    correctness/parity arm whose ms/experiment is recorded for tracking
    but exempt from the regression ratio gate; on an accelerator backend
    it compiles for real and the gate applies.
    """
    wl = generate_workload(WorkloadParams(
        n_jobs=n_jobs, nodes=nodes, load=0.9, homogeneous=True, seed=1))
    seq_ms = _bench_mode(wl, ks, s_props, "seq")
    chunked_ms = _bench_mode(wl, ks, s_props, "chunked")
    fused_ms = _bench_mode(wl, ks, s_props, "fused")
    pallas_ms = _bench_mode(wl, ks, s_props, "pallas")
    best_batched = min(chunked_ms, fused_ms)
    return {
        "n_jobs": n_jobs, "nodes": nodes, "n_k": len(ks),
        "n_s": len(s_props), "n_lanes": len(ks) * len(s_props),
        "n_devices": jax.device_count(),
        "seq_ms_per_experiment": seq_ms,
        "chunked_ms_per_experiment": chunked_ms,
        "fused_ms_per_experiment": fused_ms,
        "pallas_ms_per_experiment": pallas_ms,
        "pallas_interpret": jax.default_backend() == "cpu",
        "pallas_vs_fused_ratio": pallas_ms / fused_ms,
        "best_batched_mode": ("chunked" if chunked_ms <= fused_ms
                              else "fused"),
        "batched_vs_seq_ratio": best_batched / seq_ms,
        "regression_bar": REGRESSION_BAR,
    }


def bench_chaos_ab(n_jobs: int, ks, s_props, nodes=100) -> dict:
    """The fault-injection A/B: zero-chaos fused grid vs a live fault sweep.

    Both arms run `run_packet_grid(mode="fused")` end to end — the zero
    arm is the exact pre-chaos program (inert configs normalize away),
    the chaos arm carries the per-lane fault stream, the group-log
    requeue rounds with per-member credit (the searchsorted remnant
    walk; see des.py "requeue"), and the enlarged event budget. Arms are interleaved
    within each repeat round like the cohort A/B: the ratio is the
    quantity under test and runner throughput drifts over these
    seconds-scale studies.
    """
    from repro.core import ChaosConfig, run_packet_grid

    wl = generate_workload(WorkloadParams(
        n_jobs=n_jobs, nodes=nodes, load=0.9, homogeneous=True, seed=1))
    # N/4 requeue rounds bounds the log/budget shapes to the volume this
    # fault intensity actually produces (~N/5 requeues per lane, with
    # headroom), instead of the worst-case default R = N
    chaos = ChaosConfig(mtbf_chip_hours=100.0, ckpt_period=300.0,
                        straggler_prob=0.1, straggler_factor=4.0,
                        straggler_deadline=2.0, seed=7,
                        max_requeues=max(n_jobs // 4, 8))
    n_exp = len(ks) * len(s_props)

    def zero():
        return jax.block_until_ready(
            run_packet_grid(wl, ks, s_props, mode="fused"))

    def with_chaos():
        return jax.block_until_ready(run_packet_grid(
            wl, ks, s_props, mode="fused", chaos=chaos,
            on_budget_exhausted="raise"))

    res = with_chaos()                                # compile + sanity
    assert np.asarray(res.ok).all()
    n_failures = int(np.sum(np.asarray(res.failures)))
    n_kills = int(np.sum(np.asarray(res.straggler_kills)))
    assert n_failures + n_kills > 0, "chaos arm injected nothing"
    # member-credit sanity: the walk must actually requeue members at
    # this fault intensity, and never more than one member set per round
    n_requeues = int(np.sum(np.asarray(res.requeues)))
    n_requeued_jobs = int(np.sum(np.asarray(res.requeued_jobs)))
    assert 0 < n_requeued_jobs <= n_requeues * n_jobs
    zero()
    best = {"zero": np.inf, "chaos": np.inf}
    for _ in range(REPEATS):
        for name, run in (("zero", zero), ("chaos", with_chaos)):
            t0 = time.perf_counter()
            run()
            best[name] = min(best[name], time.perf_counter() - t0)
    return {
        "n_jobs": n_jobs, "nodes": nodes, "n_k": len(ks),
        "n_s": len(s_props), "experiments": n_exp,
        "n_devices": jax.device_count(),
        "failures": n_failures, "straggler_kills": n_kills,
        "requeues": n_requeues,
        "requeued_jobs": n_requeued_jobs,
        "zero_ms_per_experiment": best["zero"] / n_exp * 1e3,
        "chaos_ms_per_experiment": best["chaos"] / n_exp * 1e3,
        "chaos_vs_zero_ratio": best["chaos"] / best["zero"],
        "regression_bar": REGRESSION_BAR,
    }


def bench_cohort_ab(n_jobs: int, ks, s_props, nodes=100) -> dict:
    """The workload-axis A/B: sequential-per-workload vs cohort-batched.

    A 3-workload homogeneous study (loads 0.85/0.90/0.95 — one cohort, the
    same shape the paper's homogeneous half forms) timed end-to-end through
    the public drivers: the pre-cohort layout loops `run_packet_grid` over
    the workloads (each resolving its own single-workload mode, like the
    old paper_sweep driver), the cohort layouts run `run_cohort_grid` on
    the stacked batch. Warmup fills the shared jit caches, so best-of-R
    measures compute + dispatch, not compilation.
    """
    from repro.core import group_workloads, run_cohort_grid, run_packet_grid

    flows = {f"homog{load:.2f}": generate_workload(WorkloadParams(
        n_jobs=n_jobs, nodes=nodes, load=load, homogeneous=True, seed=i + 1))
        for i, load in enumerate((0.85, 0.90, 0.95))}
    cohorts = group_workloads(flows, np.float32)
    assert len(cohorts) == 1, [c.key for c in cohorts]
    cohort = cohorts[0]
    n_exp = len(flows) * len(ks) * len(s_props)

    def per_workload():
        return [jax.block_until_ready(run_packet_grid(wl, ks, s_props))
                for wl in flows.values()]

    def cohort_mode(mode):
        return jax.block_until_ready(
            run_cohort_grid(cohort, ks, s_props, mode=mode))

    # interleave the arms within each repeat round: the ratio is the
    # quantity under test, and shared-runner throughput drifts on a
    # minutes scale, so measuring each arm's best-of back to back (as the
    # engine A/B can afford with its ms-scale passes) would let drift
    # masquerade as a layout difference across these seconds-scale studies
    arms = {"per_workload": per_workload,
            "chunked": lambda: cohort_mode("chunked"),
            "fused": lambda: cohort_mode("fused")}
    best = {}
    for name, run in arms.items():
        run()                                         # compile/warm caches
        best[name] = np.inf
    for _ in range(REPEATS):
        for name, run in arms.items():
            t0 = time.perf_counter()
            run()
            best[name] = min(best[name], time.perf_counter() - t0)
    base_s = best.pop("per_workload")
    times = best
    best_mode = min(times, key=times.get)
    return {
        "n_jobs": n_jobs, "nodes": nodes, "n_workloads": len(flows),
        "n_k": len(ks), "n_s": len(s_props), "experiments": n_exp,
        "n_devices": jax.device_count(),
        "per_workload_study_s": base_s,
        "cohort_chunked_study_s": times["chunked"],
        "cohort_fused_study_s": times["fused"],
        "per_workload_ms_per_experiment": base_s / n_exp * 1e3,
        "cohort_ms_per_experiment": times[best_mode] / n_exp * 1e3,
        "best_cohort_mode": best_mode,
        "cohort_vs_per_workload_ratio": times[best_mode] / base_s,
        "regression_bar": REGRESSION_BAR,
    }


def bench_grid(n_jobs: int, ks, s_props, nodes=100) -> dict:
    wl = generate_workload(WorkloadParams(
        n_jobs=n_jobs, nodes=nodes, load=0.9, homogeneous=True, seed=1))
    pw = pack_workload(wl)
    s = wl.init_time_for_proportion(s_props[0])
    m = wl.params.nodes

    ref_ms = _bench_sequential(simulate_packet_reference, pw, ks, s, m)
    glog_ms = _bench_sequential(simulate_packet, pw, ks, s, m)
    return {
        "n_jobs": n_jobs, "nodes": nodes, "n_k": len(ks),
        "n_s": len(s_props), "ring": resolve_ring(m, n_jobs),
        "n_types": int(pw.n_types),
        "n_devices": jax.device_count(),
        "reference_ms_per_experiment": ref_ms,
        "group_log_ms_per_experiment": glog_ms,
        "speedup_group_log_vs_reference": ref_ms / glog_ms,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes, finishes in ~a minute (the CI "
                         "regression gate)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="output JSON path (default: results/BENCH_des.json)")
    args = ap.parse_args(argv)

    from repro.core import PAPER_INIT_PROPS, PAPER_SCALE_RATIOS
    if args.smoke:
        headline_n, scaling_ns = 1200, [300, 600, 1200]
        ks = [0.5, 2.0, 8.0, 50.0]
        s_props = [0.05, 0.5]
        # cohort A/B wants a paper-SHAPED study: enough lanes that the
        # per-workload baseline resolves to its batched layout (as the
        # real driver does) and seconds-long passes that integrate over
        # shared-runner noise, at a job count that fits the CI budget
        cohort_n, cohort_ks, cohort_sp = (
            600, list(PAPER_SCALE_RATIOS), list(PAPER_INIT_PROPS))
    else:
        headline_n, scaling_ns = 5000, [625, 1250, 2500, 5000]
        ks = [0.5, 1.0, 2.0, 4.0, 8.0, 20.0, 50.0, 200.0]
        s_props = [0.05, 0.2, 0.5]
        cohort_n, cohort_ks, cohort_sp = (
            1200, list(PAPER_SCALE_RATIOS), list(PAPER_INIT_PROPS))

    t_start = time.perf_counter()
    print(f"[bench_des] headline grid: {headline_n} jobs, "
          f"{len(ks)} x {len(s_props)} experiments")
    headline = bench_grid(headline_n, ks, s_props)
    print(f"[bench_des]   reference  {headline['reference_ms_per_experiment']:8.1f} ms/exp")
    print(f"[bench_des]   group_log  {headline['group_log_ms_per_experiment']:8.1f} ms/exp "
          f"({headline['speedup_group_log_vs_reference']:.2f}x)")

    # analytic event-step roofline (lazy: roofline.py pulls the model
    # stack at import): the predicted HBM-streaming ceiling for this
    # headline shape on the reference accelerator, and the VMEM-resident
    # ceiling the Pallas event-step kernel targets
    from benchmarks.roofline import event_step_roofline
    headline["event_step_model"] = event_step_roofline(
        headline_n, headline["n_types"], headline["ring"],
        n_lanes=len(ks) * len(s_props))
    esm = headline["event_step_model"]
    print(f"[bench_des]   event-step model ({esm['bound']}-bound): "
          f"{esm['bytes_per_event']} B/event, "
          f"{esm['flops_per_event']} flop/event -> predicted "
          f"{esm['predicted_ms_per_experiment']:.2f} ms/exp HBM-resident, "
          f"{esm['state_resident_ms_per_experiment']:.3f} ms/exp "
          f"state-resident (device ceiling, not this host)")

    print(f"[bench_des] engine A/B: seq vs chunked vs fused "
          f"({len(ks) * len(s_props)} lanes, "
          f"{jax.device_count()} device(s))")
    engine_ab = bench_engine_ab(headline_n, ks, s_props)
    for mode in ("seq", "chunked", "fused", "pallas"):
        print(f"[bench_des]   {mode:8s} "
              f"{engine_ab[f'{mode}_ms_per_experiment']:8.1f} ms/exp")
    print(f"[bench_des]   best batched ({engine_ab['best_batched_mode']}) = "
          f"{engine_ab['batched_vs_seq_ratio']:.2f}x seq "
          f"(bar: {REGRESSION_BAR}x)")
    if engine_ab["pallas_interpret"]:
        print(f"[bench_des]   pallas arm ran interpret-mode (CPU backend): "
              f"parity arm, exempt from the ratio gate")

    print(f"[bench_des] chaos A/B: fused grid, zero-chaos vs fault sweep "
          f"({len(ks) * len(s_props)} experiments)")
    chaos_ab = bench_chaos_ab(headline_n, ks, s_props)
    print(f"[bench_des]   zero-chaos {chaos_ab['zero_ms_per_experiment']:8.1f} ms/exp")
    print(f"[bench_des]   chaos      {chaos_ab['chaos_ms_per_experiment']:8.1f} ms/exp "
          f"({chaos_ab['failures']} failures, "
          f"{chaos_ab['straggler_kills']} kills, "
          f"{chaos_ab['requeues']} requeues, "
          f"{chaos_ab['requeued_jobs']} members requeued)")
    print(f"[bench_des]   chaos = {chaos_ab['chaos_vs_zero_ratio']:.2f}x "
          f"zero-chaos (bar: {REGRESSION_BAR}x)")

    print(f"[bench_des] cohort A/B: 3-workload paper-shaped study, "
          f"per-workload loop vs stacked cohort "
          f"({3 * len(cohort_ks) * len(cohort_sp)} experiments, "
          f"{cohort_n} jobs)")
    cohort_ab = bench_cohort_ab(cohort_n, cohort_ks, cohort_sp)
    print(f"[bench_des]   per-workload  {cohort_ab['per_workload_study_s'] * 1e3:8.0f} ms study "
          f"({cohort_ab['per_workload_ms_per_experiment']:.1f} ms/exp)")
    for mode in ("chunked", "fused"):
        print(f"[bench_des]   cohort {mode:8s} "
              f"{cohort_ab[f'cohort_{mode}_study_s'] * 1e3:5.0f} ms study")
    print(f"[bench_des]   best cohort ({cohort_ab['best_cohort_mode']}) = "
          f"{cohort_ab['cohort_vs_per_workload_ratio']:.2f}x per-workload "
          f"(bar: {REGRESSION_BAR}x)")

    scaling = []
    for n in scaling_ns:
        row = bench_grid(n, ks[:4], s_props[:2])
        scaling.append(row)
        print(f"[bench_des] N={n:5d}: reference "
              f"{row['reference_ms_per_experiment']:.1f} ms, group_log "
              f"{row['group_log_ms_per_experiment']:.1f} ms "
              f"({row['speedup_group_log_vs_reference']:.2f}x)")

    out = {
        "bench": "des_group_log_vs_reference",
        "smoke": bool(args.smoke),
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "platform": platform.platform(),
        "unix_time": time.time(),
        "total_seconds": None,          # filled below
        "headline": headline,
        "engine_ab": engine_ab,
        "chaos_ab": chaos_ab,
        "cohort_ab": cohort_ab,
        "scaling_with_n": scaling,
    }
    out["total_seconds"] = time.perf_counter() - t_start
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[bench_des] wrote {args.out} "
          f"({out['total_seconds']:.1f}s total)")

    # the pallas arm joins the ratio gate only when it actually compiled
    # (accelerator backend); an interpret-mode CPU run is a parity arm
    # whose wall time says nothing about the kernel
    pallas_ok = (engine_ab["pallas_interpret"] or
                 engine_ab["pallas_vs_fused_ratio"] <= REGRESSION_BAR)
    ok = (headline["speedup_group_log_vs_reference"] >= 2.0 and
          engine_ab["batched_vs_seq_ratio"] <= REGRESSION_BAR and
          pallas_ok and
          chaos_ab["chaos_vs_zero_ratio"] <= REGRESSION_BAR and
          cohort_ab["cohort_vs_per_workload_ratio"] <= REGRESSION_BAR)
    print(f"[bench_des] {'PASS' if ok else 'FAIL'}: group_log >= 2x "
          f"reference AND best batched layout <= {REGRESSION_BAR}x seq "
          f"AND pallas <= {REGRESSION_BAR}x fused (compiled backends only) "
          f"AND chaos <= {REGRESSION_BAR}x zero-chaos "
          f"AND cohort study <= {REGRESSION_BAR}x per-workload")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
