"""DES microbenchmark: event loops AND sweep dispatch layouts, tracked per PR.

Two sections, both recorded to ``benchmarks/results/BENCH_des.json`` (or
``--out PATH``):

  * ``headline`` / ``scaling_with_n`` — ms/experiment for the simulator
    cores dispatched sequentially:

      - ``reference`` — the seed implementation
        (`simulate_packet_reference`: per-event O(N) masked metric writes,
        fixed 512-slot ring),
      - ``group_log`` — the production while-loop path (`simulate_packet`:
        O(1) log appends + vectorized post-pass, ring = min(M, N)).

  * ``engine_ab`` — the sweep-layout A/B on the same grid through
    `repro.core.sweep`: ``seq`` (cached per-experiment dispatch) vs
    ``chunked`` (sorted fixed-width lanes through the event-budget scan
    engine) vs ``fused`` (all lanes, one program, padded + sharded on
    multi-device backends). ``batched_vs_seq_ratio`` is the headline
    regression number: PR 1's vmapped-while fused engine sat at ~16x on a
    single CPU device; the scan engine must stay under
    ``REGRESSION_BAR`` (2.0), which `--smoke` (the CI gate) enforces via
    the exit code.

Usage:
    python -m benchmarks.bench_des            # full (5000-job headline)
    python -m benchmarks.bench_des --smoke    # <= ~60 s CI-budget variant
    python -m benchmarks.bench_des --smoke --out smoke.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.core import (pack_workload, resolve_ring, simulate_packet,
                        simulate_packet_reference)
from repro.workload.lublin import WorkloadParams, generate_workload

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
BENCH_PATH = os.path.join(RESULTS_DIR, "BENCH_des.json")


REPEATS = 5         # best-of-R to shed scheduler/allocator noise
REGRESSION_BAR = 2.0  # best batched layout must stay within 2x of seq


def _bench_sequential(sim_fn, pw, ks, s, m_nodes, **kw):
    """Best-of ms/experiment for jitted per-k sequential dispatch."""
    f = jax.jit(lambda k: sim_fn(pw, k, s, m_nodes, **kw).makespan)
    f(float(ks[0])).block_until_ready()                   # compile
    best = np.inf
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for k in ks:
            f(float(k)).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / len(ks) * 1e3


def _bench_mode(wl, ks, s_props, mode):
    """Best-of ms/experiment through the sweep layouts in the given mode.

    Inputs are packed once outside the timer (like _bench_sequential), so
    the recorded number is the engine itself, not per-call host repacking.
    Chunked includes its host-side sort/unsort — that is part of the
    layout's real cost.
    """
    import jax.numpy as jnp
    from repro.core.sweep import (CHUNK_LANES, _packet_one, _run_lane_chunks,
                                  _run_lanes_fused)

    pw = pack_workload(wl)
    m = int(wl.params.nodes)
    ring = resolve_ring(m, pw.n_jobs)
    s_vals = jnp.asarray([wl.init_time_for_proportion(p) for p in s_props],
                         jnp.float32)
    ks_arr = jnp.asarray(ks, jnp.float32)
    k_lanes = jnp.repeat(ks_arr, len(s_props))
    s_lanes = jnp.tile(s_vals, len(ks))

    if mode == "fused":
        run = lambda: _run_lanes_fused(pw, k_lanes, s_lanes, m, ring)
    elif mode == "chunked":
        run = lambda: _run_lane_chunks(pw, k_lanes, s_lanes, m, ring,
                                       CHUNK_LANES)
    else:
        def run():
            for k in ks_arr:
                for s in s_vals:
                    jax.block_until_ready(_packet_one(pw, k, s, m, ring))
            return None

    out = run()                                           # compile
    if out is not None:
        assert np.asarray(out.ok).all(), mode
    best = np.inf
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return best / (len(ks) * len(s_props)) * 1e3


def bench_engine_ab(n_jobs: int, ks, s_props, nodes=100) -> dict:
    """The sweep-layout A/B: seq vs chunked vs fused on one grid."""
    wl = generate_workload(WorkloadParams(
        n_jobs=n_jobs, nodes=nodes, load=0.9, homogeneous=True, seed=1))
    seq_ms = _bench_mode(wl, ks, s_props, "seq")
    chunked_ms = _bench_mode(wl, ks, s_props, "chunked")
    fused_ms = _bench_mode(wl, ks, s_props, "fused")
    best_batched = min(chunked_ms, fused_ms)
    return {
        "n_jobs": n_jobs, "nodes": nodes, "n_k": len(ks),
        "n_s": len(s_props), "n_lanes": len(ks) * len(s_props),
        "n_devices": jax.device_count(),
        "seq_ms_per_experiment": seq_ms,
        "chunked_ms_per_experiment": chunked_ms,
        "fused_ms_per_experiment": fused_ms,
        "best_batched_mode": ("chunked" if chunked_ms <= fused_ms
                              else "fused"),
        "batched_vs_seq_ratio": best_batched / seq_ms,
        "regression_bar": REGRESSION_BAR,
    }


def bench_grid(n_jobs: int, ks, s_props, nodes=100) -> dict:
    wl = generate_workload(WorkloadParams(
        n_jobs=n_jobs, nodes=nodes, load=0.9, homogeneous=True, seed=1))
    pw = pack_workload(wl)
    s = wl.init_time_for_proportion(s_props[0])
    m = wl.params.nodes

    ref_ms = _bench_sequential(simulate_packet_reference, pw, ks, s, m)
    glog_ms = _bench_sequential(simulate_packet, pw, ks, s, m)
    return {
        "n_jobs": n_jobs, "nodes": nodes, "n_k": len(ks),
        "n_s": len(s_props), "ring": resolve_ring(m, n_jobs),
        "n_devices": jax.device_count(),
        "reference_ms_per_experiment": ref_ms,
        "group_log_ms_per_experiment": glog_ms,
        "speedup_group_log_vs_reference": ref_ms / glog_ms,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes, finishes in ~a minute (the CI "
                         "regression gate)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="output JSON path (default: results/BENCH_des.json)")
    args = ap.parse_args(argv)

    if args.smoke:
        headline_n, scaling_ns = 1200, [300, 600, 1200]
        ks = [0.5, 2.0, 8.0, 50.0]
        s_props = [0.05, 0.5]
    else:
        headline_n, scaling_ns = 5000, [625, 1250, 2500, 5000]
        ks = [0.5, 1.0, 2.0, 4.0, 8.0, 20.0, 50.0, 200.0]
        s_props = [0.05, 0.2, 0.5]

    t_start = time.perf_counter()
    print(f"[bench_des] headline grid: {headline_n} jobs, "
          f"{len(ks)} x {len(s_props)} experiments")
    headline = bench_grid(headline_n, ks, s_props)
    print(f"[bench_des]   reference  {headline['reference_ms_per_experiment']:8.1f} ms/exp")
    print(f"[bench_des]   group_log  {headline['group_log_ms_per_experiment']:8.1f} ms/exp "
          f"({headline['speedup_group_log_vs_reference']:.2f}x)")

    print(f"[bench_des] engine A/B: seq vs chunked vs fused "
          f"({len(ks) * len(s_props)} lanes, "
          f"{jax.device_count()} device(s))")
    engine_ab = bench_engine_ab(headline_n, ks, s_props)
    for mode in ("seq", "chunked", "fused"):
        print(f"[bench_des]   {mode:8s} "
              f"{engine_ab[f'{mode}_ms_per_experiment']:8.1f} ms/exp")
    print(f"[bench_des]   best batched ({engine_ab['best_batched_mode']}) = "
          f"{engine_ab['batched_vs_seq_ratio']:.2f}x seq "
          f"(bar: {REGRESSION_BAR}x)")

    scaling = []
    for n in scaling_ns:
        row = bench_grid(n, ks[:4], s_props[:2])
        scaling.append(row)
        print(f"[bench_des] N={n:5d}: reference "
              f"{row['reference_ms_per_experiment']:.1f} ms, group_log "
              f"{row['group_log_ms_per_experiment']:.1f} ms "
              f"({row['speedup_group_log_vs_reference']:.2f}x)")

    out = {
        "bench": "des_group_log_vs_reference",
        "smoke": bool(args.smoke),
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "platform": platform.platform(),
        "unix_time": time.time(),
        "total_seconds": None,          # filled below
        "headline": headline,
        "engine_ab": engine_ab,
        "scaling_with_n": scaling,
    }
    out["total_seconds"] = time.perf_counter() - t_start
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[bench_des] wrote {args.out} "
          f"({out['total_seconds']:.1f}s total)")

    ok = (headline["speedup_group_log_vs_reference"] >= 2.0 and
          engine_ab["batched_vs_seq_ratio"] <= REGRESSION_BAR)
    print(f"[bench_des] {'PASS' if ok else 'FAIL'}: group_log >= 2x "
          f"reference AND best batched layout <= {REGRESSION_BAR}x seq")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
