"""DES microbenchmark: group-log event loop vs the seed O(N)-writes path.

Measures ms/experiment for

  * ``reference`` — the seed implementation (`simulate_packet_reference`:
    per-event O(N) masked metric writes, fixed 512-slot ring),
  * ``group_log`` — the production path (`simulate_packet`: O(1) log
    appends + vectorized post-pass, ring = min(M, N)),
  * ``fused``     — the group-log path amortized through the fused (k x S)
    lane engine of `repro.core.sweep`,

on a paper-scale 5000-job homogeneous workload grid, plus a
scaling-with-N series, and records everything to
``benchmarks/results/BENCH_des.json`` so the perf trajectory is tracked
across PRs.

Usage:
    python -m benchmarks.bench_des            # full (5000-job headline)
    python -m benchmarks.bench_des --smoke    # <= 30 s CI-budget variant
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.core import (pack_workload, resolve_ring, simulate_packet,
                        simulate_packet_reference)
from repro.workload.lublin import WorkloadParams, generate_workload

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
BENCH_PATH = os.path.join(RESULTS_DIR, "BENCH_des.json")


REPEATS = 5     # best-of-R to shed scheduler/allocator noise


def _bench_sequential(sim_fn, pw, ks, s, m_nodes, **kw):
    """Best-of ms/experiment for jitted per-k sequential dispatch."""
    f = jax.jit(lambda k: sim_fn(pw, k, s, m_nodes, **kw).makespan)
    f(float(ks[0])).block_until_ready()                   # compile
    best = np.inf
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for k in ks:
            f(float(k)).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / len(ks) * 1e3


def _bench_grid(wl, ks, s_props, mode):
    """Best-of ms/experiment through the sweep engines in the given mode.

    Inputs are packed once outside the timer (like _bench_sequential), so
    the recorded number is the engine itself, not per-call host repacking.
    """
    import jax.numpy as jnp
    from repro.core.sweep import _packet_lanes, _packet_one, lane_sharding

    pw = pack_workload(wl)
    m = int(wl.params.nodes)
    ring = resolve_ring(m, pw.n_jobs)
    s_vals = jnp.asarray([wl.init_time_for_proportion(p) for p in s_props],
                         jnp.float32)
    ks_arr = jnp.asarray(ks, jnp.float32)
    if mode == "auto":
        mode = ("fused" if lane_sharding(len(ks) * len(s_props)) is not None
                else "seq")

    if mode == "fused":
        k_lanes = jnp.repeat(ks_arr, len(s_props))
        s_lanes = jnp.tile(s_vals, len(ks))
        run = lambda: jax.block_until_ready(
            _packet_lanes(pw, k_lanes, s_lanes, m, ring))
    else:
        def run():
            for k in ks_arr:
                for s in s_vals:
                    jax.block_until_ready(_packet_one(pw, k, s, m, ring))

    out = run()                                           # compile
    if mode == "fused":
        assert np.asarray(out.ok).all()
    best = np.inf
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return best / (len(ks) * len(s_props)) * 1e3


def bench_grid(n_jobs: int, ks, s_props, nodes=100) -> dict:
    wl = generate_workload(WorkloadParams(
        n_jobs=n_jobs, nodes=nodes, load=0.9, homogeneous=True, seed=1))
    pw = pack_workload(wl)
    s = wl.init_time_for_proportion(s_props[0])
    m = wl.params.nodes

    ref_ms = _bench_sequential(simulate_packet_reference, pw, ks, s, m)
    glog_ms = _bench_sequential(simulate_packet, pw, ks, s, m)
    grid_ms = _bench_grid(wl, ks, s_props, "auto")
    fused_ms = _bench_grid(wl, ks, s_props, "fused")
    return {
        "n_jobs": n_jobs, "nodes": nodes, "n_k": len(ks),
        "n_s": len(s_props), "ring": resolve_ring(m, n_jobs),
        "n_devices": jax.device_count(),
        "reference_ms_per_experiment": ref_ms,
        "group_log_ms_per_experiment": glog_ms,
        "grid_auto_ms_per_experiment": grid_ms,
        "fused_ms_per_experiment": fused_ms,
        "speedup_group_log_vs_reference": ref_ms / glog_ms,
        "speedup_grid_auto_vs_reference": ref_ms / grid_ms,
        "speedup_fused_vs_reference": ref_ms / fused_ms,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes, finishes in <= 30 s")
    args = ap.parse_args(argv)

    if args.smoke:
        headline_n, scaling_ns = 1200, [300, 600, 1200]
        ks = [0.5, 2.0, 8.0, 50.0]
        s_props = [0.05, 0.5]
    else:
        headline_n, scaling_ns = 5000, [625, 1250, 2500, 5000]
        ks = [0.5, 1.0, 2.0, 4.0, 8.0, 20.0, 50.0, 200.0]
        s_props = [0.05, 0.2, 0.5]

    t_start = time.perf_counter()
    print(f"[bench_des] headline grid: {headline_n} jobs, "
          f"{len(ks)} x {len(s_props)} experiments")
    headline = bench_grid(headline_n, ks, s_props)
    print(f"[bench_des]   reference  {headline['reference_ms_per_experiment']:8.1f} ms/exp")
    print(f"[bench_des]   group_log  {headline['group_log_ms_per_experiment']:8.1f} ms/exp "
          f"({headline['speedup_group_log_vs_reference']:.2f}x)")
    print(f"[bench_des]   grid(auto) {headline['grid_auto_ms_per_experiment']:8.1f} ms/exp "
          f"({headline['speedup_grid_auto_vs_reference']:.2f}x)")
    print(f"[bench_des]   fused      {headline['fused_ms_per_experiment']:8.1f} ms/exp "
          f"({headline['speedup_fused_vs_reference']:.2f}x, "
          f"{headline['n_devices']} device(s))")

    scaling = []
    for n in scaling_ns:
        row = bench_grid(n, ks[:4], s_props[:2])
        scaling.append(row)
        print(f"[bench_des] N={n:5d}: reference "
              f"{row['reference_ms_per_experiment']:.1f} ms, group_log "
              f"{row['group_log_ms_per_experiment']:.1f} ms "
              f"({row['speedup_group_log_vs_reference']:.2f}x)")

    out = {
        "bench": "des_group_log_vs_reference",
        "smoke": bool(args.smoke),
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "platform": platform.platform(),
        "unix_time": time.time(),
        "total_seconds": None,          # filled below
        "headline": headline,
        "scaling_with_n": scaling,
    }
    out["total_seconds"] = time.perf_counter() - t_start
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[bench_des] wrote {BENCH_PATH} "
          f"({out['total_seconds']:.1f}s total)")

    target = 2.0
    ok = headline["speedup_group_log_vs_reference"] >= target or \
        headline["speedup_grid_auto_vs_reference"] >= target or \
        headline["speedup_fused_vs_reference"] >= target
    print(f"[bench_des] {'PASS' if ok else 'FAIL'}: >= {target}x lower "
          f"ms/experiment than the seed path")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
