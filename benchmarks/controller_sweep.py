"""Controller regret study: the streaming service vs. hindsight oracles.

Runs the closed-loop scale-ratio controller (`repro.service`) over the
canonical drift scenarios (`repro.workload.windows.drift_scenarios` —
zero-drift control plus intensity/homogeneity ramps and steps) and
records, per scenario and controller, regret against two hindsight
references computed from the same per-tick oracle curves:

  * the per-tick arg-best k (regret >= 0 by construction; the headline
    ``rel_regret_wait`` is total regret over total hindsight-best wait),
  * the offline `plateau_threshold` recommendation applied per window
    (``mean_wait_vs_plateau``, signed — negative = controller beat the
    paper's offline tuning rule).

The A/B at the heart of the study: plateau-aware hysteresis
(`HysteresisController`) vs. a naive every-tick arg-best commit
(`NaiveController`), both realizing their commitment one tick late. The
paper's plateau is the stability argument — under window noise the
arg-best hops between near-tied plateau members, so naive pays the
actuation delay over and over while hysteresis holds still.

``--smoke`` (the CI gate) shrinks the traces and gates the exit code on:

  * regret_wait and regret_useful >= 0 on every scenario (construction
    invariant — a violation means the bookkeeping broke);
  * zero-drift (``steady``) hysteresis rel_regret_wait <= STEADY_BAR;
  * hysteresis switches < naive switches, summed over scenarios;
  * hysteresis total regret <= naive total regret * REGRET_SLACK — the
    switch savings may not be bought with materially worse regret.

``--chaos`` adds the regret-under-faults block: the service re-runs a
scenario subset with a 3-cell `ChaosConfig` axis (harsh / moderate /
calm fault regimes, the harsh cell playing the true environment), the
risk-aware `FaultAwareController` A/B'd against the fault-blind
hysteresis it inherits from. Its gates:

  * fault_aware total lost_work <= fault-blind hysteresis lost_work
    (the λ·lost term must actually buy something);
  * fault_aware wait regret <= hysteresis regret * REGRET_SLACK — the
    lost-work savings may not be bought with materially worse wait;
  * a degrade-mode run under injected `TickFaults` (forced budget
    exhaustion, NaN fault telemetry, a dropped monitor window) completes
    every tick with per-tick health records.

Results land in ``benchmarks/results/BENCH_controller.json`` (or
``--out PATH``). Usage:

    PYTHONPATH=src python benchmarks/controller_sweep.py            # full
    PYTHONPATH=src python benchmarks/controller_sweep.py --smoke --chaos
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import time

import jax
import numpy as np

from repro.core.des import ChaosConfig
from repro.service import ServiceConfig, TickFaults, run_service
from repro.service.driver import default_controllers
from repro.workload.windows import drift_scenarios

RESULTS = os.path.join(os.path.dirname(__file__), "results")
OUT_PATH = os.path.join(RESULTS, "BENCH_controller.json")

#: zero-drift hysteresis rel regret bar: on a steady trace the held k
#: should track the (noisy) per-window optimum to within plateau noise.
STEADY_BAR = 0.10
#: hysteresis may not trade its switch savings for materially worse
#: regret than naive (total over all scenarios).
REGRET_SLACK = 1.10

# full study: paper-scale node count, ~4000 jobs per scenario, rolling
# 400-job windows advancing 200 jobs per tick -> 19 ticks per scenario.
FULL = dict(n_jobs=4000, nodes=100, n_segments=8,
            window_jobs=400, stride_jobs=200)
# smoke: same shape at CI scale -> 13 ticks per scenario, < ~2 min.
SMOKE = dict(n_jobs=1400, nodes=100, n_segments=7,
             window_jobs=200, stride_jobs=100)

#: --chaos re-runs this scenario subset with the fault axis (the chaos
#: oracle is C=3 times the lanes per tick; the full five-scenario sweep
#: adds nothing the A/B needs).
CHAOS_SCENARIOS = ("steady", "intensity_ramp")
#: ticks the degrade-proof run poisons: forced budget exhaustion, NaN
#: fault telemetry, and a dropped monitor window on distinct ticks.
CHAOS_FAULT_TICKS = dict(exhaust_budget=(1,), nan_telemetry=(2,),
                         drop_telemetry=(3,))
#: study λ: one machine-second of expected lost work priced at 0.1
#: wait-seconds. The per-window lost-work curve is noisy in k, so an
#: aggressive λ makes the cost arg-best chase that noise (extra switches,
#: each paying the one-tick actuation delay in BOTH wait and lost work);
#: a light λ breaks plateau ties toward the low-lost member and at study
#: scale strictly dominates fault-blind hysteresis on lost work at equal
#: or better wait regret.
CHAOS_RISK_LAMBDA = 0.1


def chaos_axis() -> ChaosConfig:
    """The 3-cell fault-regime axis: harsh (25 chip-hour MTBF, deadly
    4x stragglers) / moderate (100) / calm (800, mild stragglers).
    Cell 0 plays the true environment in the study."""
    return ChaosConfig(mtbf_chip_hours=np.array([25.0, 100.0, 800.0]),
                       ckpt_period=300.0, straggler_prob=0.1,
                       straggler_factor=np.array([4.0, 1.5, 1.5]),
                       seed=11)


def _trim_ticks(out: dict) -> None:
    """Keep only the per-tick fields the figures need (the full log is
    bulky). Degraded ticks carry no oracle block — hence the ``in t``
    guard — but keep their tick/window/degraded markers."""
    out["ticks"] = [
        {k: t[k] for k in ("tick", "window", "best_k", "best_wait",
                           "plateau_k", "oracle_ms", "degraded") if k in t} |
        {"controllers": {n: c["realized_k"]
                         for n, c in t["controllers"].items()}}
        for t in out["ticks"]]


def run_study(smoke: bool, scenario_filter=None) -> dict:
    shape = SMOKE if smoke else FULL
    flows = drift_scenarios(n_jobs=shape["n_jobs"], nodes=shape["nodes"],
                            n_segments=shape["n_segments"])
    if scenario_filter:
        missing = set(scenario_filter) - set(flows)
        if missing:
            raise SystemExit(f"unknown scenarios {sorted(missing)}; "
                             f"available: {sorted(flows)}")
        flows = {n: flows[n] for n in scenario_filter}
    config = ServiceConfig(window_jobs=shape["window_jobs"],
                           stride_jobs=shape["stride_jobs"])

    scenarios = {}
    for name, wl in flows.items():
        t0 = time.perf_counter()
        out = run_service(wl, config, default_controllers(config))
        secs = time.perf_counter() - t0
        out["seconds"] = secs
        _trim_ticks(out)
        scenarios[name] = out
        ctl = out["controllers"]
        print(f"[{name}] {out['n_ticks']} ticks in {secs:.1f}s")
        for cname, s in ctl.items():
            print(f"    {cname:10s} switches={s['switches']:2d} "
                  f"rel_regret_wait={s['rel_regret_wait']:.4f} "
                  f"mean_regret_useful={s['mean_regret_useful']:.5f} "
                  f"vs_plateau={s['mean_wait_vs_plateau']:+.2f}s")
    return {"shape": shape, "scenarios": scenarios}


def run_chaos_study(smoke: bool) -> dict:
    """The regret-under-faults block: fault-aware vs. fault-blind on the
    chaos-axis service, plus the degrade-harness proof run."""
    shape = SMOKE if smoke else FULL
    flows = drift_scenarios(n_jobs=shape["n_jobs"], nodes=shape["nodes"],
                            n_segments=shape["n_segments"])
    config = ServiceConfig(window_jobs=shape["window_jobs"],
                           stride_jobs=shape["stride_jobs"],
                           chaos=chaos_axis(), chaos_env_cell=0,
                           risk_lambda=CHAOS_RISK_LAMBDA)

    scenarios = {}
    for name in CHAOS_SCENARIOS:
        t0 = time.perf_counter()
        out = run_service(flows[name], config, default_controllers(config))
        out["seconds"] = time.perf_counter() - t0
        _trim_ticks(out)
        scenarios[name] = out
        print(f"[chaos/{name}] {out['n_ticks']} ticks "
              f"in {out['seconds']:.1f}s")
        for cname, s in out["controllers"].items():
            print(f"    {cname:12s} switches={s['switches']:2d} "
                  f"rel_regret_wait={s['rel_regret_wait']:.4f} "
                  f"lost_work={s['total_lost_work']:.0f} machine-s")

    # degrade-harness proof: the same steady trace with faults injected
    # on three distinct ticks must still complete EVERY tick, with a
    # health record per tick, exactly one of them degraded.
    faults = TickFaults(**{k: frozenset(v)
                           for k, v in CHAOS_FAULT_TICKS.items()})
    proof_cfg = dataclasses.replace(config, on_budget_exhausted="degrade")
    pout = run_service(flows["steady"], proof_cfg,
                       default_controllers(proof_cfg), tick_faults=faults)
    n_expected = scenarios["steady"]["n_ticks"]
    proof = {
        "injected": {k: sorted(v) for k, v in CHAOS_FAULT_TICKS.items()},
        "n_ticks": pout["n_ticks"],
        "n_expected_ticks": n_expected,
        "n_degraded_ticks": pout["n_degraded_ticks"],
        "health": pout["health"],
        "completed_all_ticks": bool(
            pout["n_ticks"] == n_expected
            and len(pout["health"]) == pout["n_ticks"]
            and pout["n_degraded_ticks"]
            == len(CHAOS_FAULT_TICKS["exhaust_budget"])),
    }
    print(f"[chaos/degrade-proof] {pout['n_ticks']}/{n_expected} ticks, "
          f"{pout['n_degraded_ticks']} degraded, "
          f"completed_all_ticks={proof['completed_all_ticks']}")
    return {"config": scenarios["steady"]["config"]["chaos"],
            "scenarios": scenarios, "degrade_proof": proof}


def evaluate_chaos_gates(block: dict) -> dict:
    """The --chaos exit-code gates, also recorded in the JSON."""
    scen = block["scenarios"]
    names = list(next(iter(scen.values()))["controllers"])
    lost = {c: sum(s["controllers"][c]["total_lost_work"]
                   for s in scen.values()) for c in names}
    regret = {c: sum(s["controllers"][c]["total_regret_wait"]
                     for s in scen.values()) for c in names}
    gates = {
        "fault_aware_no_more_lost_work": bool(
            lost["fault_aware"] <= lost["hysteresis"] + 1e-9),
        "total_lost_work": lost,
        "bounded_wait_regret": bool(
            regret["fault_aware"]
            <= regret["hysteresis"] * REGRET_SLACK + 1e-6),
        "total_regret_wait": regret,
        "degrade_completes_all_ticks": bool(
            block["degrade_proof"]["completed_all_ticks"]),
        "regret_slack": REGRET_SLACK,
    }
    gates["ok"] = bool(gates["fault_aware_no_more_lost_work"]
                       and gates["bounded_wait_regret"]
                       and gates["degrade_completes_all_ticks"])
    return gates


def evaluate_gates(study: dict) -> dict:
    """The --smoke exit-code gates, also recorded in the JSON."""
    scen = study["scenarios"]
    nonneg = all(
        s["controllers"][c]["mean_regret_wait"] >= -1e-9
        and s["controllers"][c]["mean_regret_useful"] >= -1e-9
        for s in scen.values() for c in s["controllers"])
    switches = {c: sum(s["controllers"][c]["switches"] for s in scen.values())
                for c in next(iter(scen.values()))["controllers"]}
    regret = {c: sum(s["controllers"][c]["total_regret_wait"]
                     for s in scen.values())
              for c in switches}
    steady_rel = (scen["steady"]["controllers"]["hysteresis"]
                  ["rel_regret_wait"] if "steady" in scen else None)
    gates = {
        "regret_nonnegative": bool(nonneg),
        "hysteresis_fewer_switches": bool(
            switches["hysteresis"] < switches["naive"]),
        "switches": switches,
        "comparable_regret": bool(
            regret["hysteresis"] <= regret["naive"] * REGRET_SLACK + 1e-9),
        "total_regret_wait": regret,
        "steady_rel_regret": steady_rel,
        "steady_rel_regret_ok": (None if steady_rel is None
                                 else bool(steady_rel <= STEADY_BAR)),
        "steady_bar": STEADY_BAR,
        "regret_slack": REGRET_SLACK,
    }
    gates["ok"] = bool(
        gates["regret_nonnegative"] and gates["hysteresis_fewer_switches"]
        and gates["comparable_regret"]
        and gates["steady_rel_regret_ok"] is not False)
    return gates


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Streaming-controller regret study")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale traces; exit nonzero if a gate fails")
    ap.add_argument("--chaos", action="store_true",
                    help="add the regret-under-faults block (fault-aware "
                         "vs. fault-blind + the degrade-harness proof)")
    ap.add_argument("--out", default=OUT_PATH,
                    help=f"output JSON path (default {OUT_PATH})")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated scenario subset (default: all)")
    args = ap.parse_args(argv)

    scenario_filter = (args.scenarios.split(",") if args.scenarios else None)
    t0 = time.perf_counter()
    study = run_study(args.smoke, scenario_filter)
    gates = evaluate_gates(study)

    out = {
        "bench": "controller_regret",
        "smoke": bool(args.smoke),
        **study,
        "gates": gates,
        "backend": jax.default_backend(),
        "n_devices": int(jax.device_count()),
        "platform": platform.platform(),
        "unix_time": time.time(),
        "total_seconds": time.perf_counter() - t0,
    }
    chaos_gates = None
    if args.chaos:
        chaos_block = run_chaos_study(args.smoke)
        chaos_gates = evaluate_chaos_gates(chaos_block)
        out["chaos"] = {**chaos_block, "gates": chaos_gates}
        out["total_seconds"] = time.perf_counter() - t0
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {args.out} ({out['total_seconds']:.1f}s)")
    for name, val in gates.items():
        if isinstance(val, bool) or name == "steady_rel_regret_ok":
            print(f"  gate {name}: {val}")
    if chaos_gates is not None:
        for name, val in chaos_gates.items():
            if isinstance(val, bool):
                print(f"  gate chaos.{name}: {val}")
    failed = not gates["ok"] or (chaos_gates is not None
                                 and not chaos_gates["ok"])
    if args.smoke and failed:
        print("SMOKE GATE FAILED")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
