"""Run the paper's full 1332-experiment grid and persist results.

6 workflows x 37 scale ratios x 6 init proportions, exactly the study of
paper §6-7.  Results land in benchmarks/results/paper_grid.json and are read
by the per-figure benchmark functions in benchmarks/run.py.

Precision policy: the PR-2 tolerance study
(benchmarks/results/BENCH_dtype.json) found 77-83% of paper-grid cells on
5000-job HETEROGENEOUS flows schedule differently in float32 vs float64
(near-tie cascades), while homogeneous flows stay at rounding level. Each
workload therefore defaults to the cheapest dtype that is decision-stable:
float64 for heterogeneous flows, float32 for homogeneous ones. ``--float64``
forces everything up, ``--float32`` is the escape hatch that forces
everything down (accepting the documented schedule flips); the per-workload
decision and its reason are persisted in the grid provenance either way.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (PAPER_INIT_PROPS, PAPER_SCALE_RATIOS, run_baselines,
                        run_packet_grid, sweep_plan)
from repro.workload.lublin import paper_workloads

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
GRID_PATH = os.path.join(RESULTS_DIR, "paper_grid.json")


def workload_dtype(wl, force_dtype=None) -> tuple[np.dtype, str]:
    """The per-workload precision decision and why it was made."""
    if force_dtype is not None:
        return np.dtype(force_dtype), "forced by flag"
    if wl.params.homogeneous:
        return np.dtype(np.float32), (
            "homogeneous flow: float32 matches float64 to rounding level "
            "(BENCH_dtype.json)")
    return np.dtype(np.float64), (
        "heterogeneous flow: 77-83% of float32 cells flip schedules "
        "(BENCH_dtype.json near-tie cascades)")


def run_full_grid(n_jobs: int | None = None, seed: int = 0,
                  dtype=None, mode: str = "auto") -> dict:
    """n_jobs=None -> the paper's 5000; smaller for smoke runs.

    ``dtype=None`` (default) applies the per-workload policy of
    `workload_dtype`: float64 for heterogeneous flows, float32 for
    homogeneous ones. Passing a concrete dtype forces it for every
    workload. The chosen dtype (with its reason) and the resolved sweep
    plan are persisted alongside the metrics so downstream figure code and
    cross-PR comparisons know exactly what produced them.
    """
    flows = paper_workloads(seed=seed)
    if n_jobs is not None:
        import dataclasses
        from repro.workload.lublin import generate_workload
        flows = {name: generate_workload(dataclasses.replace(
            wl.params, n_jobs=n_jobs)) for name, wl in flows.items()}

    n_lanes = len(PAPER_SCALE_RATIOS) * len(PAPER_INIT_PROPS)
    decisions = {name: workload_dtype(wl, dtype) for name, wl in flows.items()}
    out = {"scale_ratios": list(PAPER_SCALE_RATIOS),
           "init_props": list(PAPER_INIT_PROPS),
           "dtype": {name: d.name for name, (d, _) in decisions.items()},
           "dtype_reason": {name: why for name, (_, why) in decisions.items()},
           "sweep_plan": sweep_plan(mode, n_lanes),
           "workload_digests": {name: wl.golden_digest()
                                for name, wl in flows.items()},
           "workloads": {}, "baselines": {}, "timing": {}}
    for name, wl in flows.items():
        wl_dtype, _ = decisions[name]
        t0 = time.time()
        grid = run_packet_grid(wl, dtype=wl_dtype, mode=mode)
        dt = time.time() - t0
        out["workloads"][name] = {
            f: np.asarray(getattr(grid, f)).tolist()
            for f in ("avg_wait", "med_wait", "avg_qlen", "full_util",
                      "useful_util", "avg_run_wait", "n_groups", "ok")}
        out["timing"][name] = {"seconds": dt, "experiments": n_lanes,
                               "sec_per_experiment": dt / n_lanes}
        print(f"[paper_sweep] {name}: {n_lanes} experiments in {dt:.1f}s "
              f"({dt / n_lanes * 1e3:.1f} ms/experiment, "
              f"{wl_dtype.name})", flush=True)
        bl = run_baselines(wl, dtype=wl_dtype)
        out["baselines"][name] = {
            alg: {f: np.asarray(getattr(m, f)).tolist()
                  for f in ("avg_wait", "med_wait", "full_util",
                            "useful_util")}
            for alg, m in bl.items()}
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    prec = ap.add_mutually_exclusive_group()
    prec.add_argument("--float64", action="store_true",
                      help="force float64 for ALL workloads (default: only "
                           "heterogeneous flows run float64)")
    prec.add_argument("--float32", action="store_true",
                      help="escape hatch: force float32 for ALL workloads, "
                           "accepting the documented hetero-flow schedule "
                           "flips (BENCH_dtype.json)")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "seq", "chunked", "fused", "vmap_k",
                             "vmap_s"))
    args = ap.parse_args()
    dtype = (np.float64 if args.float64
             else np.float32 if args.float32 else None)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    t0 = time.time()
    res = run_full_grid(dtype=dtype, mode=args.mode)
    res["total_seconds"] = time.time() - t0
    with open(GRID_PATH, "w") as f:
        json.dump(res, f)
    n = sum(t["experiments"] for t in res["timing"].values())
    print(f"[paper_sweep] total: {n} Packet experiments (+12 baseline runs) "
          f"in {res['total_seconds']:.1f}s -> {GRID_PATH}")


if __name__ == "__main__":
    main()
