"""Run the paper's full 1332-experiment grid and persist results.

6 workflows x 37 scale ratios x 6 init proportions, exactly the study of
paper §6-7.  Results land in benchmarks/results/paper_grid.json and are read
by the per-figure benchmark functions in benchmarks/run.py.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (PAPER_INIT_PROPS, PAPER_SCALE_RATIOS, resolve_mode,
                        run_baselines, run_packet_grid)
from repro.workload.lublin import paper_workloads

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
GRID_PATH = os.path.join(RESULTS_DIR, "paper_grid.json")


def run_full_grid(n_jobs: int | None = None, seed: int = 0,
                  dtype=np.float32, mode: str = "auto") -> dict:
    """n_jobs=None -> the paper's 5000; smaller for smoke runs.

    `dtype=np.float64` runs the whole study through the scoped precision
    opt-in (see repro.core.precision); the chosen dtype and the resolved
    sweep mode are persisted alongside the metrics so downstream figure
    code and cross-PR comparisons know exactly what produced them.
    """
    flows = paper_workloads(seed=seed)
    if n_jobs is not None:
        import dataclasses
        from repro.workload.lublin import generate_workload
        flows = {name: generate_workload(dataclasses.replace(
            wl.params, n_jobs=n_jobs)) for name, wl in flows.items()}

    n_lanes = len(PAPER_SCALE_RATIOS) * len(PAPER_INIT_PROPS)
    out = {"scale_ratios": list(PAPER_SCALE_RATIOS),
           "init_props": list(PAPER_INIT_PROPS),
           "dtype": np.dtype(dtype).name,
           "sweep_mode": resolve_mode(mode, n_lanes),
           "workload_digests": {name: wl.golden_digest()
                                for name, wl in flows.items()},
           "workloads": {}, "baselines": {}, "timing": {}}
    for name, wl in flows.items():
        t0 = time.time()
        grid = run_packet_grid(wl, dtype=dtype, mode=mode)
        dt = time.time() - t0
        out["workloads"][name] = {
            f: np.asarray(getattr(grid, f)).tolist()
            for f in ("avg_wait", "med_wait", "avg_qlen", "full_util",
                      "useful_util", "avg_run_wait", "n_groups", "ok")}
        out["timing"][name] = {"seconds": dt, "experiments": n_lanes,
                               "sec_per_experiment": dt / n_lanes}
        print(f"[paper_sweep] {name}: {n_lanes} experiments in {dt:.1f}s "
              f"({dt / n_lanes * 1e3:.1f} ms/experiment)", flush=True)
        bl = run_baselines(wl, dtype=dtype)
        out["baselines"][name] = {
            alg: {f: np.asarray(getattr(m, f)).tolist()
                  for f in ("avg_wait", "med_wait", "full_util",
                            "useful_util")}
            for alg, m in bl.items()}
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--float64", action="store_true",
                    help="run the study in float64 via the precision opt-in")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "seq", "fused", "vmap_k", "vmap_s"))
    args = ap.parse_args()
    os.makedirs(RESULTS_DIR, exist_ok=True)
    t0 = time.time()
    res = run_full_grid(dtype=np.float64 if args.float64 else np.float32,
                        mode=args.mode)
    res["total_seconds"] = time.time() - t0
    with open(GRID_PATH, "w") as f:
        json.dump(res, f)
    n = sum(t["experiments"] for t in res["timing"].values())
    print(f"[paper_sweep] total: {n} Packet experiments (+12 baseline runs) "
          f"in {res['total_seconds']:.1f}s -> {GRID_PATH}")


if __name__ == "__main__":
    main()
