"""Run the paper's full 1332-experiment grid and persist results.

6 workflows x 37 scale ratios x 6 init proportions, exactly the study of
paper §6-7.  Results land in benchmarks/results/paper_grid.json and are read
by the per-figure benchmark functions in benchmarks/run.py.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (PAPER_INIT_PROPS, PAPER_SCALE_RATIOS, run_baselines,
                        run_packet_grid)
from repro.workload.lublin import paper_workloads

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
GRID_PATH = os.path.join(RESULTS_DIR, "paper_grid.json")


def run_full_grid(n_jobs: int | None = None, seed: int = 0) -> dict:
    """n_jobs=None -> the paper's 5000; smaller for smoke runs."""
    flows = paper_workloads(seed=seed)
    if n_jobs is not None:
        import dataclasses
        from repro.workload.lublin import generate_workload
        flows = {name: generate_workload(dataclasses.replace(
            wl.params, n_jobs=n_jobs)) for name, wl in flows.items()}

    out = {"scale_ratios": list(PAPER_SCALE_RATIOS),
           "init_props": list(PAPER_INIT_PROPS),
           "workloads": {}, "baselines": {}, "timing": {}}
    for name, wl in flows.items():
        t0 = time.time()
        grid = run_packet_grid(wl)
        dt = time.time() - t0
        n_exp = len(PAPER_SCALE_RATIOS) * len(PAPER_INIT_PROPS)
        out["workloads"][name] = {
            f: np.asarray(getattr(grid, f)).tolist()
            for f in ("avg_wait", "med_wait", "avg_qlen", "full_util",
                      "useful_util", "avg_run_wait", "n_groups", "ok")}
        out["timing"][name] = {"seconds": dt, "experiments": n_exp,
                               "sec_per_experiment": dt / n_exp}
        print(f"[paper_sweep] {name}: {n_exp} experiments in {dt:.1f}s "
              f"({dt / n_exp * 1e3:.1f} ms/experiment)", flush=True)
        bl = run_baselines(wl)
        out["baselines"][name] = {
            alg: {f: np.asarray(getattr(m, f)).tolist()
                  for f in ("avg_wait", "med_wait", "full_util",
                            "useful_util")}
            for alg, m in bl.items()}
    return out


def main():
    os.makedirs(RESULTS_DIR, exist_ok=True)
    t0 = time.time()
    res = run_full_grid()
    res["total_seconds"] = time.time() - t0
    with open(GRID_PATH, "w") as f:
        json.dump(res, f)
    n = sum(t["experiments"] for t in res["timing"].values())
    print(f"[paper_sweep] total: {n} Packet experiments (+12 baseline runs) "
          f"in {res['total_seconds']:.1f}s -> {GRID_PATH}")


if __name__ == "__main__":
    main()
