"""Run the paper's full 1332-experiment grid and persist results.

6 workflows x 37 scale ratios x 6 init proportions, exactly the study of
paper §6-7.  Results land in benchmarks/results/paper_grid.json and are read
by the per-figure benchmark functions in benchmarks/run.py.

Cohort execution: the 6 workflows are no longer iterated sequentially in
Python. `repro.core.cohort.group_workloads` partitions them by compile-time
statics — under the default precision policy below, exactly two cohorts:
3 heterogeneous flows (M=500, float64) and 3 homogeneous flows (M=100,
float32) — and `run_cohort_grid` runs each cohort's whole W x 222-lane
study as one batched program family (666 lanes per cohort instead of three
sequential 222-lane sweeps). Per-workload results are unstacked back into
the same paper_grid.json schema as before, so the figure code in
benchmarks/run.py is untouched; per-cohort timing and the cohort sweep plan
are persisted alongside (``cohorts`` / ``sweep_plan`` keys).

Precision policy: the PR-2 tolerance study
(benchmarks/results/BENCH_dtype.json) found 77-83% of paper-grid cells on
5000-job HETEROGENEOUS flows schedule differently in float32 vs float64
(near-tie cascades), while homogeneous flows stay at rounding level. Each
workload therefore defaults to the cheapest dtype that is decision-stable:
float64 for heterogeneous flows, float32 for homogeneous ones. ``--float64``
forces everything up, ``--float32`` is the escape hatch that forces
everything down (accepting the documented schedule flips); the per-workload
decision and its reason are persisted in the grid provenance either way.

``--workloads name1,name2`` restricts the study to a subset of the 6 flows
(smoke runs and bisection then pay only for the workloads under test).

``--chaos`` re-runs the study as a fault sweep: every (workload, k, s)
cell is crossed with a chaos lane axis of MTBF x checkpoint-period x
straggler-factor cells (`chaos_grid_config`), the grids gain the fault
metrics (lost_work / failures / straggler_kills / requeues /
requeued_jobs / budget_exhausted) with a trailing chaos axis, and
results land in ``paper_chaos_grid.json`` so the zero-chaos study file
stays untouched. A ``figure_scale_ratio_vs_faults`` block summarizes
the study's question — how the avg_wait-optimal scale ratio k* and its
5% plateau shift with fault rate and checkpoint cadence — per
(workload, init proportion, chaos cell), ready for figure code.
Baselines are skipped under chaos — FCFS/backfill carry no fault
semantics to compare against.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import (PAPER_INIT_PROPS, PAPER_SCALE_RATIOS, ChaosConfig,
                        chaos_axis_len, group_workloads, run_baselines,
                        run_cohort_grid, sweep_plan)
from repro.workload.lublin import paper_workloads

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
GRID_PATH = os.path.join(RESULTS_DIR, "paper_grid.json")
CHAOS_GRID_PATH = os.path.join(RESULTS_DIR, "paper_chaos_grid.json")

GRID_FIELDS = ("avg_wait", "med_wait", "avg_qlen", "full_util",
               "useful_util", "avg_run_wait", "n_groups", "ok")
CHAOS_FIELDS = ("lost_work", "failures", "straggler_kills", "requeues",
                "requeued_jobs", "budget_exhausted")
BASELINE_FIELDS = ("avg_wait", "med_wait", "full_util", "useful_util")

# a cell's k belongs to the optimal plateau if its avg_wait is within
# this relative tolerance of the best k's avg_wait (paper §7 reads the
# tuning curves as flat-bottomed valleys, not single sharp minima)
PLATEAU_RTOL = 0.05

# the --chaos study axes: every combination becomes one chaos lane cell
CHAOS_MTBF_HOURS = (50.0, 200.0)
CHAOS_CKPT_PERIODS = (120.0, 600.0)
CHAOS_STRAGGLER_FACTORS = (1.5, 4.0)


def chaos_grid_config(seed: int = 0) -> ChaosConfig:
    """The fault sweep's chaos lane axis: MTBF x ckpt x straggler factor.

    Scalar straggler probability/deadline broadcast across the cells; the
    factor axis spans "stretch absorbed within the 2x deadline" (1.5) and
    "stretch that triggers a deadline kill" (4.0), so the sweep exercises
    both straggler outcomes.
    """
    mtbf, ckpt, factor = np.meshgrid(
        np.asarray(CHAOS_MTBF_HOURS), np.asarray(CHAOS_CKPT_PERIODS),
        np.asarray(CHAOS_STRAGGLER_FACTORS), indexing="ij")
    return ChaosConfig(mtbf_chip_hours=mtbf.ravel(),
                       ckpt_period=ckpt.ravel(),
                       straggler_prob=0.1,
                       straggler_factor=factor.ravel(),
                       straggler_deadline=2.0, seed=seed)


def chaos_figure_data(out: dict) -> dict:
    """The scale-ratio-under-faults figure block, from a chaos-study dict.

    For every (workload, init proportion, chaos cell): the scale ratio
    minimizing avg_wait (``best_k``), its wait, and the lowest/highest k
    whose avg_wait stays within `PLATEAU_RTOL` of that minimum — the
    flat-bottomed tuning valley the paper reads optima from. Lists are
    indexed ``[init_prop][chaos_cell]``; the chaos-cell parameter axes
    are echoed so figure code needs no second file. Cells whose schedule
    was truncated (``ok`` False) are excluded from the minimization.
    """
    ks = np.asarray(out["scale_ratios"], np.float64)
    cells = out["chaos_cells"]
    fig = {"plateau_rtol": PLATEAU_RTOL,
           "mtbf_chip_hours": cells["mtbf_chip_hours"],
           "ckpt_period": cells["ckpt_period"],
           "straggler_factor": cells["straggler_factor"],
           "workloads": {}}
    n_k = len(ks)
    for name, grids in out["workloads"].items():
        aw = np.asarray(grids["avg_wait"], np.float64)      # [K, S, C]
        ok = np.asarray(grids["ok"], bool)
        aw = np.where(ok, aw, np.inf)
        best_wait = np.min(aw, axis=0)                      # [S, C]
        within = np.isfinite(aw) & (aw <= best_wait * (1.0 + PLATEAU_RTOL))
        k_idx = np.arange(n_k)[:, None, None]
        lo = np.minimum(np.min(np.where(within, k_idx, n_k), axis=0),
                        n_k - 1)
        hi = np.maximum(np.max(np.where(within, k_idx, -1), axis=0), 0)
        fig["workloads"][name] = {
            "best_k": ks[np.argmin(aw, axis=0)].tolist(),
            "best_avg_wait": np.where(np.isfinite(best_wait), best_wait,
                                      -1.0).tolist(),
            "plateau_k_lo": ks[lo].tolist(),
            "plateau_k_hi": ks[hi].tolist()}
    return fig


def workload_dtype(wl, force_dtype=None) -> tuple[np.dtype, str]:
    """The per-workload precision decision and why it was made."""
    if force_dtype is not None:
        return np.dtype(force_dtype), "forced by flag"
    if wl.params.homogeneous:
        return np.dtype(np.float32), (
            "homogeneous flow: float32 matches float64 to rounding level "
            "(BENCH_dtype.json)")
    return np.dtype(np.float64), (
        "heterogeneous flow: 77-83% of float32 cells flip schedules "
        "(BENCH_dtype.json near-tie cascades)")


def select_workloads(flows: dict, names) -> dict:
    """Subset `flows` to the requested names, preserving study order."""
    names = [n.strip() for n in names if n.strip()]
    unknown = [n for n in names if n not in flows]
    if unknown:
        raise ValueError(f"unknown workloads {unknown}; "
                         f"available: {sorted(flows)}")
    return {name: flows[name] for name in flows if name in names}


def run_full_grid(n_jobs: int | None = None, seed: int = 0,
                  dtype=None, mode: str = "auto",
                  workloads=None, chaos: ChaosConfig | None = None) -> dict:
    """n_jobs=None -> the paper's 5000; smaller for smoke runs.

    ``dtype=None`` (default) applies the per-workload policy of
    `workload_dtype`: float64 for heterogeneous flows, float32 for
    homogeneous ones. Passing a concrete dtype forces it for every
    workload. ``workloads`` (iterable of names) restricts the study to a
    subset of the 6 flows.

    The flows are grouped into same-static cohorts and each cohort runs as
    one batched study (`run_cohort_grid`); results are unstacked into the
    per-workload schema the figure code reads, and the chosen dtypes (with
    reasons), per-cohort sweep plans, and per-cohort timing are persisted
    so downstream comparisons know exactly what produced them.
    """
    flows = paper_workloads(seed=seed)
    if workloads is not None:
        flows = select_workloads(flows, list(workloads))
    if n_jobs is not None:
        import dataclasses
        from repro.workload.lublin import generate_workload
        flows = {name: generate_workload(dataclasses.replace(
            wl.params, n_jobs=n_jobs)) for name, wl in flows.items()}

    C = chaos_axis_len(chaos) if chaos is not None else 1
    n_grid = len(PAPER_SCALE_RATIOS) * len(PAPER_INIT_PROPS)
    n_lanes = n_grid * C
    grid_fields = GRID_FIELDS + (CHAOS_FIELDS if chaos is not None else ())
    decisions = {name: workload_dtype(wl, dtype) for name, wl in flows.items()}
    cohorts = group_workloads(flows, {name: d
                                      for name, (d, _) in decisions.items()},
                              chaos=chaos)
    out = {"scale_ratios": list(PAPER_SCALE_RATIOS),
           "init_props": list(PAPER_INIT_PROPS),
           "dtype": {name: d.name for name, (d, _) in decisions.items()},
           "dtype_reason": {name: why for name, (_, why) in decisions.items()},
           "sweep_plan": {}, "cohorts": {},
           "workload_digests": {name: wl.golden_digest()
                                for name, wl in flows.items()},
           "workloads": {}, "baselines": {}, "timing": {}}
    if chaos is not None:
        # per-cell parameter values along the trailing chaos axis of every
        # grid field (seed/requeue bound are in each cohort's sweep_plan)
        out["chaos_cells"] = {
            "axis_len": C,
            **{f: np.broadcast_to(np.asarray(getattr(chaos, f), np.float64),
                                  (C,)).tolist()
               for f in ("mtbf_chip_hours", "ckpt_period", "straggler_prob",
                         "straggler_factor", "straggler_deadline")}}

    for cohort in cohorts:
        w = cohort.n_workloads
        t0 = time.time()
        # run_cohort_grid returns host numpy, but block explicitly so the
        # recorded wall clock measures completed compute, not dispatch,
        # even if the unstacking path ever returns device arrays again.
        grids = jax.block_until_ready(
            run_cohort_grid(cohort, mode=mode, chaos=chaos))
        dt = time.time() - t0
        out["sweep_plan"][cohort.label] = sweep_plan(mode, n_grid, w,
                                                    chaos=chaos)
        out["cohorts"][cohort.label] = {
            "workloads": list(cohort.names), "dtype": cohort.dtype.name,
            "m_nodes": cohort.m_nodes, "n_jobs": cohort.n_jobs,
            "seconds": dt, "experiments": w * n_lanes,
            "sec_per_experiment": dt / (w * n_lanes)}
        for name in cohort.names:
            out["workloads"][name] = {
                f: np.asarray(getattr(grids[name], f)).tolist()
                for f in grid_fields}
            out["timing"][name] = {
                "seconds": dt / w, "experiments": n_lanes,
                "sec_per_experiment": dt / (w * n_lanes),
                "cohort": cohort.label}
        print(f"[paper_sweep] cohort {cohort.label} "
              f"({', '.join(cohort.names)}): {w * n_lanes} experiments in "
              f"{dt:.1f}s ({dt / (w * n_lanes) * 1e3:.1f} ms/experiment, "
              f"{cohort.dtype.name})", flush=True)

    if chaos is not None:
        out["figure_scale_ratio_vs_faults"] = chaos_figure_data(out)
    if chaos is None:
        for name, wl in flows.items():
            wl_dtype, _ = decisions[name]
            t0 = time.time()
            bl = jax.block_until_ready(run_baselines(wl, dtype=wl_dtype))
            out["timing"][name]["baseline_seconds"] = time.time() - t0
            out["baselines"][name] = {
                alg: {f: np.asarray(getattr(m, f)).tolist()
                      for f in BASELINE_FIELDS}
                for alg, m in bl.items()}
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    prec = ap.add_mutually_exclusive_group()
    prec.add_argument("--float64", action="store_true",
                      help="force float64 for ALL workloads (default: only "
                           "heterogeneous flows run float64)")
    prec.add_argument("--float32", action="store_true",
                      help="escape hatch: force float32 for ALL workloads, "
                           "accepting the documented hetero-flow schedule "
                           "flips (BENCH_dtype.json)")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "seq", "chunked", "fused"),
                    help="cohort dispatch layout (the legacy vmap_k/vmap_s "
                         "layouts have no cohort form; use run_packet_grid "
                         "directly for those A/Bs)")
    ap.add_argument("--workloads", default=None, metavar="NAME1,NAME2",
                    help="run only these flows (comma-separated subset of "
                         "the 6 paper workflows), e.g. "
                         "--workloads homog0.85,hetero0.85")
    ap.add_argument("--chaos", action="store_true",
                    help="cross the study with the fault-parameter grid "
                         "(MTBF x ckpt period x straggler factor, "
                         "chaos_grid_config) and write paper_chaos_grid.json "
                         "instead of the zero-chaos study file")
    ap.add_argument("--chaos-seed", type=int, default=0, metavar="SEED",
                    help="fault-stream seed for --chaos (default 0)")
    ap.add_argument("--n-jobs", type=int, default=None, metavar="N",
                    help="jobs per workload (default: the paper's 5000; "
                         "smaller for smoke/CI runs)")
    args = ap.parse_args()
    dtype = (np.float64 if args.float64
             else np.float32 if args.float32 else None)
    names = args.workloads.split(",") if args.workloads else None
    chaos = chaos_grid_config(seed=args.chaos_seed) if args.chaos else None
    out_path = CHAOS_GRID_PATH if args.chaos else GRID_PATH
    os.makedirs(RESULTS_DIR, exist_ok=True)
    t0 = time.time()
    res = run_full_grid(n_jobs=args.n_jobs, dtype=dtype, mode=args.mode,
                        workloads=names, chaos=chaos)
    res["total_seconds"] = time.time() - t0
    with open(out_path, "w") as f:
        json.dump(res, f)
    if chaos is not None:
        fig = res["figure_scale_ratio_vs_faults"]
        for name, d in fig["workloads"].items():
            b = np.asarray(d["best_k"])
            print(f"[paper_sweep]   {name}: avg_wait-optimal k spans "
                  f"{b.min():g}..{b.max():g} across "
                  f"{len(fig['mtbf_chip_hours'])} fault cells "
                  f"x {b.shape[0]} init props")
    n = sum(t["experiments"] for t in res["timing"].values())
    n_bl = 2 * len(res["baselines"])
    print(f"[paper_sweep] total: {n} Packet experiments in "
          f"{len(res['cohorts'])} cohort stud(ies) (+{n_bl} baseline runs) "
          f"in {res['total_seconds']:.1f}s -> {out_path}")


if __name__ == "__main__":
    main()
