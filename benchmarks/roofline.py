"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Three terms per (arch x shape x mesh) cell, in seconds per step per device:

  compute    = FLOPs/device / 197 TFLOP/s (bf16)
  memory     = HBM bytes/device / 819 GB/s
  collective = link bytes/device / 50 GB/s

FLOPs and HBM bytes use an *analytic* workload model (matmul-exact, the
same arithmetic MFU papers use) because XLA's ``cost_analysis()`` counts a
``lax.scan`` body once rather than x trip-count — the raw HLO number is
reported alongside as a cross-check. Collective bytes ARE taken from the
compiled HLO (launch/hlo_stats.py), with while-loop trip scaling applied.

The memory term is strategy-aware: under TP each model-column rank
processes ALL tokens of its data column (weights sharded /tp, activations
x tp); under DP-ZeRO the weights are read in full per chip (gather +
stream) but activations shard /chips.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per the assignment; the
ratio MODEL_FLOPS / total-compiled-compute exposes remat recompute, GShard
dispatch overhead, expert padding and KV-replication waste.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Optional

from repro.configs import SHAPES, Shape, get_config
from repro.models import analysis
from repro.models.analysis import (active_param_count, family_counts, pad16,
                                   param_count, param_dtype_bytes)
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12         # TPU v5e bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
LINK_BW = 50e9              # bytes/s per ICI link

RESULTS = os.path.join(os.path.dirname(__file__), "results")


# --------------------------------------------------------------- FLOPs

def _attn_flops(cfg: ModelConfig, B: int, S: int, T: int, causal: bool,
                window: int, n_attn_layers: int) -> float:
    eff = min(T, window) if window else T
    if causal and not window and S == T:
        eff = T / 2                                   # causal triangle
    return 4.0 * B * S * eff * cfg.n_heads * cfg.hd * n_attn_layers


def fwd_flops(cfg: ModelConfig, B: int, S: int, expert_pad: int = 0,
              with_loss: bool = True) -> dict:
    """Forward FLOPs breakdown (global)."""
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    tok = B * S
    br = {}
    n_attn, n_rec, n_m, n_s = family_counts(cfg)

    if cfg.family == "encdec":
        ne, nd = cfg.n_enc_layers, cfg.n_dec_layers
        qkv = 2 * d * (H + 2 * KV) * hd
        br["attn_proj"] = tok * (qkv + 2 * d * H * hd) * (ne + nd)
        br["xattn_proj"] = tok * (qkv + 2 * d * H * hd) * nd
        br["attn"] = (_attn_flops(cfg, B, S, S, False, 0, ne) +
                      _attn_flops(cfg, B, S, S, True, 0, nd) +
                      _attn_flops(cfg, B, S, S, False, 0, nd))
        ff_mult = 4 if cfg.mlp_type == "gelu" else 6
        br["mlp"] = tok * ff_mult * d * cfg.d_ff * (ne + nd)
    elif cfg.family == "ssm":
        from repro.models.xlstm import _slstm_ff
        di = 2 * d
        dh = di // cfg.n_heads
        per_m = 2 * d * 2 * di + 6 * di * dh + 2 * di * d
        chunk = cfg.mlstm_chunk
        per_m_cell = 4 * cfg.n_heads * chunk * dh + 6 * cfg.n_heads * dh * dh
        br["mlstm"] = tok * (per_m + per_m_cell) * n_m
        dhs = d // cfg.n_heads
        per_s = 2 * d * 4 * d + 2 * cfg.n_heads * dhs * 4 * dhs + \
            6 * d * _slstm_ff(d)
        br["slstm"] = tok * per_s * n_s
    else:
        dr = cfg.d_rnn or d
        if n_attn:
            qkv = 2 * d * (H + 2 * KV) * hd
            br["attn_proj"] = tok * (qkv + 2 * d * H * hd) * n_attn
            br["attn"] = _attn_flops(cfg, B, S, S, True, cfg.local_window,
                                     n_attn)
        if n_rec:
            br["rglru"] = tok * (6 * d * dr + 4 * dr * dr + 10 * dr) * n_rec
        if cfg.n_experts:
            E = expert_pad or cfg.n_experts
            k = cfg.experts_per_token
            C = max(1, math.ceil(S * k / E * cfg.capacity_factor))
            br["router"] = tok * 2 * d * E * cfg.n_layers
            br["moe_dispatch"] = 2 * (2.0 * B * S * E * C * d) * cfg.n_layers
            br["experts"] = tok * k * 6 * d * cfg.expert_d_ff * cfg.n_layers
            par_ff = cfg.shared_expert_d_ff or (cfg.d_ff if
                                                cfg.dense_residual else 0)
            if par_ff:
                br["shared_mlp"] = tok * 6 * d * par_ff * cfg.n_layers
        else:
            ff_mult = 4 if cfg.mlp_type == "gelu" else 6
            br["mlp"] = tok * ff_mult * d * cfg.d_ff * cfg.n_layers
    if with_loss:
        br["unembed"] = tok * 2 * d * pad16(cfg.vocab_size)
    return br


def decode_flops(cfg: ModelConfig, B: int, T: int, kv_repeat: int,
                 expert_pad: int) -> dict:
    br = fwd_flops(cfg, B, 1, expert_pad, with_loss=True)
    n_attn, n_rec, n_m, n_s = family_counts(cfg)
    eff = min(T, cfg.local_window) if cfg.local_window else T
    if "attn" in br:
        br["attn"] = 4.0 * B * eff * cfg.n_heads * cfg.hd * n_attn
    if cfg.family == "encdec":
        from repro.models.encdec import MEMORY_LEN
        br["attn"] = 4.0 * B * (T + MEMORY_LEN) * cfg.n_heads * cfg.hd * \
            cfg.n_dec_layers
    return br


# --------------------------------------------------------------- HBM

def per_device_hbm(cfg: ModelConfig, shape: Shape, strategy: str,
                   kv_repeat: int, expert_pad: int, chips: int, tp: int,
                   dp: int, moment_bytes: int = 4) -> float:
    """Per-device HBM traffic per step (bytes), strategy-aware."""
    B, S = shape.batch, shape.seq
    bp = param_dtype_bytes(cfg)
    bc = 2 if cfg.compute_dtype == "bfloat16" else 4
    P = param_count(cfg, expert_pad)
    d, L = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        passes = 3.0 if cfg.remat == "full" else 2.0    # fwd(+refwd)+bwd
        if strategy == "dp_zero1":
            w = P * bp * (passes + 1)                    # + grad write
            opt = (4 * P * moment_bytes + 2 * P * bp) / dp
            tok_chip = B * S / chips
        elif strategy == "dp_zero3":
            w = P * bp * (passes + 1)                    # gathered stream
            opt = (4 * P * moment_bytes + 2 * P * bp) / chips
            tok_chip = B * S / chips
        else:                                            # tp
            w = P * bp * (passes + 1) / tp
            opt = (4 * P * moment_bytes + 2 * P * bp) / chips
            tok_chip = B * S / dp
        acts = 4.0 * tok_chip * d * L * bc
        return w + opt + acts
    if shape.kind == "prefill":
        tok_chip = B * S / dp
        return P * bp / tp + 2.0 * tok_chip * d * L * bc
    # decode: active params (sharded over model) + cache shard per chip
    n_attn, n_rec, n_m, n_s = family_counts(cfg)
    act = active_param_count(cfg) * bp / tp
    eff = min(S, cfg.local_window) if cfg.local_window else S
    kvr = cfg.n_kv_heads * kv_repeat
    cache = 2.0 * B * eff * kvr * cfg.hd * 2 * max(n_attn, 1) / chips
    if cfg.family == "ssm":
        di = 2 * d
        dh = di // cfg.n_heads
        cache = 2.0 * B * cfg.n_heads * dh * dh * 4 * n_m / chips
    if cfg.family == "encdec":
        cache = 2.0 * B * S * kvr * cfg.hd * 2 * cfg.n_dec_layers / chips
    return act + cache


# ------------------------------------------------- DES event-step model

def event_step_cost(n_jobs: int, n_types: int, ring: int,
                    dtype_bytes: int = 4, chaos: bool = False) -> dict:
    """Analytic bytes/event and flops/event for the fused DES event step.

    Models one lane-column of `repro.kernels.packet_step` (equivalently
    one `packet_scan_step` trip): the per-event working set is the
    23-column scan state — 12 scalars, 5 [H] per-type rows, 6 [ring]
    group-ring rows — read and written once per event, plus the workload
    gathers (prefix-sum rows at head/tail per type, the submit-time and
    job-type picks) that cannot stay resident because they index into
    [N]-sized arrays. Float work is a handful of elementwise ops per
    type row (`packet.queue_weights`) and per-event group math; with
    chaos, the outcome draw plus the fixed-trip `_credit_cut` binary
    search (ceil(log2(N+1)) gathers of one element each). Constants are
    deliberately coarse — the point of the model is the *ratio*: tens of
    bytes moved per float op puts the step deep in the memory-bound
    regime, which is the quantitative argument for keeping the ring
    state kernel-resident (VMEM) rather than bouncing it through HBM
    every `lax.scan` trip.
    """
    H, R = int(n_types), int(ring)
    state_elems = 12 + 5 * H + 6 * R
    state_bytes = 2 * state_elems * dtype_bytes          # read + write
    # prefw[tail] + prefw[head] per type row, submit/jtype/t_sub picks
    gathers = 2 * H + 6
    if chaos:
        gathers += max(int(n_jobs + 1).bit_length(), 1)  # _credit_cut
        gathers += 8                # uniforms, pool decode, remnant walk
    gather_bytes = gathers * dtype_bytes
    flops = 14 * H + 48 + (64 if chaos else 0)
    return {
        "n_jobs": int(n_jobs), "n_types": H, "ring": R,
        "dtype_bytes": int(dtype_bytes), "chaos": bool(chaos),
        "state_bytes_per_event": state_bytes,
        "gather_bytes_per_event": gather_bytes,
        "bytes_per_event": state_bytes + gather_bytes,
        "flops_per_event": flops,
    }


def event_step_roofline(n_jobs: int, n_types: int, ring: int,
                        n_lanes: int = 1, dtype_bytes: int = 4,
                        chaos: bool = False,
                        budget: int | None = None) -> dict:
    """Predicted ceiling for one DES experiment on the reference device.

    Applies the §Roofline terms to `event_step_cost`: a lane pays
    ``budget`` (~3N) events, each bounded below by max(bytes/HBM_BW,
    flops/PEAK_FLOPS) with the byte traffic amortized over the `n_lanes`
    lanes of one dispatch (the flop term never binds — the step is
    hundreds of bytes per ~100 flops). ``predicted_ms_per_experiment``
    is what an HBM-resident scan step costs at the device's streaming
    bandwidth; a kernel that keeps the state columns VMEM-resident pays
    only the gather traffic, so the gap between the two predictions
    (``state_resident_ms_per_experiment``) is the headroom the Pallas
    event-step kernel chases. BENCH_des records both next to the
    measured engines.
    """
    cost = event_step_cost(n_jobs, n_types, ring, dtype_bytes, chaos)
    ev = int(budget) if budget is not None else 3 * int(n_jobs)
    lanes = max(1, int(n_lanes))
    mem_s = ev * cost["bytes_per_event"] / HBM_BW
    flop_s = ev * cost["flops_per_event"] / PEAK_FLOPS
    resident_s = ev * cost["gather_bytes_per_event"] / HBM_BW
    return {
        **cost,
        "events_per_lane": ev, "n_lanes": lanes,
        "bound": "memory" if mem_s >= flop_s else "compute",
        "predicted_ms_per_experiment": max(mem_s, flop_s) * 1e3,
        "state_resident_ms_per_experiment": max(resident_s, flop_s) * 1e3,
        "peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
    }


# --------------------------------------------------------------- terms

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    strategy: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    total_flops: float
    hlo_flops_raw: float
    bound: str
    frac_of_roofline: float       # compute / sum(terms): achievable MFU
    notes: str = ""

    def table_row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.strategy} | "
                f"{self.compute_s * 1e3:.2f} | {self.memory_s * 1e3:.2f} | "
                f"{self.collective_s * 1e3:.2f} | {self.bound} | "
                f"{self.frac_of_roofline * 100:.1f}% | "
                f"{self.model_flops / max(self.total_flops, 1):.2f} |")


def analyze_record(rec: dict) -> Optional[Roofline]:
    if not rec.get("ok"):
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["devices"]
    pol = rec["policy"]
    kvr = pol.get("kv_repeat", 1)
    epad = pol.get("expert_pad", 0)
    strategy = pol.get("strategy", "tp")
    tp = 16
    dp = chips // tp

    bpe = param_dtype_bytes(cfg)
    n_act = active_param_count(cfg)
    if shape.kind == "decode":
        br = decode_flops(cfg, shape.batch, shape.seq, kvr, epad)
        total = sum(br.values())
        model = 2.0 * n_act * shape.batch
    else:
        br = fwd_flops(cfg, shape.batch, shape.seq, epad,
                       with_loss=(shape.kind == "train"))
        fwd = sum(br.values())
        if shape.kind == "train":
            remat = 1.0 if cfg.remat == "full" else 0.0
            total = fwd * 3.0 + fwd * remat
            model = 6.0 * n_act * shape.batch * shape.seq
        else:
            total = fwd
            # prefill computes no logits: exclude the unembed params
            model = 2.0 * (n_act - pad16(cfg.vocab_size) * cfg.d_model) \
                * shape.batch * shape.seq

    mb = 2 if rec["arch"].startswith("arctic") else 4
    hbm = per_device_hbm(cfg, shape, strategy, kvr, epad, chips, tp, dp, mb)
    coll = rec.get("collectives", {}).get("link_bytes_per_device", 0.0)

    compute_s = total / chips / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)
    frac = compute_s / max(sum(terms.values()), 1e-30)
    fix = _suggestion(bound, strategy, cfg, shape)
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        strategy=strategy,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=model, total_flops=total,
        hlo_flops_raw=rec.get("flops", -1), bound=bound,
        frac_of_roofline=frac,
        notes="; ".join([fix] + pol.get("notes", [])))


def _suggestion(bound: str, strategy: str, cfg: ModelConfig,
                shape: Shape) -> str:
    """One sentence: what would move the dominant term down."""
    if bound == "compute":
        return ("fix: compute-bound — fuse attention/recurrence via the "
                "Pallas kernels; next win is arithmetic, not layout")
    if bound == "memory":
        if shape.kind == "decode":
            return ("fix: int8/fp8 KV-cache + wider decode batches to "
                    "amortize param streaming")
        return "fix: tighter remat policy / activation dtype"
    # collective-bound
    if cfg.n_experts and strategy in ("tp", "serve"):
        return ("fix: explicit shard_map all-to-all expert routing "
                "instead of SPMD-auto dispatch")
    if strategy == "dp_zero1":
        return ("fix: quantized (int8/fp8) gradient all-reduce; overlap "
                "bucketed reduce with backward compute")
    if strategy == "dp_zero3":
        return ("fix: overlap param gathers with compute (latency-hiding "
                "scheduler); ZeRO-1 if params fit HBM")
    if strategy == "dp_seq":
        return ("fix: ring-attention pipelining of the per-layer K/V "
                "gathers")
    if strategy == "serve":
        return ("fix: hierarchical (ICI-first) all-reduce; replicate "
                "small weights")
    return ("fix: sequence-parallel norms/residuals (halves TP "
            "activation all-reduces)")


def load_records(paths=None) -> list[dict]:
    paths = paths or [os.path.join(RESULTS, "dryrun.json"),
                      os.path.join(RESULTS, "dryrun_extra.json")]
    by_cell = {}
    for p in paths:
        if not os.path.exists(p):
            continue
        for r in json.load(open(p)):
            k = (r.get("arch"), r.get("shape"),
                 "multi" if (r.get("devices") == 512 or
                             "2x" in str(r.get("mesh"))) else "single")
            if k not in by_cell or r.get("ok"):
                by_cell[k] = r
    return list(by_cell.values())


def analyze_all(paths=None) -> list[Roofline]:
    rows = [analyze_record(r) for r in load_records(paths)]
    rows = [r for r in rows if r]
    rows.sort(key=lambda x: (x.arch, x.shape, x.mesh))
    return rows


HDR = ("| arch | shape | mesh | strategy | compute ms | memory ms | "
       "collective ms | bound | roofline frac | useful/total |")


def main():
    import sys
    paths = sys.argv[1:] or None
    rows = analyze_all(paths)
    print(HDR)
    print("|" + "---|" * 10)
    for row in rows:
        print(row.table_row())
    out = os.path.join(RESULTS, "roofline.json")
    with open(out, "w") as f:
        json.dump([dataclasses.asdict(r) for r in rows], f, indent=1)
    print(f"\n{len(rows)} cells analyzed -> {out}")


if __name__ == "__main__":
    main()
