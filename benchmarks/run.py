"""Benchmark harness — one function per paper table/figure (§4-7) plus the
framework-side benchmarks (DES throughput, cluster scheduler).

Reads the persisted grid from ``paper_sweep.py`` if present (full 5000-job
workloads); otherwise runs a reduced grid inline (1200 jobs) so
``python -m benchmarks.run`` is self-contained. Each ``fig_*`` function
emits the data behind the corresponding paper figure and asserts the
paper's qualitative claim, printing PASS/FAIL — this is the §Paper-repro
validation harness.

Optional artifacts (the fault study ``paper_chaos_grid.json`` and the
streaming-controller study ``BENCH_controller.json``) get their figures
rendered when present and are SKIPPED with a regeneration hint when
absent — a fresh clone always completes the harness.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.core import (PAPER_INIT_PROPS, PAPER_SCALE_RATIOS,
                        plateau_threshold, run_baselines, run_packet_grid)
from repro.workload.lublin import WorkloadParams, generate_workload

RESULTS = os.path.join(os.path.dirname(__file__), "results")
GRID_PATH = os.path.join(RESULTS, "paper_grid.json")
CHAOS_GRID_PATH = os.path.join(RESULTS, "paper_chaos_grid.json")
CONTROLLER_PATH = os.path.join(RESULTS, "BENCH_controller.json")
KS = np.asarray(PAPER_SCALE_RATIOS)
SP = list(PAPER_INIT_PROPS)

_checks: list[tuple[str, bool, str]] = []


def check(name: str, ok: bool, detail: str = ""):
    _checks.append((name, bool(ok), detail))
    print(f"  [{'PASS' if ok else 'FAIL'}] {name}  {detail}")


def _load_grid(n_jobs=1200):
    """Persisted full grid if available, else compute a reduced one."""
    if os.path.exists(GRID_PATH):
        with open(GRID_PATH) as f:
            data = json.load(f)
        print(f"[run] using persisted grid {GRID_PATH} "
              f"({data.get('total_seconds', 0):.0f}s of simulation)")
        return data
    print(f"[run] no persisted grid; simulating reduced workloads "
          f"({n_jobs} jobs)")
    data = {"scale_ratios": list(KS), "init_props": SP, "workloads": {},
            "baselines": {}, "timing": {}}
    for load in (0.85, 0.90, 0.95):
        for homog in (True, False):
            name = f"{'homog' if homog else 'hetero'}{load:.2f}"
            wl = generate_workload(WorkloadParams(
                n_jobs=n_jobs, nodes=100 if homog else 500, load=load,
                homogeneous=homog, seed=1 if homog else 0))
            t0 = time.time()
            g = run_packet_grid(wl)
            data["timing"][name] = {"seconds": time.time() - t0,
                                    "experiments": len(KS) * len(SP)}
            data["workloads"][name] = {
                f: np.asarray(getattr(g, f)).tolist()
                for f in ("avg_wait", "med_wait", "avg_qlen", "full_util",
                          "useful_util", "n_groups", "ok")}
            bl = run_baselines(wl)
            data["baselines"][name] = {
                alg: {f: np.asarray(getattr(m, f)).tolist()
                      for f in ("avg_wait", "med_wait", "full_util",
                                "useful_util")} for alg, m in bl.items()}
            print(f"[run] simulated {name}: "
                  f"{data['timing'][name]['seconds']:.1f}s")
    return data


def _load_optional(path: str, regenerate_hint: str):
    """An optional results artifact: load it, or skip its figures.

    The zero-chaos grid has an inline reduced-scale fallback (`_load_grid`);
    the artifacts loaded here (the fault study, the controller study) are
    multi-minute-to-multi-hour runs with no sensible inline substitute, so
    a fresh clone just skips their figures instead of hard-failing the
    whole harness.
    """
    if not os.path.exists(path):
        print(f"[run] SKIP optional artifact {os.path.basename(path)} "
              f"(regenerate with: {regenerate_hint})")
        return None
    with open(path) as f:
        return json.load(f)


def _w(data, name, field):
    return np.asarray(data["workloads"][name][field])   # [k, s_prop]


def _sp_idx(p):
    return SP.index(p)


# ------------------------------------------------------------ paper figures

def fig5_queue_time_workload085_5pct(data):
    """Fig 5: avg & median queue time vs k, Workload0.85, 5% init."""
    aw = _w(data, "homog0.85", "avg_wait")[:, _sp_idx(0.05)]
    mw = _w(data, "homog0.85", "med_wait")[:, _sp_idx(0.05)]
    lo, hi = aw[KS <= 0.5].mean(), aw[KS >= 20].mean()
    check("fig5: avg queue time decreases with k", lo > hi,
          f"k<=0.5 mean {lo:.0f}s vs k>=20 mean {hi:.0f}s")
    plateau = plateau_threshold(KS, aw, rel_tol=0.10)
    # the plateau position scales with work/s (~600 for this workload;
    # ~20 for the paper's): the claim is that it EXISTS inside the grid
    check("fig5: avg wait reaches a plateau (position = work/s; "
          "paper's workloads: ~20)",
          plateau.threshold <= 700,
          f"plateau at k={plateau.threshold} (level {plateau.plateau:.0f}s)")
    decay = mw[KS >= 20].mean() / max(mw[KS <= 0.5].mean(), 1e-9)
    check("fig5: median collapses at moderate k (paper: ->0 by k=8)",
          decay < 0.25, f"median(k>=20)/median(k<=0.5)={decay:.3f}")
    return {"k": KS.tolist(), "avg": aw.tolist(), "med": mw.tolist()}


def fig6_queue_length(data):
    """Fig 6: avg queue length mirrors queue time; plateau by ~20."""
    ql = _w(data, "homog0.85", "avg_qlen")[:, _sp_idx(0.05)]
    aw = _w(data, "homog0.85", "avg_wait")[:, _sp_idx(0.05)]
    corr = np.corrcoef(ql, aw)[0, 1]
    check("fig6: queue length tracks queue time", corr > 0.9,
          f"corr={corr:.3f}")
    return {"k": KS.tolist(), "qlen": ql.tolist()}


def fig7_table1_50pct(data):
    """Fig 7 / Table 1: 50% init proportion: faster decay, med->0 by k~4."""
    aw = _w(data, "homog0.85", "avg_wait")[:, _sp_idx(0.50)]
    mw = _w(data, "homog0.85", "med_wait")[:, _sp_idx(0.50)]
    i8 = int(np.argmin(np.abs(KS - 8)))
    decay = mw[i8] / max(mw[KS <= 0.5].mean(), 1e-9)
    check("fig7: 50%-init median collapses by k=8 (paper: ~0 by k=4)",
          decay < 0.15, f"median(k=8)/median(k<=0.5)={decay:.3f} "
          f"({mw[i8]:.0f}s)")
    check("table1: low-k corner is catastrophic (1000s of seconds)",
          aw[KS <= 0.3].max() > 10 * aw[KS >= 4].mean(),
          f"max(k<=0.3)={aw[KS <= 0.3].max():.0f}s vs "
          f"mean(k>=4)={aw[KS >= 4].mean():.0f}s")
    return {"k": KS[:5].tolist(), "avg": aw[:5].tolist(),
            "med": mw[:5].tolist()}


def fig8_table2_all_props(data):
    """Fig 8 / Table 2: queue time vs k for all init proportions. The
    50%-init line starts far above the 5% line at low k (Table 2's
    catastrophic corner) and collapses toward/below it at the plateau —
    the crossover geometry of the paper's figure."""
    aw = _w(data, "homog0.85", "avg_wait")
    # the 50%-init curve reaches its plateau at much smaller k than the
    # 5% curve (paper: fig 7's median collapses by k~4 vs fig 5's k~8-20;
    # the absolute top/bottom ordering is calibration-dependent — see
    # EXPERIMENTS.md §Paper-repro)
    def k_settle(col):
        plateau = col[KS >= 300].mean()
        good = col <= 2.0 * plateau
        return float(KS[np.argmax(good)]) if good.any() else np.inf

    k50 = k_settle(aw[:, _sp_idx(0.50)])
    k05 = k_settle(aw[:, _sp_idx(0.05)])
    check("fig8: 50%-init settles at much smaller k than 5%",
          k50 <= k05 / 2.0, f"k(50%)={k50} vs k(5%)={k05}")
    hi_k = KS >= 20
    dec = all(aw[hi_k, _sp_idx(p)].mean() <= aw[KS <= 0.5, _sp_idx(p)].mean()
              for p in SP)
    check("fig8: wait decreases with k for every init proportion", dec)
    return {f"{int(p * 100)}%": aw[:, _sp_idx(p)].tolist() for p in SP}


def fig9_workload090(data):
    """Fig 9 / Table 3: medium-intensity workload, same trend."""
    aw = _w(data, "homog0.90", "avg_wait")[:, _sp_idx(0.05)]
    check("fig9: Workload0.90 trend (decrease then plateau)",
          aw[KS <= 0.5].mean() > aw[KS >= 20].mean(),
          f"{aw[KS <= 0.5].mean():.0f}s -> {aw[KS >= 20].mean():.0f}s")
    return {"k": KS.tolist(), "avg": aw.tolist()}


def fig10_intensity(data):
    """Fig 10: higher load -> higher absolute queue time, same shape."""
    m = {ld: _w(data, f"homog{ld:.2f}", "avg_wait")[:, _sp_idx(0.05)]
         for ld in (0.85, 0.90, 0.95)}
    at_plateau = {ld: v[KS >= 50].mean() for ld, v in m.items()}
    check("fig10: queue time rises with workload intensity",
          at_plateau[0.85] <= at_plateau[0.90] * 1.5 and
          at_plateau[0.90] <= at_plateau[0.95] * 1.5,
          " ".join(f"{ld}:{v:.0f}s" for ld, v in at_plateau.items()))
    for ld, v in m.items():
        res = plateau_threshold(KS, v, rel_tol=0.10)
        check(f"fig10: load {ld} also plateaus",
              res.threshold <= 700,
              f"k={res.threshold} (level {res.plateau:.0f}s)")
    return {str(ld): v.tolist() for ld, v in m.items()}


def fig11_full_utilization(data):
    """Fig 11: full utilization decreases as k increases."""
    fu = _w(data, "homog0.85", "full_util")
    drops = sum(fu[KS <= 0.5, i].mean() >= fu[KS >= 20, i].mean() - 0.02
                for i in range(len(SP)))
    check("fig11: full utilization decreases with k (all props)",
          drops == len(SP), f"{drops}/{len(SP)} proportions")
    return {f"{int(p * 100)}%": fu[:, _sp_idx(p)].tolist() for p in SP}


def fig12_full_util_intensity(data):
    """Fig 12: full utilization vs k for the 3 intensities at 5%."""
    out = {}
    for ld in (0.85, 0.90, 0.95):
        fu = _w(data, f"homog{ld:.2f}", "full_util")[:, _sp_idx(0.05)]
        out[str(ld)] = fu.tolist()
        check(f"fig12: load {ld}: low-k util >= high-k util",
              fu[KS <= 0.5].mean() >= fu[KS >= 50].mean() - 0.02,
              f"{fu[KS <= 0.5].mean():.3f} vs {fu[KS >= 50].mean():.3f}")
    return out


def fig13_14_useful_utilization(data):
    """Figs 13-14: useful utilization ~flat in k (within noise)."""
    for ld in (0.85, 0.90, 0.95):
        uu = _w(data, f"homog{ld:.2f}", "useful_util")[:, _sp_idx(0.05)]
        spread = uu[KS >= 0.4].max() - uu[KS >= 0.4].min()
        check(f"fig13/14: useful util ~flat for load {ld}", spread < 0.15,
              f"spread={spread:.3f} (mean {uu.mean():.3f})")
    uu = _w(data, "homog0.85", "useful_util")
    return {f"{int(p * 100)}%": uu[:, _sp_idx(p)].tolist() for p in SP}


def homogeneity_invariance(data):
    """Conclusion §8: intensity/homogeneity shift absolute values, not the
    shape of the k-dependence."""
    for ld in (0.85, 0.90):
        a = _w(data, f"homog{ld:.2f}", "avg_wait")[:, _sp_idx(0.05)]
        b = _w(data, f"hetero{ld:.2f}", "avg_wait")[:, _sp_idx(0.05)]
        ra = a[KS >= 20].mean() / max(a[KS <= 0.5].mean(), 1e-9)
        rb = b[KS >= 20].mean() / max(b[KS <= 0.5].mean(), 1e-9)
        check(f"conclusion: k-shape invariant to homogeneity (load {ld})",
              ra < 1.0 and rb < 1.0, f"decay homog {ra:.2f} hetero {rb:.2f}")
    return {}


def scale_ratio_50_no_effect(data):
    """§6: 'scale ratio over 50 does not influence the metrics' — above
    the threshold where every group's m hits 1, k is exactly inert. The
    threshold position is work/s (workload-dependent): the paper's
    workloads freeze by 50; ours at 50% init freeze by 300, and at 5%
    init (work/s ~ 600) the tail varies only within noise."""
    worst_frozen = 0.0
    for name in data["workloads"]:
        if not name.startswith("homog"):
            continue          # hetero work/s ratios exceed the k grid
        aw = _w(data, name, "avg_wait")
        hi = aw[KS >= 300]
        # >= 40% init: every group's m has hit 1: k is exactly inert
        for i, p in enumerate(SP):
            if p >= 0.40:
                rng = (hi[:, i].max() - hi[:, i].min()) / \
                    max(hi[:, i].mean(), 60.0)
                worst_frozen = max(worst_frozen, float(rng))
    check("k above work/s is exactly inert (homog, >=40% init, k>=300)",
          worst_frozen < 0.001, f"max relative range {worst_frozen:.5f}")
    return {}


def grouping_vs_backfill(data):
    """Predecessor-paper sanity: at high init proportion, Packet beats the
    rigid FCFS/backfill baselines on useful utilization."""
    name = "homog0.90"
    uu = _w(data, name, "useful_util")[:, _sp_idx(0.50)][KS >= 4].mean()
    bl = data["baselines"][name]["backfill"]["useful_util"][_sp_idx(0.50)]
    check("packet beats backfill on useful util @50% init", uu > bl,
          f"packet {uu:.3f} vs backfill {bl:.3f}")
    return {"packet": float(uu), "backfill": float(bl)}


# -------------------------------------------------- optional-artifact figs

def fig_scale_ratio_vs_faults(chaos):
    """Chaos study (ROADMAP follow-through): how the avg_wait-optimal k and
    its 5% plateau move with MTBF / checkpoint cadence / straggler factor.

    Writeup of the committed 5000-job study (regen: paper_sweep.py --chaos):
    faults move the *cost of the valley floor*, not the tuning
    recommendation. Halving-MTBF-to-50h roughly triples the best
    achievable wait on heterogeneous flows (e.g. hetero0.85: ~214s vs
    ~67s at 200h) because lost work and requeues queue behind everything
    else — but the optimal k itself stays deep in the high-k plateau for
    every fault cell, and the 5% plateaus of all 8 cells overlap for at
    least one init proportion per workload. Operationally: pick k from
    the zero-chaos sweep and keep it; provision for faults via capacity,
    not retuning. (The checks below assert exactly that geometry, loosely
    enough to hold for a smoke-scale regeneration.)
    """
    fig = chaos.get("figure_scale_ratio_vs_faults")
    if not fig:
        check("chaos-fig: figure block present", False,
              "paper_chaos_grid.json has no figure_scale_ratio_vs_faults")
        return {}
    mtbf = np.asarray(fig["mtbf_chip_hours"])    # [C] cell axes
    ckpt = np.asarray(fig["ckpt_period"])
    out = {"mtbf_chip_hours": mtbf.tolist(), "ckpt_period": ckpt.tolist(),
           "straggler_factor": fig["straggler_factor"],
           "plateau_rtol": fig["plateau_rtol"], "workloads": {}}
    lo_mtbf, hi_mtbf = mtbf == mtbf.min(), mtbf == mtbf.max()
    ordered, corner, costlier, robust = True, True, True, True
    for name, w in fig["workloads"].items():
        best_k = np.asarray(w["best_k"])         # [init_prop, cell]
        best_w = np.asarray(w["best_avg_wait"])
        k_lo = np.asarray(w["plateau_k_lo"])
        k_hi = np.asarray(w["plateau_k_hi"])
        ordered &= bool(np.all((k_lo <= best_k) & (best_k <= k_hi)))
        corner &= bool(best_k.min() >= 4.0)
        costlier &= bool(best_w[:, lo_mtbf].mean()
                         >= 0.95 * best_w[:, hi_mtbf].mean())
        # the tuning recommendation survives every fault cell: some init
        # proportion has one k inside all 8 cells' 5% plateaus
        common = (k_lo.max(axis=1) <= k_hi.min(axis=1))
        robust &= bool(common.any())
        out["workloads"][name] = {
            "best_k": best_k.tolist(), "best_avg_wait": best_w.tolist(),
            "plateau_k_lo": k_lo.tolist(), "plateau_k_hi": k_hi.tolist(),
            "wait_ratio_mtbf_lo_over_hi": float(
                best_w[:, lo_mtbf].mean() / max(best_w[:, hi_mtbf].mean(),
                                                1e-9)),
            "common_plateau_props": [float(p) for p, c in
                                     zip(chaos["init_props"], common) if c],
        }
    check("chaos-fig: plateau brackets the optimum (lo <= k* <= hi)",
          ordered)
    check("chaos-fig: k* never driven into the low-k corner by faults",
          corner, f"min k* = "
          f"{min(np.asarray(w['best_k']).min() for w in fig['workloads'].values()):g}")
    check("chaos-fig: shorter MTBF raises the valley-floor wait", costlier,
          " ".join(f"{n}:{v['wait_ratio_mtbf_lo_over_hi']:.2f}x"
                   for n, v in out["workloads"].items()))
    check("chaos-fig: a common 5% plateau spans all fault cells "
          "(some init prop, every workload)", robust)
    return out


def fig_controller_regret(ctl):
    """Streaming-service study: controller regret vs. hindsight oracles
    per drift scenario (regen: benchmarks/controller_sweep.py)."""
    scen = ctl.get("scenarios", {})
    if not scen:
        check("controller-fig: scenarios present", False,
              "BENCH_controller.json has no scenarios")
        return {}
    out = {name: {c: {k: s["controllers"][c][k] for k in
                      ("switches", "rel_regret_wait", "mean_regret_useful",
                       "mean_wait_vs_plateau")}
                  for c in s["controllers"]} for name, s in scen.items()}
    nonneg = all(s["controllers"][c]["mean_regret_wait"] >= -1e-9
                 for s in scen.values() for c in s["controllers"])
    check("controller-fig: regret vs per-tick optimum is >= 0", nonneg)
    hyst = sum(s["controllers"]["hysteresis"]["switches"]
               for s in scen.values())
    naive = sum(s["controllers"]["naive"]["switches"] for s in scen.values())
    check("controller-fig: hysteresis switches less than naive arg-best",
          hyst < naive, f"{hyst} vs {naive} switches")
    if "steady" in scen:
        r = scen["steady"]["controllers"]["hysteresis"]["rel_regret_wait"]
        check("controller-fig: zero-drift regret ~ 0", r <= 0.10,
              f"steady rel_regret_wait={r:.4f}")
    chaos = ctl.get("chaos")
    if chaos:    # regret-under-faults block (regen: controller_sweep --chaos)
        cs = chaos["scenarios"]
        lost = {c: sum(s["controllers"][c]["total_lost_work"]
                       for s in cs.values())
                for c in next(iter(cs.values()))["controllers"]}
        regret = {c: sum(s["controllers"][c]["total_regret_wait"]
                         for s in cs.values()) for c in lost}
        check("controller-fig: fault-aware loses no more work than "
              "fault-blind hysteresis",
              lost["fault_aware"] <= lost["hysteresis"] + 1e-9,
              " ".join(f"{c}:{v:.0f}" for c, v in lost.items()))
        check("controller-fig: fault-aware wait regret within 1.1x of "
              "fault-blind",
              regret["fault_aware"] <= regret["hysteresis"] * 1.1 + 1e-6,
              " ".join(f"{c}:{v:.0f}s" for c, v in regret.items()))
        proof = chaos["degrade_proof"]
        check("controller-fig: degrade-mode service completes every tick "
              "under injected faults",
              proof["completed_all_ticks"],
              f"{proof['n_ticks']}/{proof['n_expected_ticks']} ticks, "
              f"{proof['n_degraded_ticks']} degraded")
        out["chaos"] = {"total_lost_work": lost,
                        "total_regret_wait": regret,
                        "degrade_proof_ok": proof["completed_all_ticks"]}
    return out


# ------------------------------------------------------- framework benches

def bench_des_throughput():
    """DES speed: the paper's Alea takes 'dozens of minutes' per experiment;
    the vmapped XLA DES target is milliseconds."""
    import jax
    from repro.core.des import pack_workload, simulate_packet
    wl = generate_workload(WorkloadParams(n_jobs=1200, nodes=100, load=0.9,
                                          homogeneous=True, seed=1))
    pw = pack_workload(wl)
    s = wl.init_time_for_proportion(0.05)
    f = jax.jit(lambda k: simulate_packet(pw, k, s, wl.params.nodes).ok)
    f(1.0).block_until_ready()                        # compile
    t0 = time.time()
    n = 20
    for k in np.linspace(0.5, 50, n):
        f(float(k)).block_until_ready()
    dt = (time.time() - t0) / n
    print(f"  [bench] DES: {dt * 1e3:.0f} ms/experiment (1200 jobs) — "
          f"paper's Alea: dozens of minutes for 5000")
    return {"ms_per_experiment_1200jobs": dt * 1e3}


def bench_cluster_sim():
    from repro.cluster import ClusterConfig, ClusterSim, JobType
    from repro.cluster.scheduler import workload_from_arrival_rate
    types = [JobType(f"arch{i}:train", init_time=120.0 + 60 * i,
                     tp_degree=16) for i in range(4)]
    t0 = time.time()
    sim = ClusterSim(types, ClusterConfig(n_chips=1024, scale_ratio=4.0,
                                          mtbf_chip_hours=80.0,
                                          straggler_prob=0.05))
    for j in workload_from_arrival_rate(types, 400, 6 * 3600, 64 * 900.0):
        sim.submit(j)
    m = sim.run()
    print(f"  [bench] cluster sim: 400 jobs, {m['groups']} groups, "
          f"useful_util={m['useful_util']:.3f}, "
          f"failures={m['failures']}, {time.time() - t0:.2f}s")
    return m


FIGS = [fig5_queue_time_workload085_5pct, fig6_queue_length,
        fig7_table1_50pct, fig8_table2_all_props, fig9_workload090,
        fig10_intensity, fig11_full_utilization, fig12_full_util_intensity,
        fig13_14_useful_utilization, homogeneity_invariance,
        scale_ratio_50_no_effect, grouping_vs_backfill]


def main():
    os.makedirs(RESULTS, exist_ok=True)
    data = _load_grid()
    out = {}
    for fig in FIGS:
        print(f"[run] {fig.__name__}: {fig.__doc__.splitlines()[0]}")
        out[fig.__name__] = fig(data)
    for fig, path, hint in (
            (fig_scale_ratio_vs_faults, CHAOS_GRID_PATH,
             "PYTHONPATH=src python benchmarks/paper_sweep.py --chaos"),
            (fig_controller_regret, CONTROLLER_PATH,
             "PYTHONPATH=src python benchmarks/controller_sweep.py "
             "--chaos")):
        artifact = _load_optional(path, hint)
        if artifact is not None:
            print(f"[run] {fig.__name__}: {fig.__doc__.splitlines()[0]}")
            out[fig.__name__] = fig(artifact)
    out["bench_des"] = bench_des_throughput()
    out["bench_cluster"] = bench_cluster_sim()
    with open(os.path.join(RESULTS, "figures.json"), "w") as f:
        json.dump(out, f, indent=1)
    n_pass = sum(1 for _, ok, _ in _checks if ok)
    print(f"\n[run] paper-repro checks: {n_pass}/{len(_checks)} PASS")
    for name, ok, detail in _checks:
        if not ok:
            print(f"  FAILED: {name} {detail}")
    return 0 if n_pass == len(_checks) else 1


if __name__ == "__main__":
    raise SystemExit(main())
