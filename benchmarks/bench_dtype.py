"""Float32-vs-float64 tolerance study over the paper's experiment grid.

For each paper workflow the full 37 x 6 (scale ratio x init proportion)
Packet grid — plus both rigid baselines — is run twice through the dtype-
parametric sweep engine: once in the default float32 and once in float64
under the scoped `repro.core.precision` opt-in. The float64 run is the
reference; per metric we record the max/mean relative deviation of float32
(and where on the grid the max occurs), plus the number of cells whose
integer group count diverged — the signature of a *decision* flip (a
near-tie in queue weights or event order resolving differently), as opposed
to mere accumulator rounding.

Two regimes emerge (paper-scale numbers in the checked-in JSON):

  * **homogeneous flows / FCFS** stay at accumulator-rounding level
    (~1e-6 .. 1e-2 relative), with at most a few decision flips per grid;
  * **heterogeneous 5000-job flows are float32-chaotic**: ~78-83% of Packet
    cells resolve near-ties differently and the schedules diverge wholesale
    (EASY backfill flips too, up to ~25% on avg_wait). For per-cell metric
    work on long-horizon heterogeneous workloads the float64 opt-in is the
    validated reference, not a luxury.

Because of the second regime, the regression tolerances are NOT derived
from paper-scale deviations: the study additionally runs the golden-scale
workload pair (the spec checked into ``tests/golden/golden_metrics.json``)
over the same 37 x 6 grid, and ``suggested_float32_rtol`` = 10x the worst
rounding-only (same-schedule) deviation measured *at that scale*. The
persisted ``benchmarks/results/BENCH_dtype.json`` is the provenance for

  * the float32 tolerances used by the golden-metrics regression suite
    (``tests/test_golden_metrics.py`` reads ``suggested_float32_rtol``),
  * the per-workload float32 reliability summary (flip fractions), and
  * the deviation figures quoted in the `repro.core.des` / `repro.core.sweep`
    module docstrings.

Usage:
    python -m benchmarks.bench_dtype              # paper scale (5000 jobs)
    python -m benchmarks.bench_dtype --smoke      # reduced, CI-budget
    python -m benchmarks.bench_dtype --n-jobs 800 # custom job count
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import run_baselines, run_packet_grid
from repro.core.metrics import METRIC_REL_FLOORS, SCALAR_METRIC_FIELDS
from repro.core.sweep import PAPER_INIT_PROPS, PAPER_SCALE_RATIOS
from repro.workload.lublin import generate_workload, paper_workloads

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
BENCH_PATH = os.path.join(RESULTS_DIR, "BENCH_dtype.json")
# Smoke runs land elsewhere so they can never clobber the checked-in
# paper-scale artifact that tests/test_golden_metrics.py derives its
# tolerances from.
BENCH_SMOKE_PATH = os.path.join(RESULTS_DIR, "BENCH_dtype_smoke.json")
GOLDEN_SPEC_PATH = os.path.join(os.path.dirname(__file__), "..", "tests",
                                "golden", "golden_metrics.json")

# Fallback golden-scale spec, kept in sync with tests/test_golden_metrics.py
# (the checked-in golden file's "spec" block is the authority when present).
DEFAULT_GOLDEN_SPEC = {
    "hetero": dict(n_jobs=200, nodes=96, load=0.9, homogeneous=False,
                   seed=17),
    "homog": dict(n_jobs=200, nodes=48, load=0.9, homogeneous=True,
                  seed=18, daily_amplitude=0.3),
}


def golden_scale_workloads() -> dict:
    """The golden-suite workload pair, at golden (not paper) job count."""
    from repro.workload.lublin import WorkloadParams
    spec = dict(DEFAULT_GOLDEN_SPEC)
    if os.path.exists(GOLDEN_SPEC_PATH):
        with open(GOLDEN_SPEC_PATH) as f:
            spec = json.load(f)["spec"]["workloads"]
    return {f"golden_{name}": generate_workload(WorkloadParams(**params))
            for name, params in spec.items()}

# Shared with tests/test_golden_metrics.py via repro.core.metrics so the
# floors under measured deviations and enforced tolerances never drift:
# relative deviations are measured against max(|float64|, floor), the floor
# keeping near-zero cells (e.g. median wait at huge k) from reading as
# divergence when the absolute error is physically negligible.
METRIC_FIELDS = SCALAR_METRIC_FIELDS
ABS_FLOORS = METRIC_REL_FLOORS


def _deviation(f32, f64, field, mask=None):
    """Max/mean relative deviation of float32 from the float64 reference.

    `mask` (optional, bool per cell) restricts the statistics to cells whose
    *discrete schedule agreed* between dtypes (equal group counts). Off-mask
    cells sit on a decision boundary — a near-tie in queue weights resolved
    differently by the two precisions — where metrics differ by O(1), not by
    rounding; they are counted separately, not folded into the tolerance.
    """
    a = np.asarray(f32, np.float64)
    b = np.asarray(f64, np.float64)
    rel = np.abs(a - b) / np.maximum(np.abs(b), ABS_FLOORS[field])
    flat = int(np.argmax(rel))
    out = {
        "max_rel": float(rel.max()),
        "mean_rel": float(rel.mean()),
        "max_abs": float(np.abs(a - b).max()),
        "argmax_cell": [int(i) for i in np.unravel_index(flat, rel.shape)],
    }
    if mask is not None:
        sel = rel[mask]
        out["max_rel_same_schedule"] = float(sel.max()) if sel.size else 0.0
    return out


def study_workload(wl, ks, s_props) -> dict:
    """Dual-dtype Packet grid + baselines for one workload."""
    x64_before = jax.config.jax_enable_x64
    t0 = time.perf_counter()
    g32 = run_packet_grid(wl, ks=ks, s_props=s_props, dtype=jnp.float32)
    t32 = time.perf_counter() - t0
    t0 = time.perf_counter()
    g64 = run_packet_grid(wl, ks=ks, s_props=s_props, dtype=jnp.float64)
    t64 = time.perf_counter() - t0
    assert jax.config.jax_enable_x64 == x64_before, \
        "dtype_scope changed the session's x64 state"
    assert np.asarray(g32.ok).all() and np.asarray(g64.ok).all()

    ng32, ng64 = np.asarray(g32.n_groups), np.asarray(g64.n_groups)
    same_schedule = ng32 == ng64
    out = {"packet": {f: _deviation(getattr(g32, f), getattr(g64, f), f,
                                    mask=same_schedule)
                      for f in METRIC_FIELDS}}
    out["packet"]["n_group_mismatch_cells"] = int((~same_schedule).sum())
    out["packet"]["cells"] = int(ng32.size)

    b32 = run_baselines(wl, s_props=s_props, dtype=jnp.float32)
    b64 = run_baselines(wl, s_props=s_props, dtype=jnp.float64)
    for alg in ("fcfs", "backfill"):
        out[alg] = {f: _deviation(getattr(b32[alg], f), getattr(b64[alg], f), f)
                    for f in METRIC_FIELDS}
    out["seconds_float32"] = t32
    out["seconds_float64"] = t64
    return out


def aggregate(per_workload: dict) -> dict:
    """Global max relative deviation per metric across workloads/algorithms.

    `max_rel` includes decision-flip cells; `max_rel_same_schedule` is the
    rounding-only Packet deviation (cells restricted to equal group counts).
    The baselines carry no flip mask (their group count is always N), so
    their flip-inclusive worst case is reported separately as
    `max_rel_baselines` rather than silently folded into the same-schedule
    number.
    """
    agg = {}
    for f in METRIC_FIELDS:
        worst, where, worst_same, worst_bl = 0.0, None, 0.0, 0.0
        for name, res in per_workload.items():
            for alg in ("packet", "fcfs", "backfill"):
                v = res[alg][f]["max_rel"]
                if v >= worst:
                    worst, where = v, f"{name}/{alg}"
                if alg == "packet":
                    worst_same = max(worst_same,
                                     res[alg][f]["max_rel_same_schedule"])
                else:
                    worst_bl = max(worst_bl, v)
        agg[f] = {"max_rel": worst, "worst_case": where,
                  "max_rel_same_schedule": worst_same,
                  "max_rel_baselines": worst_bl}
    return agg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="2 workloads at reduced job count (CI budget)")
    ap.add_argument("--n-jobs", type=int, default=None,
                    help="override job count per workload (default: paper's "
                         "5000; --smoke uses 600)")
    args = ap.parse_args(argv)

    flows = paper_workloads(seed=0)
    if args.smoke:
        flows = {k: flows[k] for k in ("hetero0.90", "homog0.90")}
    n_jobs = args.n_jobs or (600 if args.smoke else None)
    if n_jobs is not None:
        flows = {name: generate_workload(dataclasses.replace(
            wl.params, n_jobs=n_jobs)) for name, wl in flows.items()}

    golden_flows = golden_scale_workloads()
    ks, s_props = PAPER_SCALE_RATIOS, PAPER_INIT_PROPS
    t_start = time.perf_counter()
    per_workload, golden_scale = {}, {}
    for name, wl in {**flows, **golden_flows}.items():
        res = study_workload(wl, ks, s_props)
        (golden_scale if name in golden_flows else per_workload)[name] = res
        worst = max(res["packet"][f]["max_rel_same_schedule"]
                    for f in METRIC_FIELDS)
        print(f"[bench_dtype] {name}: {res['packet']['cells']} cells, "
              f"packet max rel dev (same schedule) {worst:.2e}, "
              f"n_group mismatches {res['packet']['n_group_mismatch_cells']}, "
              f"f32 {res['seconds_float32']:.1f}s / "
              f"f64 {res['seconds_float64']:.1f}s", flush=True)

    agg = aggregate(per_workload)
    agg_golden = aggregate(golden_scale)
    out = {
        "bench": "dtype_float32_vs_float64",
        "smoke": bool(args.smoke),
        "n_jobs": n_jobs or 5000,
        "grid": {"scale_ratios": len(ks), "init_props": len(s_props)},
        "workloads": sorted(per_workload),
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "unix_time": time.time(),
        "per_workload": per_workload,
        "golden_scale": golden_scale,
        "aggregate_max_rel": agg,
        "golden_scale_max_rel": agg_golden,
        # Fraction of Packet cells whose float32 schedule diverged from
        # float64 — the "is float32 even the same experiment?" signal.
        # Heterogeneous 5000-job flows are expected to be chaotic here; see
        # module docstring.
        "float32_schedule_flip_fraction": {
            name: res["packet"]["n_group_mismatch_cells"]
            / res["packet"]["cells"]
            for name, res in {**per_workload, **golden_scale}.items()},
        # Regression-suite bound: 10x headroom over the worst AT-GOLDEN-SCALE
        # deviation — Packet restricted to same-schedule cells (paper-scale
        # hetero flips are a precision finding, not a tolerance), baselines
        # at their flip-inclusive worst (no mask exists; a golden-scale
        # baseline flip would push the suggestion past the golden suite's
        # test_tolerances_are_meaningful cap and fail loudly rather than
        # widen the allowance silently). Floored at 1e-6 (float32 eps is
        # ~1.2e-7).
        "suggested_float32_rtol": {
            f: float(max(agg_golden[f]["max_rel_same_schedule"] * 10.0,
                         agg_golden[f]["max_rel_baselines"] * 10.0,
                         1e-6))
            for f in METRIC_FIELDS},
        "total_seconds": time.perf_counter() - t_start,
    }
    # only a true paper-scale run (no --smoke, no --n-jobs override) may
    # replace the checked-in artifact that the golden suite reads
    paper_scale = not args.smoke and n_jobs is None
    bench_path = BENCH_PATH if paper_scale else BENCH_SMOKE_PATH
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(bench_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[bench_dtype] paper-scale aggregate max rel dev: " +
          ", ".join(f"{k}={v['max_rel']:.2e}" for k, v in agg.items()))
    print(f"[bench_dtype] golden-scale same-schedule max rel dev: " +
          ", ".join(f"{k}={v['max_rel_same_schedule']:.2e}"
                    for k, v in agg_golden.items()))
    print(f"[bench_dtype] suggested float32 rtol: " +
          ", ".join(f"{k}={v:.2e}"
                    for k, v in out['suggested_float32_rtol'].items()))
    print(f"[bench_dtype] wrote {bench_path} "
          f"({out['total_seconds']:.1f}s total)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
