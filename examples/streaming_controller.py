"""Streaming scale-ratio controller: "what k right now", not "what k was best".

Plays a drifting workload (intensity step: the cluster's offered load
jumps mid-trace) through the closed-loop service (`repro.service`): each
control tick the fused lane oracle evaluates every candidate k on the
most recent job window, and the plateau-aware hysteresis controller
decides whether the committed k should move. A naive every-tick arg-best
controller runs beside it on the same oracle curves — watch it thrash
between near-tied plateau members while hysteresis holds still.

The second act goes fault-aware: the same trace re-runs with a 3-cell
`ChaosConfig` axis (harsh / moderate / calm fault regimes), the harsh
cell playing the true environment. Each tick the oracle returns [K, C]
curves, the fault-regime estimator maps realized failure telemetry onto
regime weights, and `FaultAwareController` commits against the
wait + λ·lost-work cost — watch its regime weights lock onto the harsh
cell and its lost work undercut the fault-blind hysteresis.

Run:  PYTHONPATH=src python examples/streaming_controller.py
"""
import numpy as np

from repro.core.des import ChaosConfig
from repro.service import ServiceConfig, run_service
from repro.workload import WorkloadParams, drift_workload


def main():
    # 8 segments; the offered load steps 0.85 -> 0.95 halfway through
    base = WorkloadParams(n_jobs=2000, nodes=100, homogeneous=True,
                          seed=0, daily_amplitude=0.3)
    wl = drift_workload(base, loads=[0.85] * 4 + [0.95] * 4)
    config = ServiceConfig(window_jobs=250, stride_jobs=125)
    out = run_service(wl, config)

    print(f"{out['n_ticks']} control ticks of {config.window_jobs} jobs "
          f"(stride {config.stride_jobs}); oracle: {len(config.ks)} "
          f"candidate k's per tick, one fused lane program")
    print(f"{'tick':>4} {'jobs':>11} {'best k':>7} {'plateau k':>9} "
          f"{'hysteresis':>10} {'naive':>7}  note")
    for t in out["ticks"]:
        h = t["controllers"]["hysteresis"]
        n = t["controllers"]["naive"]
        note = h["reason"] if h["moved"] else ""
        print(f"{t['tick']:>4} {t['window'][0]:>5}-{t['window'][1]:<5} "
              f"{t['best_k']:>7g} {t['plateau_k']:>9g} "
              f"{h['realized_k']:>10g} {n['realized_k']:>7g}  {note}")

    print("\ncontroller scorecard (vs the per-tick hindsight optimum):")
    for name, s in out["controllers"].items():
        print(f"  {name:10s} switches={s['switches']:2d}  "
              f"rel_regret_wait={s['rel_regret_wait']:.4f}  "
              f"vs offline plateau rule: {s['mean_wait_vs_plateau']:+.1f}s/tick")
    h, n = out["controllers"]["hysteresis"], out["controllers"]["naive"]
    assert h["switches"] <= n["switches"], "hysteresis must switch less"
    print("\nfirst tick compiles the oracle; later ticks reuse the jit "
          "cache:", " ".join(f"{ms:.0f}ms" for ms in
                             out["oracle"]["oracle_ms"][:5]), "...")

    # --- act two: the same trace under faults, risk-aware vs. fault-blind
    chaos = ChaosConfig(mtbf_chip_hours=np.array([25.0, 100.0, 800.0]),
                        ckpt_period=300.0, straggler_prob=0.1,
                        straggler_factor=np.array([4.0, 1.5, 1.5]), seed=11)
    fa_config = ServiceConfig(window_jobs=250, stride_jobs=125,
                              chaos=chaos, chaos_env_cell=0, risk_lambda=1.0)
    out = run_service(wl, fa_config)

    print(f"\nfault-aware rerun: {fa_config.n_chaos_cells}-cell chaos axis "
          f"(MTBF 25/100/800 chip-hours), env = harsh cell 0, "
          f"λ={fa_config.risk_lambda:g} wait-s per machine-s lost")
    print(f"{'tick':>4} {'fault-aware k':>13} {'blind k':>8} "
          f"{'regime weights (harsh/mod/calm)':>32}")
    for t in out["ticks"]:
        fa = t["controllers"]["fault_aware"]
        fb = t["controllers"]["hysteresis"]
        w = " ".join(f"{x:.2f}" for x in fa["weights"])
        print(f"{t['tick']:>4} {fa['realized_k']:>13g} "
              f"{fb['realized_k']:>8g} {w:>32}")

    print("\nfault scorecard (realized in the harsh environment cell):")
    for name, s in out["controllers"].items():
        print(f"  {name:12s} rel_regret_wait={s['rel_regret_wait']:.4f}  "
              f"lost_work={s['total_lost_work']:8.0f} machine-s")
    fa = out["controllers"]["fault_aware"]
    fb = out["controllers"]["hysteresis"]
    assert fa["total_lost_work"] <= fb["total_lost_work"], \
        "the λ·lost term must not lose MORE work than fault-blind"
    last = out["ticks"][-1]["controllers"]["fault_aware"]["weights"]
    print(f"\nestimator regime weights settled on "
          f"{['harsh', 'moderate', 'calm'][int(np.argmax(last))]} "
          f"(true environment: harsh)")


if __name__ == "__main__":
    main()
