"""Streaming scale-ratio controller: "what k right now", not "what k was best".

Plays a drifting workload (intensity step: the cluster's offered load
jumps mid-trace) through the closed-loop service (`repro.service`): each
control tick the fused lane oracle evaluates every candidate k on the
most recent job window, and the plateau-aware hysteresis controller
decides whether the committed k should move. A naive every-tick arg-best
controller runs beside it on the same oracle curves — watch it thrash
between near-tied plateau members while hysteresis holds still.

Run:  PYTHONPATH=src python examples/streaming_controller.py
"""
import numpy as np

from repro.service import ServiceConfig, run_service
from repro.workload import WorkloadParams, drift_workload


def main():
    # 8 segments; the offered load steps 0.85 -> 0.95 halfway through
    base = WorkloadParams(n_jobs=2000, nodes=100, homogeneous=True,
                          seed=0, daily_amplitude=0.3)
    wl = drift_workload(base, loads=[0.85] * 4 + [0.95] * 4)
    config = ServiceConfig(window_jobs=250, stride_jobs=125)
    out = run_service(wl, config)

    print(f"{out['n_ticks']} control ticks of {config.window_jobs} jobs "
          f"(stride {config.stride_jobs}); oracle: {len(config.ks)} "
          f"candidate k's per tick, one fused lane program")
    print(f"{'tick':>4} {'jobs':>11} {'best k':>7} {'plateau k':>9} "
          f"{'hysteresis':>10} {'naive':>7}  note")
    for t in out["ticks"]:
        h = t["controllers"]["hysteresis"]
        n = t["controllers"]["naive"]
        note = h["reason"] if h["moved"] else ""
        print(f"{t['tick']:>4} {t['window'][0]:>5}-{t['window'][1]:<5} "
              f"{t['best_k']:>7g} {t['plateau_k']:>9g} "
              f"{h['realized_k']:>10g} {n['realized_k']:>7g}  {note}")

    print("\ncontroller scorecard (vs the per-tick hindsight optimum):")
    for name, s in out["controllers"].items():
        print(f"  {name:10s} switches={s['switches']:2d}  "
              f"rel_regret_wait={s['rel_regret_wait']:.4f}  "
              f"vs offline plateau rule: {s['mean_wait_vs_plateau']:+.1f}s/tick")
    h, n = out["controllers"]["hysteresis"], out["controllers"]["naive"]
    assert h["switches"] <= n["switches"], "hysteresis must switch less"
    print("\nfirst tick compiles the oracle; later ticks reuse the jit "
          "cache:", " ".join(f"{ms:.0f}ms" for ms in
                             out["oracle"]["oracle_ms"][:5]), "...")


if __name__ == "__main__":
    main()
