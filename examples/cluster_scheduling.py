"""The paper's technique as an ML-cluster feature: Packet scheduling of
training jobs whose initialization = XLA compile + checkpoint restore.

Sweeps the scale ratio for a 1024-chip cluster running a mix of
(arch x shape) job types — with chip failures and stragglers enabled —
and prints the same trade-off the paper measures for HPC jobs, plus the
fault-tolerance accounting.

  PYTHONPATH=src python examples/cluster_scheduling.py
"""
import numpy as np

from repro.cluster import ClusterConfig, ClusterSim, JobType
from repro.cluster.scheduler import workload_from_arrival_rate

# job types: initialization = measured compile+restore time per arch cell
TYPES = [
    JobType("granite-3-2b:train_4k", init_time=90.0, tp_degree=16),
    JobType("yi-6b:train_4k", init_time=150.0, tp_degree=16),
    JobType("qwen2-moe-a2.7b:train_4k", init_time=240.0, tp_degree=16),
    JobType("arctic-480b:eval", init_time=600.0, tp_degree=64),
]

JOBS = 300
HORIZON = 6 * 3600.0
MEAN_WORK = 64 * 900.0          # chip-seconds per job

print(f"{'k':>6} | {'avg wait':>9} {'med wait':>9} {'groups':>6} "
      f"{'full util':>9} {'useful':>7} {'fails':>5} {'lost chip-h':>11}")
for k in (0.25, 0.5, 1, 2, 4, 8, 16, 64):
    sim = ClusterSim(TYPES, ClusterConfig(
        n_chips=1024, scale_ratio=k, ckpt_period=300.0,
        mtbf_chip_hours=200.0, straggler_prob=0.03, seed=7))
    for j in workload_from_arrival_rate(TYPES, JOBS, HORIZON, MEAN_WORK,
                                        seed=7):
        sim.submit(j)
    m = sim.run()
    assert m["unfinished"] == 0
    print(f"{k:6.2f} | {m['avg_wait']:9.1f} {m['med_wait']:9.1f} "
          f"{m['groups']:6d} {m['full_util']:9.3f} {m['useful_util']:7.3f} "
          f"{m['failures']:5d} {m['lost_chip_seconds'] / 3600:11.1f}")

print("\nsame trade-off as the paper's Figs 5/11: larger k amortizes "
      "compile/restore\n(useful fraction up) but concentrates jobs on "
      "fewer chips (queue time at low k\nexplodes when init dominates; "
      "full utilization falls as k grows).")
