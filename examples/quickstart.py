"""Quickstart: the paper in ~40 lines.

Generates a Lublin-Feitelson workload, runs the Packet-algorithm DES over a
scale-ratio sweep on the XLA backend, and prints the queue-time /
utilization trade-off plus the plateau threshold — the number the paper's
method hands a JMS administrator.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import plateau_threshold, run_packet_grid
from repro.workload.lublin import WorkloadParams, generate_workload

# the paper's homogeneous under-loaded workflow, reduced to 1500 jobs
wl = generate_workload(WorkloadParams(
    n_jobs=1500, nodes=100, load=0.85, homogeneous=True, seed=1))
print(f"workload: {wl.n_jobs} jobs over {wl.horizon / 86400:.1f} days, "
      f"calculated load {wl.calculated_load():.2f}, M={wl.params.nodes}")

ks = [0.1, 0.3, 0.5, 1, 2, 4, 8, 20, 50, 200]
grid = run_packet_grid(wl, ks=ks, s_props=[0.05, 0.50])

print(f"\n{'k':>6} | {'avg wait (5%)':>13} {'med wait':>9} "
      f"{'full util':>9} {'useful':>7} | {'avg wait (50%)':>14}")
for i, k in enumerate(ks):
    print(f"{k:6.1f} | {grid.avg_wait[i, 0]:13.1f} "
          f"{grid.med_wait[i, 0]:9.1f} {grid.full_util[i, 0]:9.3f} "
          f"{grid.useful_util[i, 0]:7.3f} | {grid.avg_wait[i, 1]:14.1f}")

thr = plateau_threshold(np.asarray(ks), grid.avg_wait[:, 0])
print(f"\nadministrator recommendation: scale ratio k >= {thr.threshold} "
      f"(plateau {thr.plateau:.1f}s) reaches the "
      f"queue-time plateau;\nraising k further buys nothing (paper §8); "
      f"lowering k raises full utilization\nbut inflates queue time "
      f"(the paper's central trade-off).")

# --- streaming: the same answer, live ------------------------------------
# Everything above is offline — one full trace, one sweep, one k. The
# streaming service (`repro.service`) answers "what k right now" instead:
# it cuts the arriving trace into fixed-size windows, runs this same sweep
# on each window as one cached lane program (compile once, ~ms per tick),
# and a plateau-aware hysteresis controller moves k only when the optimum
# leaves the current 5% plateau — so window noise doesn't thrash the
# cluster. Try it on a drifting workload:
#
#   PYTHONPATH=src python examples/streaming_controller.py
#   PYTHONPATH=src python -m repro.launch.service --scenario intensity_step
#
# Fault-aware mode (`ServiceConfig(chaos=...)`) sweeps a ChaosConfig
# fault-regime axis in the same per-tick program ([K, C] curves), a
# regime estimator maps realized failure telemetry onto cell weights,
# and `FaultAwareController` commits against wait + λ·lost-work instead
# of wait alone; `on_budget_exhausted="degrade"` keeps the loop alive
# through budget-exhausted windows (hold last-good k, health records).
# The same example's second act and `--chaos` on the launcher run it.
#
# The regret study (controller vs hindsight oracles, per drift scenario)
# is `benchmarks/controller_sweep.py` -> results/BENCH_controller.json;
# `--chaos` adds the regret-under-faults block and its gates.
