"""End-to-end training driver: a small LM trained for a few hundred steps
with the full substrate — sharded params, AdamW, microbatching, async
checkpointing, and a simulated mid-run failure + restart.

  PYTHONPATH=src python examples/train_lm.py            # ~25M params, fast
  PYTHONPATH=src python examples/train_lm.py --big      # ~110M params
"""
import argparse
import shutil
import tempfile

import jax

from repro.configs import get_config
from repro.ckpt import CheckpointManager
from repro.launch.mesh import make_host_mesh, mesh_axis_sizes
from repro.sharding import policy as policy_lib
from repro.train import data as data_lib
from repro.train import optim as optim_lib
from repro.train.step import init_state, make_train_step


def build(big: bool):
    cfg = get_config("granite-3-2b").with_(
        n_layers=8 if big else 4,
        d_model=768 if big else 384,
        n_heads=12 if big else 6, n_kv_heads=4 if big else 2,
        head_dim=64, d_ff=3072 if big else 1024,
        vocab_size=8192, param_dtype="float32", compute_dtype="float32",
        remat="none")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true", help="~110M params")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = build(args.big)
    mesh = make_host_mesh()
    pol = policy_lib.resolve(cfg, mesh_axis_sizes(mesh), args.batch,
                             "train", seq=args.seq)
    ocfg = optim_lib.AdamWConfig(lr=1e-3, warmup_steps=20,
                                 total_steps=args.steps)
    state, _ = init_state(cfg, pol, jax.random.PRNGKey(0), ocfg)
    n = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"model: {n / 1e6:.1f}M params; policy: {pol.strategy}")

    step = jax.jit(make_train_step(cfg, pol, ocfg, n_micro=2))
    it = data_lib.batches(cfg, data_lib.DataConfig(batch=args.batch,
                                                   seq=args.seq))
    ckdir = tempfile.mkdtemp(prefix="repro_ck_")
    mgr = CheckpointManager(ckdir, keep=2)
    fail_at = args.steps // 2

    with mesh:
        first = None
        for i in range(fail_at):
            state, mets = step(state, next(it))
            first = first or float(mets["loss"])
            if (i + 1) % 25 == 0:
                print(f"  step {i + 1:4d} loss={float(mets['loss']):.4f}")
            if (i + 1) % 20 == 0:
                mgr.save(i + 1, state, {"arch": cfg.name})
        mgr.wait()

        print(f"== simulated node failure at step {fail_at}: restarting "
              f"from latest checkpoint ==")
        fresh, _ = init_state(cfg, pol, jax.random.PRNGKey(0), ocfg)
        state, meta = mgr.restore_latest(fresh)
        resume = meta["step"]
        print(f"  restored step {resume}")
        it2 = data_lib.batches(cfg, data_lib.DataConfig(batch=args.batch,
                                                        seq=args.seq))
        for _ in range(resume):          # fast-forward the data stream
            next(it2)
        for i in range(resume, args.steps):
            state, mets = step(state, next(it2))
            if (i + 1) % 25 == 0:
                print(f"  step {i + 1:4d} loss={float(mets['loss']):.4f}")

    final = float(mets["loss"])
    print(f"done: loss {first:.3f} -> {final:.3f} "
          f"({'OK' if final < first else 'no improvement?'})")
    shutil.rmtree(ckdir, ignore_errors=True)
    assert final < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
