"""Batched serving: prefill + greedy decode across model families.

Runs the dense path (prefill seeds the KV cache, then batched decode) and
the recurrent path (xLSTM: O(1)-state decode — the mechanism behind the
long_500k cell), printing tokens/s for each.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.launch.mesh import make_host_mesh, mesh_axis_sizes
from repro.models.layers import unbox
from repro.models.registry import get_family
from repro.serve.engine import generate
from repro.sharding import policy as policy_lib


def demo(arch: str, B=4, prompt_len=16, max_new=24):
    cfg = smoke_config(arch, d_model=128, n_heads=4, head_dim=32)
    mesh = make_host_mesh()
    pol = policy_lib.resolve(cfg, mesh_axis_sizes(mesh), B, "decode",
                             seq=prompt_len + max_new)
    fam = get_family(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = unbox(fam.init_params(cfg, pol, key))
    prompts = np.asarray(jax.random.randint(key, (B, prompt_len), 0,
                                            cfg.vocab_size))
    embeds = None
    if cfg.family == "encdec":
        embeds = jax.random.normal(key, (B, prompt_len, cfg.d_model)) * 0.02
    with mesh:
        t0 = time.time()
        out = generate(cfg, pol, params, prompts, max_new=max_new,
                       embeds=embeds)
        dt = time.time() - t0
    print(f"  {arch:24s} [{cfg.family:6s}] generated {out.shape[1]} tokens "
          f"x {B} seqs in {dt:5.2f}s ({B * max_new / dt:7.1f} tok/s) "
          f"sample={out[0][:6].tolist()}")
    assert out.shape == (B, max_new)


if __name__ == "__main__":
    print("batched greedy serving across families:")
    demo("yi-6b")                  # dense GQA: prefill -> KV-cache decode
    demo("qwen2-moe-a2.7b")        # MoE decode
    demo("xlstm-1.3b")             # recurrent O(1)-state decode
    demo("recurrentgemma-2b")      # RG-LRU + ring-buffer local attention
    demo("seamless-m4t-large-v2")  # enc-dec with precomputed cross-KV
    print("all families served.")
