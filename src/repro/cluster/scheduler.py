"""The paper's technique as a first-class ML-cluster feature.

Mapping (DESIGN.md §2): a *job type* = an (arch x step-shape) pair whose
"initialization" is XLA compilation + checkpoint restore + mesh setup —
type-keyed and amortizable across a group exactly like the paper's s_j. A
*job* = a training/eval task of that type, moldable over its data-parallel
width with ~linear speedup (work measured in chip-seconds). The Packet
algorithm (repro.core.packet — the same policy functions the DES and the
Pallas kernel use) forms per-type meta-jobs and sizes their chip slice by
the scale ratio k: exec_time ~= k x init_time.

On top of the paper's model, the production concerns:
  * failure injection — exponential chip-slice failures; the running group
    loses progress since its last checkpoint and its *remaining* work is
    requeued (checkpoint period bounds the loss),
  * straggler mitigation — group duration is stretched by a straggler
    factor; if it exceeds ``straggler_deadline`` x the expected duration,
    the group is killed at the deadline and the unfinished remainder is
    re-dispatched (re-queued at the front via its original submit time),
  * elastic slices — a requeued remainder may be regrouped and run on a
    different number of chips (the checkpoint layer's elastic re-shard is
    what makes this legal for training jobs).

This event-driven simulator is intentionally host-side Python (rich
semantics, modest event counts); the paper's 1332-experiment grid runs on
the fixed-shape JAX DES in repro.core.des.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional

import numpy as np

from repro.core import packet as policy

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class JobType:
    name: str                  # e.g. "yi-6b:train_4k"
    init_time: float           # s_j: compile + restore + mesh setup (s)
    tp_degree: int = 1         # chips per model shard (slice granularity)
    priority: float = 1.0
    t_max: float = 3600.0


@dataclasses.dataclass
class MLJob:
    jid: int
    jtype: int                 # index into the type table
    submit: float
    work: float                # chip-seconds on one chip-slice (moldable)
    done_work: float = 0.0     # checkpointed progress
    start: float = math.inf    # first time its group started
    finish: float = math.inf


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    n_chips: int = 1024
    scale_ratio: float = 4.0
    ckpt_period: float = 300.0          # seconds between checkpoints
    mtbf_chip_hours: float = 0.0        # 0 = no failures
    straggler_prob: float = 0.0
    straggler_factor: float = 1.5
    straggler_deadline: float = 2.0     # kill at deadline x expected
    seed: int = 0


def slice_for(m_chips: int, tp_degree: int) -> tuple[int, int]:
    """Moldable slice shape (dp, tp): dp = chips // tp (>= 1 group rule)."""
    dp = max(m_chips // tp_degree, 1)
    return dp, tp_degree


class ClusterSim:
    """Event-driven Packet scheduler over an ML cluster."""

    def __init__(self, types: list[JobType], cfg: ClusterConfig):
        self.types = types
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.queues: list[list[MLJob]] = [[] for _ in types]
        self.events: list = []           # (time, seq, kind, payload)
        self._seq = 0
        self.t = 0.0
        self.free = cfg.n_chips
        self.jobs: dict[int, MLJob] = {}
        self.groups = 0
        self.busy_cs = 0.0               # busy chip-seconds
        self.useful_cs = 0.0
        self.lost_cs = 0.0               # work lost to failures
        self.requeues = 0
        self.requeued_jobs = 0           # individual members re-queued
        self.failures = 0
        self.straggler_kills = 0

    # ----------------------------------------------------------- events
    def _push(self, t, kind, payload):
        self._seq += 1
        heapq.heappush(self.events, (t, self._seq, kind, payload))

    def submit(self, job: MLJob):
        self.jobs[job.jid] = job
        self._push(job.submit, "submit", job)

    # -------------------------------------------------------- scheduling
    def _weights(self):
        h = len(self.types)
        sum_w = np.array([sum(j.work - j.done_work for j in q)
                          for q in self.queues])
        s_j = np.array([t.init_time for t in self.types])
        p_j = np.array([t.priority for t in self.types])
        oldest = np.array([min((j.submit for j in q), default=np.inf)
                           for q in self.queues])
        tmax = np.array([t.t_max for t in self.types])
        nonempty = np.array([len(q) > 0 for q in self.queues])
        w = policy.queue_weights(jnp.asarray(sum_w), jnp.asarray(s_j),
                                 jnp.asarray(p_j), jnp.asarray(oldest),
                                 self.t, jnp.asarray(tmax),
                                 jnp.asarray(nonempty))
        return np.asarray(w), sum_w, s_j

    def _schedule(self):
        """Paper Steps 1-5, repeatedly until blocked."""
        while self.free > 0 and any(self.queues):
            w, sum_w, s_j = self._weights()
            j = int(np.argmax(w))
            if not np.isfinite(w[j]):
                break
            jt = self.types[j]
            work = float(sum_w[j])
            m_thr = int(policy.m_threshold(work, self.cfg.scale_ratio,
                                           s_j[j]))
            # slice granularity: groups allocate whole TP slices
            m_thr = max(math.ceil(m_thr / jt.tp_degree) * jt.tp_degree,
                        jt.tp_degree)
            m = min(m_thr, self.free - self.free % jt.tp_degree)
            if m < jt.tp_degree:
                break
            members = self.queues[j]
            self.queues[j] = []
            exp_dur = jt.init_time + work / m
            dur = exp_dur
            stretched = self.rng.random() < self.cfg.straggler_prob
            if stretched:
                dur = jt.init_time + (work / m) * self.cfg.straggler_factor
            deadline = self.cfg.straggler_deadline * exp_dur
            killed = dur > deadline
            end = self.t + min(dur, deadline)
            for job in members:
                job.start = min(job.start, self.t)
            self.free -= m
            self.groups += 1
            self._push(end, "finish", {
                "jtype": j, "m": m, "t0": self.t, "members": members,
                "killed": killed, "dur": min(dur, deadline),
                "stretch": (self.cfg.straggler_factor if stretched else 1.0),
            })

    # ----------------------------------------------------------- failures
    def _maybe_fail(self, grp) -> Optional[float]:
        """Absolute failure time of the group, or None if it survives.

        Drawn lazily when the group's scheduled end is processed: a
        failure is *resolved* at group end — the chips stay held for the
        full duration (restart-in-place semantics), and the failure time
        only decides how much work since the last checkpoint is lost.
        The returned instant is ``t0 + t_fail``, the group start plus an
        exponential draw at the slice's aggregate chip failure rate.
        """
        if self.cfg.mtbf_chip_hours <= 0:
            return None
        rate = grp["m"] / (self.cfg.mtbf_chip_hours * 3600.0)
        t_fail = self.rng.exponential(1.0 / rate) if rate > 0 else np.inf
        return grp["t0"] + t_fail if t_fail < grp["dur"] else None

    # --------------------------------------------------------------- run
    def run(self):
        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            self.t = t
            if kind == "submit":
                self.queues[payload.jtype].append(payload)
                self._schedule()
            elif kind == "finish":
                self._finish(payload)
        return self.metrics()

    def _finish(self, grp):
        jt = self.types[grp["jtype"]]
        m, t0 = grp["m"], grp["t0"]
        dur = grp["dur"]
        self.busy_cs += m * dur
        fail_t = self._maybe_fail(grp)
        run_span = dur - jt.init_time
        if fail_t is not None:
            self.failures += 1
            run_done = max(min(fail_t - t0, dur) - jt.init_time, 0.0)
            ckpt_done = math.floor(run_done / self.cfg.ckpt_period) * \
                self.cfg.ckpt_period
            self.lost_cs += (run_done - ckpt_done) * m
            self.useful_cs += ckpt_done * m
            self._requeue(grp, ckpt_done * m / grp["stretch"])
        elif grp["killed"]:
            self.straggler_kills += 1
            run_done = max(dur - jt.init_time, 0.0)
            done_work = run_done * m / grp["stretch"]
            self.useful_cs += run_done * m
            self._requeue(grp, done_work)
        else:
            self.useful_cs += run_span * m
            for job in grp["members"]:
                job.done_work = job.work
                # members of a completing group always carry finish=inf
                # (a job with a finite finish was fully credited earlier
                # and never requeued), so this group's end IS the job's
                # last completion time — including for jobs that failed
                # or were killed in earlier groups and requeued here.
                job.finish = t0 + dur
        self.free += m
        self._schedule()

    def _requeue(self, grp, done_work: float):
        """Credit completed work to members in order; requeue the rest."""
        self.requeues += 1
        remaining = done_work
        for job in grp["members"]:
            need = job.work - job.done_work
            credit = min(need, remaining)
            job.done_work += credit
            remaining -= credit
            if job.work - job.done_work > 1e-9:
                self.queues[job.jtype].append(job)
                self.requeued_jobs += 1
            else:
                job.finish = self.t

    # ----------------------------------------------------------- metrics
    def metrics(self) -> dict:
        jobs = list(self.jobs.values())
        waits = [j.start - j.submit for j in jobs if np.isfinite(j.start)]
        span = max((j.finish for j in jobs if np.isfinite(j.finish)),
                   default=self.t)
        denom = self.cfg.n_chips * max(span, 1e-9)
        return {
            "jobs": len(jobs),
            "unfinished": sum(1 for j in jobs
                              if j.work - j.done_work > 1e-9),
            "groups": self.groups,
            "avg_wait": float(np.mean(waits)) if waits else 0.0,
            "med_wait": float(np.median(waits)) if waits else 0.0,
            "full_util": self.busy_cs / denom,
            "useful_util": self.useful_cs / denom,
            "lost_chip_seconds": self.lost_cs,
            "failures": self.failures,
            "straggler_kills": self.straggler_kills,
            "requeues": self.requeues,
            "requeued_jobs": self.requeued_jobs,
            "makespan": span,
        }


def workload_from_arrival_rate(types: list[JobType], n_jobs: int,
                               horizon: float, mean_work: float,
                               seed: int = 0) -> list[MLJob]:
    """Poisson arrivals, exponential work, zipf-ish type popularity."""
    rng = np.random.default_rng(seed)
    pw = 1.0 / np.arange(1, len(types) + 1)
    pw /= pw.sum()
    jobs = []
    for i in range(n_jobs):
        jobs.append(MLJob(
            jid=i, jtype=int(rng.choice(len(types), p=pw)),
            submit=float(rng.uniform(0, horizon)),
            work=float(rng.exponential(mean_work))))
    jobs.sort(key=lambda j: j.submit)
    return jobs
