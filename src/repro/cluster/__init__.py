from repro.cluster.scheduler import (ClusterConfig, ClusterSim, JobType,
                                     MLJob, slice_for)

__all__ = ["ClusterConfig", "ClusterSim", "JobType", "MLJob", "slice_for"]
