"""Batched serving engine: prefill + greedy decode over any family.

``serve_step`` is the function the decode-shape dry-run cells lower: one new
token for every sequence in the batch against a KV cache / recurrent state
of the cell's context length. ``generate`` is the example-facing loop
(prefill where the family supports cache seeding, else token-by-token
replay), with greedy sampling.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.registry import get_family
from repro.sharding.policy import Policy


def make_serve_step(cfg: ModelConfig, pol: Policy):
    """(params, cache, tokens [B,1]) -> (next_tokens [B,1], cache)."""
    family = get_family(cfg)

    def serve_step(params, cache, tokens):
        logits, cache = family.decode_step(cfg, pol, params, cache, tokens)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


def make_decode_logits_step(cfg: ModelConfig, pol: Policy):
    """Raw decode step (logits out) — what the dry-run lowers."""
    family = get_family(cfg)

    def step(params, cache, tokens):
        return family.decode_step(cfg, pol, params, cache, tokens)

    return step


def generate(cfg: ModelConfig, pol: Policy, params, prompts,
             max_new: int = 16, max_len: Optional[int] = None,
             embeds=None) -> np.ndarray:
    """Greedy generation for examples/tests. prompts: [B, S] int32."""
    family = get_family(cfg)
    B, S = prompts.shape
    max_len = max_len or (S + max_new)
    step = jax.jit(make_serve_step(cfg, pol))

    if cfg.family in ("dense", "moe", "vlm"):
        hidden, cache = jax.jit(
            lambda p, t: lm.prefill(cfg, pol, p, t, max_len, embeds=embeds)
        )(params, prompts)
        from repro.models.layers import unembed
        logits = unembed(cfg, pol, hidden[:, -1:], params["embed"])
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    elif cfg.family == "encdec":
        from repro.models import encdec
        memory = jax.jit(lambda p, e: encdec.encode(cfg, pol, p, e))(
            params, embeds)
        cache = encdec.init_cache(cfg, pol, B, max_len)
        xk, xv = encdec.prefill_cross_kv(cfg, pol, params, memory)
        cache = cache._replace(xk=xk, xv=xv)
        tok = prompts[:, :1]
        for i in range(S - 1):          # teacher-forced replay of the prompt
            _, cache = step(params, cache, prompts[:, i:i + 1])
        tok = prompts[:, -1:]
    else:
        # recurrent families: replay the prompt token by token
        cache = family.init_cache(cfg, pol, B, max_len)
        for i in range(S - 1):
            _, cache = step(params, cache, prompts[:, i:i + 1])
        tok = prompts[:, -1:]

    out = [np.asarray(tok)]
    for _ in range(max_new - 1):
        tok, cache = step(params, cache, tok)
        out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)
