"""Fault-tolerant checkpointing: atomic writes, async writer, rotation,
elastic re-shard on restore.

Format: one ``.npz`` per step with '/'-joined tree paths as keys plus a
JSON metadata entry (step, config digest, mesh shape at save time). Writes
go to a temp file + atomic rename so a node failure mid-write never
corrupts the latest checkpoint — the restart sees either the old or the
new complete file (the property the cluster layer's failure-injection
tests rely on).

Elastic re-shard: arrays are saved host-complete; ``restore_checkpoint``
takes an optional (mesh, sharding-tree) and device_puts every leaf with its
*new* sharding, so a job checkpointed on a 256-chip slice restarts on any
other slice shape (the paper's moldable-job property, applied to training
jobs).

On a real multi-host pod this single-file format is replaced by per-host
shard files (same tree paths, one file per data-parallel host group); the
manager API is identical, which is what the rest of the framework codes
against.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"
_META_KEY = "__meta__"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part_name(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _part_name(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _unflatten_into(template, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = _SEP.join(_part_name(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def save_checkpoint(path: str, step: int, state, extra_meta: Optional[dict]
                    = None) -> str:
    """Atomic synchronous save. Returns the final file path."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    meta = {"step": int(step), **(extra_meta or {})}
    final = os.path.join(path, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat, **{_META_KEY: np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8)})
        os.replace(tmp, final)          # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore_checkpoint(path: str, template, step: Optional[int] = None,
                       shardings=None):
    """Restore into ``template``'s structure; optionally device_put every
    leaf with a new sharding tree (elastic re-shard)."""
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    with np.load(os.path.join(path, f"ckpt_{step:08d}.npz")) as z:
        flat = {k: z[k] for k in z.files if k != _META_KEY}
        meta = json.loads(bytes(z[_META_KEY]).decode()) \
            if _META_KEY in z.files else {"step": step}
    state = _unflatten_into(template, flat)
    if shardings is not None:
        state = jax.tree.map(jax.device_put, state, shardings)
    return state, meta


class CheckpointManager:
    """Async writer + rotation.

    ``save`` snapshots to host memory synchronously (cheap) and writes on a
    background thread, overlapping I/O with the next train steps; ``wait``
    joins the writer (called before exit / before deleting old steps).
    Keeps the newest ``keep`` checkpoints.
    """

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state, extra_meta: Optional[dict] = None):
        self.wait()
        host = jax.tree.map(np.asarray, state)      # snapshot before mutation

        def _write():
            try:
                save_checkpoint(self.path, step, host, extra_meta)
                self._rotate()
            except BaseException as e:               # surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _rotate(self):
        steps = sorted(int(re.fullmatch(r"ckpt_(\d+)\.npz", f).group(1))
                       for f in os.listdir(self.path)
                       if re.fullmatch(r"ckpt_(\d+)\.npz", f))
        for s in steps[:-self.keep]:
            os.unlink(os.path.join(self.path, f"ckpt_{s:08d}.npz"))

    def restore_latest(self, template, shardings=None):
        self.wait()
        return restore_checkpoint(self.path, template, shardings=shardings)
