"""Deterministic synthetic data pipeline.

Produces a reproducible, host-shardable stream of next-token-predictable
batches (an order-k Markov bigram-ish stream) so the end-to-end training
examples have a real, decreasing loss signal without external datasets.
Each host generates only its own shard (``host_id``/``n_hosts``), the
standard multi-pod input-pipeline pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq: int = 128
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1


def _stream(vocab: int, rng: np.random.Generator, n: int) -> np.ndarray:
    """Tokens where t_{i+1} = (a * t_i + b) % vocab with noisy resets —
    learnable structure with entropy."""
    a = 31 % vocab or 1
    b = 17 % vocab
    toks = np.empty(n, np.int32)
    t = int(rng.integers(vocab))
    for i in range(n):
        toks[i] = t
        if rng.random() < 0.05:
            t = int(rng.integers(vocab))
        else:
            t = (a * t + b) % vocab
    return toks


def batches(cfg: ModelConfig, dc: DataConfig) -> Iterator[dict]:
    """Yields {tokens, labels(, embeds)} numpy batches for this host."""
    rng = np.random.default_rng(dc.seed * 1009 + dc.host_id)
    B, S = dc.batch // dc.n_hosts, dc.seq
    assert dc.batch % dc.n_hosts == 0
    while True:
        toks = _stream(cfg.vocab_size, rng, B * (S + 1)).reshape(B, S + 1)
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if cfg.family == "encdec":
            batch["embeds"] = rng.standard_normal(
                (B, S, cfg.d_model)).astype(np.float32) * 0.02
        elif cfg.embeds_input and cfg.n_prefix:
            batch["embeds"] = rng.standard_normal(
                (B, cfg.n_prefix, cfg.d_model)).astype(np.float32) * 0.02
            # prefix positions are frontend embeddings, not text: no loss
            batch["labels"][:, :cfg.n_prefix] = -1
        yield batch
