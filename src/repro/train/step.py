"""Train step: microbatched grad accumulation + AdamW + metrics.

``make_train_step`` builds a pure (state, batch) -> (state, metrics)
function for any of the model families. With ``n_micro > 1`` the global
batch is split into microbatches accumulated in a lax.scan — the standard
large-scale pattern that lets XLA's latency-hiding scheduler overlap the
reduce-scatter of one microbatch's gradients with the next one's compute.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import get_family
from repro.sharding.policy import Policy
from repro.train import optim as optim_lib
from repro.train.loss import chunked_ce


class TrainState(NamedTuple):
    params: dict
    opt: optim_lib.OptState


def init_state(cfg: ModelConfig, pol: Policy, key,
               ocfg: Optional[optim_lib.AdamWConfig] = None):
    from repro.models.layers import unbox
    ocfg = ocfg or optim_lib.AdamWConfig()
    boxed = get_family(cfg).init_params(cfg, pol, key)
    params, axes = unbox(boxed)
    return TrainState(params=params, opt=optim_lib.init(ocfg, params)), axes


def make_loss_fn(cfg: ModelConfig, pol: Policy, loss_chunk: int = 512):
    family = get_family(cfg)

    def loss_fn(params, batch):
        hidden, aux = family.forward(cfg, pol, params, batch["tokens"],
                                     batch.get("embeds"))
        loss, mets = chunked_ce(cfg, pol, hidden, params["embed"],
                                batch["labels"], chunk=loss_chunk)
        return loss + aux.astype(loss.dtype), mets

    return loss_fn


def make_train_step(cfg: ModelConfig, pol: Policy,
                    ocfg: Optional[optim_lib.AdamWConfig] = None,
                    n_micro: int = 1, loss_chunk: int = 512):
    ocfg = ocfg or optim_lib.AdamWConfig()
    loss_fn = make_loss_fn(cfg, pol, loss_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        if n_micro == 1:
            (loss, mets), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                B = x.shape[0]
                assert B % n_micro == 0, (B, n_micro)
                return x.reshape(n_micro, B // n_micro, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                tot, g = carry
                (l, m), gi = grad_fn(state.params, mb)
                return (tot + l, jax.tree.map(jnp.add, g, gi)), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, gsum), mets = jax.lax.scan(
                acc, (jnp.zeros(()), zeros), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            mets = jax.tree.map(lambda m: m[-1], mets)

        params, opt, omets = optim_lib.apply(ocfg, state.opt, state.params,
                                             grads)
        out = {"loss": loss, **omets,
               **{k: v for k, v in mets.items()}}
        return TrainState(params=params, opt=opt), out

    return train_step
