"""AdamW with gradient clipping and LR schedules (self-contained, no optax).

Optimizer moments inherit the parameter logical axes, so under ZeRO-style
rules they shard exactly like the parameters (ZeRO-1/3 falls out of the rule
table, not special code). For >=100B-parameter configs the moments are kept
in bfloat16 (``dtype="bfloat16"``) — the gradient-compression knob recorded
in DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"     # "bfloat16" for very large models


class OptState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: AdamWConfig, params) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jnp.zeros_like(p, dtype=dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(z, params), v=jax.tree.map(z, params))


def global_norm(tree):
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def apply(cfg: AdamWConfig, state: OptState, params, grads,
          decay_mask=None):
    """One AdamW step. decay_mask: pytree of bools (False = no weight decay;
    default: decay only rank>=2 tensors)."""
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.moment_dtype)
    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)

    def upd(p, g, m, v, dk):
        g = g.astype(jnp.float32) * scale
        m1 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v1 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m1 / b1c
        vhat = v1 / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0:
            upd = upd + jnp.where(dk, cfg.weight_decay, 0.0) * \
                p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * upd).astype(p.dtype),
                m1.astype(dt), v1.astype(dt))

    out = jax.tree.map(upd, params, grads, state.m, state.v, decay_mask)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x:
                         isinstance(x, tuple) and len(x) == 3)
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x:
                         isinstance(x, tuple) and len(x) == 3)
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x:
                         isinstance(x, tuple) and len(x) == 3)
    return new_p, OptState(step=step, m=new_m, v=new_v), \
        {"lr": lr, "grad_norm": gn}
