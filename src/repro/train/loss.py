"""Cross-entropy loss computed in sequence chunks.

For 100k-256k vocabularies a full [B, S, V] logit tensor at 1M tokens is
terabytes; real frameworks never materialize it. We scan over sequence
chunks: each step computes [B, chunk, V] logits from the final hidden
states, the label log-prob, and the log-partition — O(B*chunk*V) transient
memory regardless of S. Padded vocab rows are masked exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding.policy import Policy

IGNORE = -1          # label value that is excluded from the loss


def chunked_ce(cfg: ModelConfig, pol: Policy, hidden, embed_w, labels,
               chunk: int = 512, z_loss: float = 0.0):
    """hidden: [B, S, d]; embed_w: [Vpad, d]; labels: [B, S] (-1 = ignore).

    Returns (mean loss over non-ignored tokens, dict of scalars).
    """
    B, S, d = hidden.shape
    Vpad = embed_w.shape[0]
    if pol.rules.get("seq") is not None:
        chunk = S          # dp_seq: chunking would reshape the sharded axis
    chunk = min(chunk, S)
    n = (S + chunk - 1) // chunk
    pad = n * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=IGNORE)
    hs = hidden.reshape(B, n, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)
    w = embed_w.astype(hidden.dtype)
    vmask = (jnp.arange(Vpad) < cfg.vocab_size)

    def step(carry, xs):
        tot, cnt, zacc = carry
        h, lab = xs
        logits = (h @ w.T).astype(jnp.float32)
        logits = jnp.where(vmask, logits, -1e30)
        if cfg.logit_softcap > 0:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        logits = pol.constrain(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.clip(lab, 0, cfg.vocab_size - 1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        valid = lab != IGNORE
        nll = jnp.where(valid, lse - gold, 0.0)
        z = jnp.where(valid, lse ** 2, 0.0)
        return (tot + nll.sum(), cnt + valid.sum(), zacc + z.sum()), None

    (tot, cnt, zacc), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32),
               jnp.zeros((), jnp.float32)), (hs, ls))
    denom = jnp.maximum(cnt, 1).astype(jnp.float32)
    loss = tot / denom
    if z_loss > 0:
        loss = loss + z_loss * zacc / denom
    return loss, {"ce": tot / denom, "tokens": cnt}
