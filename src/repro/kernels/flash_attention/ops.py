"""jit'd public wrapper: model layout [B, S, H, hd] <-> kernel layout.

On CPU hosts (tests, smoke runs) the kernel executes in interpret mode;
on TPU it compiles to Mosaic. The layout transpose is fused by XLA into
the surrounding projections.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bkv"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 256, bkv: int = 256):
    """q: [B, S, H, hd]; k, v: [B, T, KV, hd] -> [B, S, H, hd]."""
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               softcap=softcap, bq=bq, bkv=bkv,
                               interpret=_on_cpu())
    return out.swapaxes(1, 2)
