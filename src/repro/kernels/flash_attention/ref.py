"""Pure-jnp oracle for flash attention (GQA, causal/local, softcap)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd]. Returns [B, Sq, H, hd]."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qi = jnp.arange(Sq)[:, None] + (Skv - Sq)
    ki = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)
