"""Blocked causal/local flash attention — Pallas TPU kernel.

TPU-native tiling: grid = (batch, kv_head, q_group, Sq/bq, Skv/bkv) with the
KV-block axis innermost. TPU grids execute sequentially, so the online-
softmax running state (m, l, acc) lives in VMEM scratch that persists across
the innermost axis; the output block is written once on the last KV step.
Block shapes are (bq, head_dim) / (bkv, head_dim) — multiples of the (8,128)
float32 VMEM tile and of the 128x128 MXU.

GQA is handled by the grid, not by materializing repeated K/V: query head
h = kv*g + gi reads K/V block kv — zero replication in HBM.

Causal/local masking is done with 2-D iota against absolute positions; KV
blocks that are fully out of window are skipped via ``@pl.when`` (the
dominant saving for the 2048-token local-attention cells).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_BQ = 256
DEFAULT_BKV = 256
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 bq: int, bkv: int, n_kv: int, seq_q: int, seq_kv: int):
    iq = pl.program_id(3)
    ik = pl.program_id(4)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    # offset: query i attends to absolute kv positions <= i + (seq_kv - seq_q)
    off = seq_kv - seq_q

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks that are fully masked (above the causal diagonal or out of
    # the local window)
    blk_live = True
    if causal:
        blk_live = (ik * bkv) <= (iq * bq + bq - 1 + off)
    if window > 0:
        blk_live = blk_live & ((ik * bkv + bkv - 1) >
                               (iq * bq - window + off))

    @pl.when(blk_live)
    def _step():
        q = q_ref[0, 0, 0].astype(jnp.float32)           # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bkv, hd]
        v = v_ref[0, 0].astype(jnp.float32)              # [bkv, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        mask = k_pos < seq_kv                             # padding
        if causal:
            mask &= k_pos <= q_pos + off
        if window > 0:
            mask &= k_pos > q_pos + off - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # [bq, bkv]
        alpha = jnp.exp(m_prev - m_new)                   # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + p.sum(1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bkv",
                              "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         softcap: float = 0.0, bq: int = DEFAULT_BQ,
                         bkv: int = DEFAULT_BKV, interpret: bool = False):
    """q: [B, H, Sq, hd]; k, v: [B, KV, Skv, hd]; H % KV == 0.

    Returns [B, H, Sq, hd]. Sequences are padded to block multiples
    internally; padded KV columns are masked exactly.
    """
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    bq = min(bq, max(8, Sq))
    bkv = min(bkv, max(8, Skv))
    pq, pkv = (-Sq) % bq, (-Skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    nq, nkv = (Sq + pq) // bq, (Skv + pkv) // bkv
    qg = q.reshape(B, KV, g, Sq + pq, hd)

    grid = (B, KV, g, nq, nkv)
    kern = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bkv=bkv, n_kv=nkv, seq_q=Sq, seq_kv=Skv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd),
                         lambda b, kv, gi, iq, ik: (b, kv, gi, iq, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, kv, gi, iq, ik: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, kv, gi, iq, ik: (b, kv, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bq, hd),
                               lambda b, kv, gi, iq, ik: (b, kv, gi, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, Sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max m
            pltpu.VMEM((bq, 1), jnp.float32),      # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),     # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(B, H, Sq + pq, hd)[:, :, :Sq]
