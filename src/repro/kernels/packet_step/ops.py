"""Wrapper for the fused event-step kernel with CPU interpret fallback.

`fused_packet_step` is the call site `repro.core.des` uses from inside
the `simulate_packet_scan_lanes(step_impl="pallas")` scan: one kernel
invocation per event for a whole [T]-lane dispatch. On CPU the kernel
runs with ``interpret=True`` — Pallas discharges the body back into the
enclosing XLA program, so the path is a correctness/parity fallback
there (compiled, but no VMEM-residency win). On TPU it compiles via
Mosaic with the `_compat.CompilerParams` shim.

Not jitted here on purpose: every caller invokes it under an enclosing
`jax.jit`/`lax.scan` trace, and leaving it undecorated keeps single-step
calls (the unit tests' budget-exhaustion probes) eagerly debuggable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.packet_step.kernel import N_STATE_COLS, event_step_kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def fused_packet_step(tj_prefw, tj_submit, submit, jtype, k, s, p_j,
                      tmax_j, t_last, state, u1=None, u2=None,
                      chaos_params=None, *, r_cap: int = 0,
                      interpret: bool | None = None):
    """Advance every lane one event. See kernel.event_step_kernel.

    `state` is a `des._ScanState` of [*, T] columns; `chaos_params` is
    the (mtbf, ckpt_period, straggler_prob, straggler_factor,
    straggler_deadline) tuple of [1, T] columns, present iff `u1`/`u2`
    (the [L_cap, T] uniform streams) are. Returns ``(new_state, y)``
    with `y` the 4-tuple of [1, T] log records.
    """
    if interpret is None:
        interpret = _on_cpu()
    has_chaos = u1 is not None
    st_cols = list(state)
    T = st_cols[0].shape[1]
    dtype = st_cols[0].dtype
    inputs = [tj_prefw, tj_submit, submit, jtype, k, s, p_j, tmax_j,
              t_last]
    if has_chaos:
        inputs += [u1, u2, *chaos_params]
    state_off = len(inputs)
    inputs += st_cols
    out_shape = ([jax.ShapeDtypeStruct(x.shape, x.dtype)
                  for x in st_cols] +
                 [jax.ShapeDtypeStruct((1, T), jnp.int32),
                  jax.ShapeDtypeStruct((1, T), dtype),
                  jax.ShapeDtypeStruct((1, T), jnp.int32),
                  jax.ShapeDtypeStruct((1, T), dtype)])
    kernel = functools.partial(event_step_kernel,
                               n_jobs=int(submit.shape[0]),
                               r_cap=int(r_cap),
                               has_chaos=has_chaos)
    outs = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        input_output_aliases={state_off + i: i
                              for i in range(N_STATE_COLS)},
        interpret=interpret,
    )(*inputs)
    new_state = type(state)(*outs[:N_STATE_COLS])
    y = tuple(outs[N_STATE_COLS:])
    return new_state, y
