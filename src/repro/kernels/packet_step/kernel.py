"""Fused per-event DES step — Pallas kernel, lanes on the minor axis.

One invocation advances EVERY lane of a dispatch by one event: the
branchless select between group formation and event consumption, the
group-ring commit (including the packed requeue span stash from the
chaos engine), chaos outcome resolution, and the metric accumulates —
the whole body of `repro.core.des.packet_scan_step`, vectorized over a
trailing lane axis T. State is carried as [state, T] columns (scalars
as [1, T], per-type rows as [H, T], ring rows as [ring, T]) so the
gather/scatter chain of a step stays resident in kernel memory instead
of round-tripping each small intermediate through HBM, which is what
XLA's generic lowering does to the scan step's ~40 fused ops.

Bitwise contract: every float op here is elementwise and every
reduction is an integer/boolean/arg reduction over the state axis, so
per-lane results are bit-identical to the scalar `packet_scan_step`
(ref.py) in both dtypes, chaos on and off — tests/test_packet_step.py
pins this through the interpret path, which discharges the kernel back
into the enclosing XLA program on CPU.

The event arithmetic deliberately REUSES the des.py helpers
(`_chaos_outcome`, `_resolve_remnant`, `_pool_decode`, the packet
policy functions): they are shape-polymorphic, so the kernel body is
the same source of truth as the XLA engine, just indexed by lane.
"""
from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp

from repro.core import packet
from repro.core.des import (CREDIT_EPS, INF, ChaosConfig, _chaos_outcome,
                            _pool_decode, _resolve_remnant, _window_overlap)

#: number of _ScanState fields carried as [*, T] columns
N_STATE_COLS = 23


def event_step_kernel(*refs, n_jobs: int, r_cap: int, has_chaos: bool):
    """Pallas kernel body. Operand order (built by ops.fused_packet_step):

    inputs:  tj_prefw [H, N+1], tj_submit [H, N], submit [N], jtype [N],
             k [1, T], s [1, T], p_j [H], tmax_j [H], t_last [1, 1],
             then iff has_chaos: u1 [L, T], u2 [L, T] and the five fault
             parameter columns [1, T] (mtbf, ckpt, prob, factor,
             deadline), then the 23 state columns in _ScanState order.
    outputs: the 23 updated state columns (aliased onto the inputs),
             then the 4 log records (key, t, m, head_w) as [1, T].
    """
    N = n_jobs
    (prefw_ref, tsub_ref, submit_ref, jtype_ref, k_ref, s_ref, pj_ref,
     tmax_ref, tlast_ref) = refs[:9]
    off = 9
    if has_chaos:
        (u1_ref, u2_ref, mtbf_ref, ckpt_ref, prob_ref, factor_ref,
         dead_ref) = refs[off:off + 7]
        off += 7
    st = refs[off:off + N_STATE_COLS]
    out = refs[off + N_STATE_COLS:off + 2 * N_STATE_COLS]
    y_out = refs[off + 2 * N_STATE_COLS:off + 2 * N_STATE_COLS + 4]

    prefw = prefw_ref[...]
    tsub = tsub_ref[...]
    submit = submit_ref[...]
    jtypes = jtype_ref[...]
    k = k_ref[...][0]
    s = s_ref[...][0]
    p_j = pj_ref[...]
    tmax_j = tmax_ref[...]
    t_last = tlast_ref[...][0, 0]

    t = st[0][...][0]
    next_sub = st[1][...][0]
    head = st[2][...]
    tail = st[3][...]
    m_free = st[4][...][0]
    grp_end = st[5][...]
    grp_m = st[6][...]
    qlen_int = st[7][...][0]
    busy_ns = st[8][...][0]
    useful_ns = st[9][...][0]
    n_groups = st[10][...][0]
    pool_w = st[11][...]
    pool_oldest = st[12][...]
    pool_code = st[13][...]
    grp_jtype = st[14][...]
    grp_rem_w = st[15][...]
    grp_rem_cnt = st[16][...]
    grp_rem_oldest = st[17][...]
    lost_work = st[18][...][0]
    failures = st[19][...][0]
    straggler_kills = st[20][...][0]
    requeues = st[21][...][0]
    requeued_jobs = st[22][...][0]

    dtype = t.dtype
    T = t.shape[0]
    lanes = jnp.arange(T)
    key_pad = jnp.iinfo(jnp.int32).max
    zero_f = jnp.zeros((), dtype)
    zero_i = jnp.zeros((), jnp.int32)
    one_i = jnp.ones((), jnp.int32)

    nonempty = tail > head                                   # [H, T]
    if has_chaos:
        nonempty = nonempty | (pool_code > 0)
    free_mask = jnp.isinf(grp_end)                           # [ring, T]
    queued = jnp.any(nonempty, axis=0)                       # [T]
    active = ((next_sub < N) | jnp.any(~jnp.isinf(grp_end), axis=0) |
              jnp.any(tail > head, axis=0))
    if has_chaos:
        active = active | jnp.any(pool_code > 0, axis=0)
    can_sched = (m_free > 0) & queued & jnp.any(free_mask, axis=0)
    do_sched = active & can_sched
    do_event = active & ~can_sched

    # greedy scheduling pass (paper Steps 1-5), masked unless do_sched
    sum_w = (jnp.take_along_axis(prefw, tail, axis=1) -
             jnp.take_along_axis(prefw, head, axis=1))       # [H, T]
    oldest = jnp.take_along_axis(tsub, jnp.minimum(head, N - 1), axis=1)
    if has_chaos:
        sum_w = sum_w + pool_w
        oldest = jnp.minimum(oldest, pool_oldest)
    w = packet.queue_weights(sum_w, s, p_j[:, None], oldest, t,
                             tmax_j[:, None], nonempty)
    j = jnp.argmax(w, axis=0).astype(jnp.int32)              # [T]
    work = sum_w[j, lanes]
    m_grp = packet.group_nodes(work, k, s, m_free)
    dur = packet.group_duration(work, s, m_grp)
    sslot = jnp.argmax(free_mask, axis=0)
    head_w = prefw[j, head[j, lanes]]
    if not has_chaos:
        t_gfin = t + dur
        useful_end = t_gfin
    else:
        u1 = u1_ref[...]
        u2 = u2_ref[...]
        chaos = ChaosConfig(
            mtbf_chip_hours=mtbf_ref[...][0],
            ckpt_period=ckpt_ref[...][0],
            straggler_prob=prob_ref[...][0],
            straggler_factor=factor_ref[...][0],
            straggler_deadline=dead_ref[...][0])
        L_cap = u1.shape[0]
        gslot = jnp.minimum(n_groups, L_cap - 1)
        out_c = _chaos_outcome(chaos, u1[gslot, lanes], u2[gslot, lanes],
                               requeues < r_cap, s, work, m_grp, dur,
                               dtype)
        t_gfin = t + out_c.dur
        useful_end = jnp.where(out_c.failed,
                               t + s + out_c.ckpt_done, t_gfin)
        requeued = do_sched & (out_c.failed | out_c.killed)
        eps = jnp.asarray(CREDIT_EPS, dtype)
        p_cnt, p_lo, p_frag = _pool_decode(pool_code[j, lanes], N)
        has_pool = p_cnt > 0
        qlo = jnp.where(has_pool, p_lo, head[j, lanes])
        res0 = jnp.where(has_pool, jnp.maximum(
            head_w - prefw[j, qlo] - pool_w[j, lanes], zero_f), zero_f)
        walk_ok = ~(has_pool & p_frag)
        avail = res0 + out_c.credit
        span_code = 1 + qlo * (N + 1) + tail[j, lanes]
        rem_agg = work - out_c.credit
        a_has = requeued & (rem_agg > eps)
        a_cnt = (tail[j, lanes] - head[j, lanes]) + p_cnt
        code = jnp.where(requeued & walk_ok, span_code,
                         jnp.where(a_has, -a_cnt, zero_i))
        stash_w = jnp.where(
            requeued & walk_ok, avail,
            jnp.where(a_has, jnp.maximum(rem_agg, zero_f), zero_f))
        stash_old = jnp.where(a_has & ~walk_ok, oldest[j, lanes], INF)
    busy_inc = m_grp.astype(dtype) * _window_overlap(t, t_gfin, t_last)
    useful_inc = m_grp.astype(dtype) * _window_overlap(
        t + s, useful_end, t_last)
    if has_chaos:
        busy_inc, useful_inc = jax.lax.optimization_barrier(
            (busy_inc, useful_inc))

    # event step (submission or completion), masked unless do_event
    t_sub = jnp.where(next_sub < N,
                      submit[jnp.minimum(next_sub, N - 1)], INF)
    eslot = jnp.argmin(grp_end, axis=0)
    t_efin = grp_end[eslot, lanes]
    take_sub = t_sub <= t_efin
    t_new = jnp.where(take_sub, t_sub, t_efin)
    qlen = jnp.sum(tail - head, axis=0).astype(dtype)
    if has_chaos:
        qlen = qlen + jnp.sum(pool_code % (N + 1), axis=0).astype(dtype)
    q_inc = qlen * _window_overlap(t, t_new, t_last)
    if has_chaos:
        q_inc = jax.lax.optimization_barrier(q_inc)
    sub_j = jtypes[jnp.minimum(next_sub, N - 1)]

    do_submit = do_event & take_sub
    do_finish = do_event & ~take_sub

    new_head = head.at[j, lanes].set(
        jnp.where(do_sched, tail[j, lanes], head[j, lanes]))
    new_tail = tail.at[sub_j, lanes].add(
        jnp.where(do_submit, one_i, zero_i))
    new_m_free = (m_free - jnp.where(do_sched, m_grp, zero_i)
                  + jnp.where(do_finish, grp_m[eslot, lanes], zero_i))
    new_grp_end = grp_end.at[sslot, lanes].set(
        jnp.where(do_sched, t_gfin, grp_end[sslot, lanes]))
    new_grp_end = new_grp_end.at[eslot, lanes].set(
        jnp.where(do_finish, INF, new_grp_end[eslot, lanes]))
    new_grp_m = grp_m.at[sslot, lanes].set(
        jnp.where(do_sched, m_grp, grp_m[sslot, lanes]))
    new_grp_m = new_grp_m.at[eslot, lanes].set(
        jnp.where(do_finish, zero_i, new_grp_m[eslot, lanes]))

    y_key = jnp.where(do_sched, j * (N + 1) + tail[j, lanes], key_pad)
    y_t = jnp.where(do_sched, t, zero_f)
    y_m = jnp.where(do_sched, m_grp, zero_i)
    y_hw = jnp.where(do_sched, head_w, zero_f)

    if not has_chaos:
        new_pool_w, new_pool_oldest, new_pool_code = (
            pool_w, pool_oldest, pool_code)
        new_grp_jtype = grp_jtype
        new_grp_rem_w, new_grp_rem_cnt, new_grp_rem_oldest = (
            grp_rem_w, grp_rem_cnt, grp_rem_oldest)
        new_lost, new_fail, new_kill = lost_work, failures, straggler_kills
        new_req, new_reqj = requeues, requeued_jobs
    else:
        # finish resolves the stashed requeue span into its member set
        # (the deferred ClusterSim credit walk) and merges it back into
        # the per-type pool — same chain as packet_scan_step, per lane
        j_f = grp_jtype[eslot, lanes]
        pw_ns = SimpleNamespace(n_jobs=N, tj_prefw=prefw, tj_submit=tsub)
        cnt_r, rem_w_r, rem_old_r, rem_lo_r, rem_hi_r, walk_r = (
            _resolve_remnant(pw_ns, j_f, grp_rem_cnt[eslot, lanes],
                             grp_rem_w[eslot, lanes],
                             grp_rem_oldest[eslot, lanes], dtype))
        old_cnt, old_lo, old_frag = _pool_decode(pool_code[j_f, lanes], N)
        inc = do_finish & (cnt_r > 0)
        was_empty = old_cnt == 0
        contig = rem_hi_r == head[j_f, lanes]
        frag = jnp.where(
            inc, old_frag | ~walk_r | ~was_empty | ~contig, old_frag)
        new_lo = jnp.where(was_empty, rem_lo_r,
                           jnp.minimum(old_lo, rem_lo_r))
        new_code = ((new_lo * 2 + frag.astype(jnp.int32))
                    * (N + 1) + old_cnt + cnt_r)
        new_pool_w = pool_w.at[j, lanes].set(
            jnp.where(do_sched, zero_f, pool_w[j, lanes]))
        new_pool_w = new_pool_w.at[j_f, lanes].add(
            jnp.where(do_finish, rem_w_r, zero_f))
        new_pool_oldest = pool_oldest.at[j, lanes].set(
            jnp.where(do_sched, INF, pool_oldest[j, lanes]))
        new_pool_oldest = new_pool_oldest.at[j_f, lanes].min(
            jnp.where(do_finish, rem_old_r, INF))
        new_pool_code = pool_code.at[j, lanes].set(
            jnp.where(do_sched, zero_i, pool_code[j, lanes]))
        new_pool_code = new_pool_code.at[j_f, lanes].set(
            jnp.where(inc, new_code, new_pool_code[j_f, lanes]))
        new_grp_jtype = grp_jtype.at[sslot, lanes].set(
            jnp.where(do_sched, j, grp_jtype[sslot, lanes]))
        new_grp_rem_w = grp_rem_w.at[sslot, lanes].set(
            jnp.where(do_sched, stash_w, grp_rem_w[sslot, lanes]))
        new_grp_rem_w = new_grp_rem_w.at[eslot, lanes].set(
            jnp.where(do_finish, zero_f, new_grp_rem_w[eslot, lanes]))
        new_grp_rem_cnt = grp_rem_cnt.at[sslot, lanes].set(
            jnp.where(do_sched, code, grp_rem_cnt[sslot, lanes]))
        new_grp_rem_cnt = new_grp_rem_cnt.at[eslot, lanes].set(
            jnp.where(do_finish, zero_i, new_grp_rem_cnt[eslot, lanes]))
        new_grp_rem_oldest = grp_rem_oldest.at[sslot, lanes].set(
            jnp.where(do_sched, stash_old, grp_rem_oldest[sslot, lanes]))
        new_grp_rem_oldest = new_grp_rem_oldest.at[eslot, lanes].set(
            jnp.where(do_finish, INF, new_grp_rem_oldest[eslot, lanes]))
        new_lost = lost_work + jnp.where(do_sched, out_c.lost, zero_f)
        new_fail = failures + jnp.where(do_sched & out_c.failed,
                                        one_i, zero_i)
        new_kill = straggler_kills + jnp.where(
            do_sched & out_c.killed & ~out_c.failed, one_i, zero_i)
        new_req = requeues + jnp.where(requeued, one_i, zero_i)
        new_reqj = requeued_jobs + jnp.where(do_finish, cnt_r, zero_i)

    out[0][...] = jnp.where(do_event, t_new, t)[None, :]
    out[1][...] = (next_sub + jnp.where(do_submit, one_i, zero_i))[None, :]
    out[2][...] = new_head
    out[3][...] = new_tail
    out[4][...] = new_m_free[None, :]
    out[5][...] = new_grp_end
    out[6][...] = new_grp_m
    out[7][...] = (qlen_int + jnp.where(do_event, q_inc, zero_f))[None, :]
    out[8][...] = (busy_ns + jnp.where(do_sched, busy_inc, zero_f))[None, :]
    out[9][...] = (useful_ns +
                   jnp.where(do_sched, useful_inc, zero_f))[None, :]
    out[10][...] = (n_groups + jnp.where(do_sched, one_i, zero_i))[None, :]
    out[11][...] = new_pool_w
    out[12][...] = new_pool_oldest
    out[13][...] = new_pool_code
    out[14][...] = new_grp_jtype
    out[15][...] = new_grp_rem_w
    out[16][...] = new_grp_rem_cnt
    out[17][...] = new_grp_rem_oldest
    out[18][...] = new_lost[None, :]
    out[19][...] = new_fail[None, :]
    out[20][...] = new_kill[None, :]
    out[21][...] = new_req[None, :]
    out[22][...] = new_reqj[None, :]
    y_out[0][...] = y_key.astype(jnp.int32)[None, :]
    y_out[1][...] = y_t[None, :]
    y_out[2][...] = y_m.astype(jnp.int32)[None, :]
    y_out[3][...] = y_hw[None, :]
