"""Pure-jnp reference for the fused event-step kernel.

Unlike `packet_select`, the reference here is not a re-statement of the
math — it IS the production XLA engine's step: `repro.core.des` extracts
the scan step as the module-level `packet_scan_step`, the XLA engine
scans it directly, and the Pallas kernel body vectorizes the same
source over the lane axis. Re-exporting it as `ref` keeps the kernels
convention (every kernel package ships a `ref.py` the tests diff
against) while guaranteeing the reference can never drift from what
`simulate_packet_scan(step_impl="xla")` actually runs.

`packet_step_ref` applies the step to one lane's scalar state, exactly
as the equivalence tests consume it.
"""
from __future__ import annotations

from repro.core.des import packet_scan_step as packet_step_ref

__all__ = ["packet_step_ref"]
