"""jit'd wrapper with CPU interpret fallback."""
from __future__ import annotations

import jax

from repro.kernels.packet_select.kernel import packet_select


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def fused_packet_select(sum_w, s_j, p_j, oldest, t_max, nonempty, now, k,
                        m_free):
    return packet_select(sum_w, s_j, p_j, oldest, t_max, nonempty, now, k,
                         m_free, interpret=_on_cpu())
