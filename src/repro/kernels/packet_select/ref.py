"""Pure-jnp oracle: the Packet policy functions from repro.core.packet."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packet


def packet_select_ref(sum_w, s_j, p_j, oldest, t_max, nonempty, now, k,
                      m_free):
    """Batched reference of the fused scheduling decision (see kernel.py)."""

    def one(sum_w, s_j, p_j, oldest, t_max, nonempty, now, k, m_free):
        w = packet.queue_weights(sum_w, s_j, p_j, oldest, now, t_max,
                                 nonempty > 0)
        j = jnp.argmax(w)
        work = sum_w[j]
        m = packet.group_nodes(work, k, s_j[j],
                               m_free.astype(jnp.int32)).astype(jnp.float32)
        dur = packet.group_duration(work, s_j[j], jnp.maximum(m, 1.0))
        return j.astype(jnp.int32), m, dur, work

    return jax.vmap(one)(sum_w, s_j, p_j, oldest, t_max, nonempty, now, k,
                         m_free)
