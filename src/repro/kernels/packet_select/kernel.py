"""Fused Packet scheduling step (paper §5 Steps 1-4) — Pallas TPU kernel.

One DES scheduling decision = queue weights over h types, argmax, node
count, duration — a handful of [H]-wide vector ops. Inside the vmapped
sweep (hundreds of (k, S) experiments in flight) this is the innermost hot
loop; fusing it into a single VMEM-resident kernel removes per-op dispatch
and keeps the whole decision on registers/VMEM. Batched over experiments
(grid axis 0), with H padded to the 128-lane boundary.

Outputs per experiment: selected type j*, m_group, group duration, and the
selected queue's total work (for state update on the host side of the DES).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _select_kernel(sumw_ref, sj_ref, pj_ref, oldest_ref, tmax_ref,
                   nonempty_ref, now_ref, k_ref, mfree_ref,
                   j_ref, m_ref, dur_ref, work_ref):
    sum_w = sumw_ref[0]
    s_j = jnp.maximum(sj_ref[0], 1e-9)
    now = now_ref[0, 0]
    k = jnp.maximum(k_ref[0, 0], 1e-9)
    m_free = mfree_ref[0, 0]

    # Step 2: W(T_j) = C_j * P_j * (1 + T_cur / T_max)
    c_j = sum_w / s_j
    t_cur = jnp.maximum(now - oldest_ref[0], 0.0)
    w = c_j * pj_ref[0] * (1.0 + t_cur / jnp.maximum(tmax_ref[0], 1e-9))
    w = jnp.where(nonempty_ref[0] > 0, w, NEG_INF)
    j = jnp.argmax(w)

    # Step 4: m_threshold = ceil(work / (k * s_j)); m_group = min(., m_free)
    work = sum_w[j]
    m_thr = jnp.maximum(jnp.ceil(work / (k * s_j[j])), 1.0)
    m_grp = jnp.maximum(jnp.minimum(m_thr, m_free), 0.0)
    dur = s_j[j] + work / jnp.maximum(m_grp, 1.0)

    j_ref[0, 0] = j.astype(jnp.int32)
    m_ref[0, 0] = m_grp
    dur_ref[0, 0] = dur
    work_ref[0, 0] = work


@functools.partial(jax.jit, static_argnames=("interpret",))
def packet_select(sum_w, s_j, p_j, oldest, t_max, nonempty, now, k, m_free,
                  *, interpret: bool = False):
    """Batched fused scheduling decision.

    sum_w, s_j, p_j, oldest, t_max: [N, H] float32; nonempty: [N, H]
    (0/1 float32); now, k, m_free: [N] float32.
    Returns (j [N] int32, m_group [N], duration [N], work [N]).
    """
    N, H = sum_w.shape
    pad = (-H) % 128
    if pad:
        padw = ((0, 0), (0, pad))
        sum_w = jnp.pad(sum_w, padw)
        s_j = jnp.pad(s_j, padw, constant_values=1.0)
        p_j = jnp.pad(p_j, padw)
        oldest = jnp.pad(oldest, padw)
        t_max = jnp.pad(t_max, padw, constant_values=1.0)
        nonempty = jnp.pad(nonempty, padw)
    Hp = H + pad
    vec = lambda: pl.BlockSpec((1, Hp), lambda i: (i, 0))
    scl = lambda: pl.BlockSpec((1, 1), lambda i: (i, 0))
    outs = pl.pallas_call(
        _select_kernel,
        grid=(N,),
        in_specs=[vec(), vec(), vec(), vec(), vec(), vec(),
                  scl(), scl(), scl()],
        out_specs=[scl(), scl(), scl(), scl()],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(sum_w, s_j, p_j, oldest, t_max, nonempty,
      now[:, None], k[:, None], m_free[:, None])
    j, m, dur, work = (o[:, 0] for o in outs)
    return j, m, dur, work
