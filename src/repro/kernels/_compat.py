"""Version-compat shims for the Pallas TPU API."""
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes this as TPUCompilerParams, newer versions as
# CompilerParams; fail loudly at import time if neither exists.
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - future-jax guard
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; update repro.kernels._compat for this jax "
        "version")
