"""jit'd wrapper used by repro.models.hybrid when attention_impl='pallas'."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import lru_chunked


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def chunked_lru(a, bx, h0=None):
    """Model-facing API: decay a (not log) as produced by rglru_gates.

    a, bx: [B, S, D]; returns h [B, S, D] (float32)."""
    log_a = jnp.log(jnp.maximum(a, 1e-37))
    h, _ = lru_chunked(log_a, bx, h0, interpret=_on_cpu())
    return h
