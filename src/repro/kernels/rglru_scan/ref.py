"""Pure-jnp oracle for the diagonal linear recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lru_ref(log_a, b, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + b_t via associative scan.

    log_a, b: [B, S, D]; h0: optional [B, D]. Returns (h, h_last)."""
    a = jnp.exp(log_a.astype(jnp.float32))
    b = b.astype(jnp.float32)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h, h[:, -1]
