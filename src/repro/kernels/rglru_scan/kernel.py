"""Chunked diagonal linear recurrence h_t = a_t * h_{t-1} + b_t — Pallas TPU.

The RG-LRU (and any diagonal SSM) is a first-order recurrence with
per-feature decay. A naive scan is S sequential vector ops — latency-bound
on TPU. The TPU-native form used here processes the sequence in chunks:

  within a chunk (length c), with La = cumsum(log a):
      h_t = exp(La_t) * h_0  +  sum_{s<=t} exp(La_t - La_s) * b_s
  i.e. a causal [c, c] decay-weight window applied per feature — dense
  VPU work on VMEM-resident tiles instead of S dependent steps; the carry
  h_chunk_end moves between chunks through VMEM scratch across the
  sequential innermost grid axis.

Inputs are log-decays (callers have log a analytically: RG-LRU's
log a = -c * softplus(Lambda) * r), so the kernel never takes log of a
denormal. exp(La_t - La_s) <= 1 for s <= t: always stable.

Grid: (B, D/bd, S/c) with the chunk axis innermost-sequential; feature
blocks bd are lane-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_CHUNK = 128
DEFAULT_BD = 256


def _lru_kernel(loga_ref, b_ref, h0_ref, o_ref, hlast_ref, carry_ref, *,
                chunk: int, use_h0: bool):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        if use_h0:
            carry_ref[...] = h0_ref[0].astype(jnp.float32)
        else:
            carry_ref[...] = jnp.zeros_like(carry_ref)

    la = loga_ref[0].astype(jnp.float32)                # [c, bd]
    b = b_ref[0].astype(jnp.float32)                    # [c, bd]
    La = jnp.cumsum(la, axis=0)                          # [c, bd]
    # W[t, s, d] = exp(La_t - La_s) for s <= t else 0
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (ti >= si)[:, :, None]
    W = jnp.where(causal, jnp.exp(La[:, None, :] - La[None, :, :]), 0.0)
    h = (W * b[None, :, :]).sum(axis=1)                  # [c, bd]
    h = h + jnp.exp(La) * carry_ref[...][None]
    o_ref[0] = h.astype(o_ref.dtype)
    carry_ref[...] = h[-1]

    @pl.when(ic == pl.num_programs(2) - 1)
    def _flush():
        hlast_ref[0] = h[-1].astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def lru_chunked(log_a, b, h0=None, *, chunk: int = DEFAULT_CHUNK,
                bd: int = DEFAULT_BD, interpret: bool = False):
    """log_a, b: [B, S, D]; h0: optional [B, D] initial state.

    Returns (h [B, S, D], h_last [B, D])."""
    B, S, D = log_a.shape
    chunk = min(chunk, S)
    bd = min(bd, D)
    ps = (-S) % chunk
    pd = (-D) % bd
    if ps or pd:
        padnb = ((0, 0), (0, ps), (0, pd))
        log_a = jnp.pad(log_a, padnb)   # log a = 0 -> a = 1: carries state
        b = jnp.pad(b, padnb)           # b = 0: no contribution
    Sp, Dp = S + ps, D + pd
    use_h0 = h0 is not None
    if h0 is None:
        h0 = jnp.zeros((B, Dp), b.dtype)
    elif pd:
        h0 = jnp.pad(h0, ((0, 0), (0, pd)))

    grid = (B, Dp // bd, Sp // chunk)
    kern = functools.partial(_lru_kernel, chunk=chunk, use_h0=use_h0)
    h, hlast = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, chunk, bd), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, bd), lambda ib, id_, ic: (ib, id_)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((1, bd), lambda ib, id_, ic: (ib, id_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Dp), b.dtype),
            jax.ShapeDtypeStruct((B, Dp), b.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, b, h0)
    return h[:, :S, :D], hlast[:, :D]
