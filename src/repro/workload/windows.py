"""Rolling windows and drift scenarios over the Lublin generator.

This is the workload-facing half of the streaming Packet service
(`repro.service`): instead of handing the simulator one monolithic trace,
the service consumes the trace as a sequence of fixed-size job windows and
retunes the scale ratio k once per window ("control tick").

Two guarantees anchor everything downstream:

* **Window-is-a-slice, bitwise.** `slice_window(wl, lo, hi)` returns
  arrays that are exact numpy slices of the full trace — same bits, no
  regeneration, no rounding. With ``rebase=True`` (the simulation-facing
  default) only `submit` is shifted so the window starts at t=0; the shift
  subtracts the window's first submit time in float64, which is itself
  deterministic, so windowed runs are reproducible from (seed, lo, hi)
  alone. `tests/test_windows.py` pins this in both dtypes.

* **Fixed window shapes.** `window_bounds` yields only *full* windows of
  `window_jobs` jobs (a partial tail is dropped, reported via
  `n_dropped`). Every window therefore packs to a `PackedWorkload` with
  identical static shapes, so the sweep jit caches
  (`repro.core.sweep._packet_lanes`) are traced once on the first control
  tick and hit on every later tick.

Drift scenarios: `drift_workload` concatenates per-segment
`generate_workload` traces (per-segment load / homogeneity knobs, seeded
from a base seed) with submit times shifted onto a common clock, giving
seed-stable intensity/homogeneity ramps and step changes. The canonical
set used by `benchmarks/controller_sweep.py` lives in `drift_scenarios`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Mapping, Sequence

import numpy as np

from .lublin import Workload, WorkloadParams, generate_workload


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """How to cut a trace into control-tick windows.

    window_jobs: jobs per window (the static shape every tick shares).
    stride_jobs: jobs between consecutive window starts; defaults to
        window_jobs (non-overlapping tumbling windows). A smaller stride
        gives overlapping rolling windows.
    rebase: shift each window's submit times so the window opens at t=0
        (what the DES expects); rebase=False keeps the raw bitwise slice.
    """

    window_jobs: int
    stride_jobs: int | None = None
    rebase: bool = True

    def __post_init__(self):
        if self.window_jobs < 1:
            raise ValueError(f"window_jobs must be >= 1, got {self.window_jobs}")
        if self.stride_jobs is not None and self.stride_jobs < 1:
            raise ValueError(f"stride_jobs must be >= 1, got {self.stride_jobs}")

    @property
    def stride(self) -> int:
        return self.window_jobs if self.stride_jobs is None else self.stride_jobs


def window_bounds(n_jobs: int, spec: WindowSpec) -> list[tuple[int, int]]:
    """[lo, hi) job-index bounds of every *full* window in a trace.

    Only windows with exactly ``spec.window_jobs`` jobs are returned so all
    windows share one static shape; a short tail is dropped (see
    `n_dropped`). Empty list if the trace is shorter than one window.
    """
    if n_jobs < 0:
        raise ValueError(f"n_jobs must be >= 0, got {n_jobs}")
    bounds = []
    lo = 0
    while lo + spec.window_jobs <= n_jobs:
        bounds.append((lo, lo + spec.window_jobs))
        lo += spec.stride
    return bounds


def n_dropped(n_jobs: int, spec: WindowSpec) -> int:
    """Jobs past the last full window (never simulated by the service)."""
    bounds = window_bounds(n_jobs, spec)
    return n_jobs if not bounds else n_jobs - bounds[-1][1]


def slice_window(wl: Workload, lo: int, hi: int, rebase: bool = True) -> Workload:
    """Jobs [lo, hi) of a trace as a Workload.

    With rebase=False every array is a bitwise numpy slice of the parent
    (zero-copy views). With rebase=True (default) `submit` is shifted by
    ``-submit[lo]`` in float64 so the window starts at t=0 — the form the
    DES measures over — while runtime/nodes/work/jtype stay bitwise
    slices. Jobs in a trace are sorted by submit, so [lo, hi) is also a
    contiguous time interval.
    """
    if not (0 <= lo < hi <= len(wl.submit)):
        raise ValueError(
            f"window [{lo}, {hi}) out of range for trace of {len(wl.submit)} jobs")
    submit = wl.submit[lo:hi]
    if rebase:
        submit = submit - wl.submit[lo]
    params = dataclasses.replace(
        wl.params, n_jobs=hi - lo,
        horizon=float(max(wl.submit[hi - 1] - wl.submit[lo], 1.0)))
    return Workload(submit=submit, runtime=wl.runtime[lo:hi],
                    nodes=wl.nodes[lo:hi], work=wl.work[lo:hi],
                    jtype=wl.jtype[lo:hi], params=params)


def iter_windows(wl: Workload, spec: WindowSpec
                 ) -> Iterator[tuple[int, int, Workload]]:
    """Yield (lo, hi, window) for every full window of a trace in order."""
    for lo, hi in window_bounds(len(wl.submit), spec):
        yield lo, hi, slice_window(wl, lo, hi, rebase=spec.rebase)


def iter_windows_batch(flows: Mapping[str, Workload], spec: WindowSpec
                       ) -> Iterator[tuple[str, int, int, Workload]]:
    """`iter_windows` over a name -> trace mapping (e.g. batch replicas)."""
    for name, wl in flows.items():
        for lo, hi, win in iter_windows(wl, spec):
            yield name, lo, hi, win


def _broadcast(value, n: int, name: str) -> list:
    if isinstance(value, (list, tuple, np.ndarray)):
        seq = list(value)
        if len(seq) != n:
            raise ValueError(
                f"{name} has {len(seq)} entries but the scenario has "
                f"{n} segments")
        return seq
    return [value] * n


def drift_workload(base: WorkloadParams,
                   *,
                   n_segments: int | None = None,
                   loads: float | Sequence[float] | None = None,
                   homogeneous: bool | Sequence[bool] | None = None,
                   homog_shrinks: float | Sequence[float] | None = None,
                   ) -> Workload:
    """A seed-stable trace whose statistics drift across segments.

    The trace is S back-to-back `generate_workload` segments, each with
    `base.n_jobs // S` jobs over `base.horizon / S` seconds; segment i
    uses seed ``base.seed + i`` and may override load / homogeneity /
    homog_shrink. Segment submit times are shifted onto a common clock
    (segment i occupies [i, i+1) * horizon/S — the generator pins each
    segment's arrivals to exactly its horizon, so the concatenation is
    nondecreasing). M (nodes) and n_types are fixed across segments so
    every window of the result has the same `workload_statics` and one
    jit cache serves the whole stream.

    Segment count comes from n_segments or the longest per-segment
    sequence; every sequence argument must match it.
    """
    seqs = [len(v) for v in (loads, homogeneous, homog_shrinks)
            if isinstance(v, (list, tuple, np.ndarray))]
    if n_segments is None:
        if not seqs:
            raise ValueError(
                "pass n_segments or at least one per-segment sequence")
        n_segments = max(seqs)
    if n_segments < 1:
        raise ValueError(f"n_segments must be >= 1, got {n_segments}")
    loads = _broadcast(base.load if loads is None else loads,
                       n_segments, "loads")
    homogeneous = _broadcast(
        base.homogeneous if homogeneous is None else homogeneous,
        n_segments, "homogeneous")
    homog_shrinks = _broadcast(
        base.homog_shrink if homog_shrinks is None else homog_shrinks,
        n_segments, "homog_shrinks")

    seg_jobs = base.n_jobs // n_segments
    if seg_jobs < 1:
        raise ValueError(
            f"n_jobs={base.n_jobs} too small for {n_segments} segments")
    seg_horizon = float(base.horizon) / n_segments

    parts = []
    for i in range(n_segments):
        params = dataclasses.replace(
            base, n_jobs=seg_jobs, horizon=seg_horizon,
            load=float(loads[i]), homogeneous=bool(homogeneous[i]),
            homog_shrink=float(homog_shrinks[i]), seed=base.seed + i)
        seg = generate_workload(params)
        parts.append(dataclasses.replace(seg, submit=seg.submit + i * seg_horizon))

    submit = np.concatenate([p.submit for p in parts])
    if np.any(np.diff(submit) < 0):  # pragma: no cover - segments are pinned
        raise AssertionError("drift segments produced non-monotone submits")
    out_params = dataclasses.replace(base, n_jobs=seg_jobs * n_segments)
    return Workload(
        submit=submit,
        runtime=np.concatenate([p.runtime for p in parts]),
        nodes=np.concatenate([p.nodes for p in parts]),
        work=np.concatenate([p.work for p in parts]),
        jtype=np.concatenate([p.jtype for p in parts]),
        params=out_params)


def drift_scenarios(n_jobs: int = 4000, nodes: int = 100, seed: int = 0,
                    n_segments: int = 8) -> dict[str, Workload]:
    """The canonical controller-study scenarios.

    ``steady`` is the zero-drift control (same segmented construction, so
    any regret it shows is window noise, not drift); the other four drift
    either arrival intensity (offered load) or job homogeneity, as a ramp
    or a step. All share M=nodes and n_types, so all windows of all
    scenarios hit one jit cache.
    """
    base = WorkloadParams(n_jobs=n_jobs, nodes=nodes, load=0.90,
                          homogeneous=True, seed=seed, daily_amplitude=0.3)
    s = n_segments
    ramp = np.linspace(0.82, 0.96, s)
    shrink_ramp = np.linspace(0.15, 0.95, s)
    return {
        "steady": drift_workload(base, loads=[0.90] * s),
        "intensity_ramp": drift_workload(base, loads=ramp),
        "intensity_step": drift_workload(
            base, loads=[0.85] * (s // 2) + [0.95] * (s - s // 2)),
        "homogeneity_ramp": drift_workload(base, homog_shrinks=shrink_ramp),
        "homogeneity_step": drift_workload(
            base, homogeneous=[True] * (s // 2) + [False] * (s - s // 2)),
    }
