"""Lublin–Feitelson supercomputer workload generator (JAX/numpy).

Implements the statistical model of Lublin & Feitelson, "The Workload on
Parallel Supercomputers: Modeling the Characteristics of Rigid Jobs",
JPDC 2003 [29 in the paper] — the generator the paper's 6 workflows are
built from:

  * node counts: serial fraction + power-of-two bias + two-stage log-uniform,
  * runtimes: ln(runtime) ~ hyper-gamma, mixture weight linear in log2(nodes),
  * arrivals: heavy-tailed gaps modulated by a daily cycle,

plus the paper's "modified generator" that produces *more homogeneous*
workflows (reduced runtime variance, narrower size range), and load
calibration: runtimes are scaled so the *calculated load*
``rho = sum(e_i * n_i) / (M * horizon)`` hits the requested 0.85 / 0.90 / 0.95.

The paper's experiments: 5000 jobs over 4 days, 8 job types,
M = 500 nodes (heterogeneous flows) or M = 100 (homogeneous flows).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Mapping, Optional

import numpy as np

DAY = 86400.0

# Lublin's published "batch" model constants.
SERIAL_PROB = 0.244
POW2_PROB = 0.75
ULOW = 0.8          # log2 of smallest parallel size
UPROB = 0.86        # probability of the low range of the two-stage uniform
# ln(runtime) hyper-gamma:
A1, B1 = 4.2, 0.94
A2, B2 = 312.0, 0.03
PA, PB = -0.0054, 0.78
# ln(inter-arrival gap) gamma (daytime model):
AARR, BARR = 10.23, 0.4871


@dataclasses.dataclass(frozen=True)
class WorkloadParams:
    n_jobs: int = 5000
    horizon: float = 4 * DAY          # submit window (last submit ~ horizon)
    n_types: int = 8                  # paper: 8 job types
    nodes: int = 500                  # M: 500 heterogeneous / 100 homogeneous
    load: float = 0.85                # calculated load rho
    homogeneous: bool = False         # paper's "modified generator"
    seed: int = 0
    daily_amplitude: float = 0.6      # arrival-rate daily cycle strength
    homog_shrink: float = 0.25        # ln-runtime variance shrink factor


@dataclasses.dataclass(frozen=True)
class Workload:
    """A generated workflow. All arrays are length n_jobs, sorted by submit."""
    submit: np.ndarray       # submit times, seconds, float64
    runtime: np.ndarray      # e_i: runtime on n_i nodes, seconds
    nodes: np.ndarray        # n_i: rigid requested node count
    work: np.ndarray         # w_i = e_i * n_i (single-node duration, node-s)
    jtype: np.ndarray        # tau_i in [0, n_types)
    params: WorkloadParams

    @property
    def n_jobs(self) -> int:
        return int(self.submit.shape[0])

    @property
    def horizon(self) -> float:
        return float(self.submit[-1])

    def calculated_load(self) -> float:
        return float(self.work.sum() / (self.params.nodes * self.params.horizon))

    def init_time_for_proportion(self, s_prop: float) -> float:
        """Constant per-job initialization time s giving average init
        proportion S = n*s / (n*s + sum(e_i))  =>  s = S/(1-S) * mean(e)."""
        if not (0.0 <= s_prop < 1.0):
            raise ValueError(f"init proportion must be in [0,1), got {s_prop}")
        return float(s_prop / (1.0 - s_prop) * self.runtime.mean())

    def golden_digest(self) -> dict[str, str]:
        """Stable per-array content digests for regression pinning.

        Returns sha256 hex digests of `submit`/`runtime`/`nodes`/`jtype`,
        floats rounded to 1e-6 s before hashing so bit-identical generator
        output is required only up to libm rounding. Workload drift (an
        accidental generator change) then breaks the determinism suite
        instead of masquerading as a simulator regression downstream.
        """
        def h(a, decimals=None):
            a = np.ascontiguousarray(
                np.asarray(a, np.float64).round(decimals) if decimals is not None
                else np.asarray(a, np.int64))
            return hashlib.sha256(a.tobytes()).hexdigest()

        return {"submit": h(self.submit, 6), "runtime": h(self.runtime, 6),
                "nodes": h(self.nodes), "jtype": h(self.jtype)}


def _hyper_gamma_ln_runtime(rng: np.random.Generator, log2n: np.ndarray) -> np.ndarray:
    """ln(runtime) ~ p*Gamma(a1,b1) + (1-p)*Gamma(a2,b2), p linear in log2(n)."""
    p = np.clip(PA * log2n + PB, 0.01, 0.99)
    pick1 = rng.random(log2n.shape) < p
    g1 = rng.gamma(A1, B1, size=log2n.shape)
    g2 = rng.gamma(A2, B2, size=log2n.shape)
    return np.where(pick1, g1, g2)


def _node_counts(rng: np.random.Generator, shape, max_nodes: int,
                 homogeneous: bool) -> np.ndarray:
    """Lublin two-stage log-uniform with power-of-two bias.

    `shape` may be an int (one workload) or a tuple ``(R, n)`` (R replica
    workloads drawn in one vectorized pass — see `generate_workload_batch`).
    """
    uhi = np.log2(max_nodes)
    umed = (uhi - ULOW) * 0.625 + ULOW      # Lublin: medium point
    if homogeneous:
        # The paper's "modified generator" is described only as "more
        # homogeneous"; calibrated against the paper's absolute queue-time
        # scale (Tables 1-2) this matches 8-32-node jobs: mean work per job
        # is pinned by the load calibration, so wider jobs mean shorter
        # runtimes, which reproduces the paper's 50%-init median collapse
        # (Fig 7) and the 5%-top / 50%-bottom plateau ordering (Fig 8).
        # See EXPERIMENTS.md §Paper-repro for the calibration study.
        u = rng.uniform(3.0, 5.0, size=shape)
        return np.clip(np.round(2.0 ** u), 1, max_nodes).astype(np.int64)
    serial = rng.random(shape) < SERIAL_PROB
    low = rng.random(shape) < UPROB
    u = np.where(low,
                 rng.uniform(ULOW, umed, size=shape),
                 rng.uniform(umed, uhi, size=shape))
    pow2 = rng.random(shape) < POW2_PROB
    size = np.where(pow2, np.round(u), u)
    nodes = np.clip(np.round(2.0 ** size), 1, max_nodes).astype(np.int64)
    return np.where(serial, 1, nodes)


def _arrivals(rng: np.random.Generator, shape, horizon: float,
              amplitude: float) -> np.ndarray:
    """Heavy-tailed gaps (exp of gamma), warped by a daily cycle, rescaled to
    fill [0, horizon]. Shape-polymorphic along the leading axes: each row of
    a ``(R, n)`` draw is an independent arrival process."""
    ln_gap = rng.gamma(AARR, BARR, size=shape)
    gaps = np.exp(ln_gap - ln_gap.mean(axis=-1, keepdims=True))  # mean ~1
    t = np.cumsum(gaps, axis=-1)
    t = t / t[..., -1:] * horizon
    # daily cycle: compress gaps at daytime peak, stretch at night, by warping
    # time through the inverse cumulative rate of
    # r(t) = 1 + A*cos(2*pi*(t - peak)/DAY).
    peak = 0.58 * DAY                              # ~14:00 peak
    phase = 2 * np.pi * (t - peak) / DAY
    # cumulative of r is t + A*DAY/(2pi)*sin(phase); invert approximately by
    # one Newton step from identity (amplitude < 1 keeps it monotone).
    warped = t - amplitude * DAY / (2 * np.pi) * np.sin(phase)
    warped = np.sort(warped - warped.min(axis=-1, keepdims=True), axis=-1)
    return warped / np.maximum(warped[..., -1:], 1e-9) * horizon


def generate_workload(params: WorkloadParams) -> Workload:
    rng = np.random.default_rng(params.seed)
    n = params.n_jobs

    nodes = _node_counts(rng, n, params.nodes, params.homogeneous)
    ln_rt = _hyper_gamma_ln_runtime(rng, np.log2(nodes.astype(np.float64)))
    if params.homogeneous:
        # paper's modified generator: shrink runtime spread around the mean
        ln_rt = ln_rt.mean() + (ln_rt - ln_rt.mean()) * params.homog_shrink
    runtime = np.exp(ln_rt)
    runtime = np.clip(runtime, 1.0, 2 * DAY)

    submit = _arrivals(rng, n, params.horizon, params.daily_amplitude)

    # job types: skewed categorical (a few popular types), as in production.
    type_weights = 1.0 / np.arange(1, params.n_types + 1)
    type_weights /= type_weights.sum()
    jtype = rng.choice(params.n_types, size=n, p=type_weights).astype(np.int64)

    # calibrate runtimes so the calculated load matches params.load exactly
    raw_load = (runtime * nodes).sum() / (params.nodes * params.horizon)
    runtime = runtime * (params.load / raw_load)

    order = np.argsort(submit, kind="stable")
    submit, runtime, nodes, jtype = (a[order] for a in (submit, runtime, nodes, jtype))
    work = runtime * nodes
    return Workload(submit=submit, runtime=runtime, nodes=nodes.astype(np.int64),
                    work=work, jtype=jtype, params=params)


def generate_workload_batch(params: WorkloadParams,
                            n_replicas: int,
                            name_fmt: str = "rep{r:03d}") -> dict[str, Workload]:
    """R replica workloads of one parameter set, drawn in ONE vectorized pass.

    Multi-seed replication studies (error bars over the paper grid) need R
    same-shape workloads; calling `generate_workload` R times restarts the
    generator pipeline per seed. Here every distribution is drawn once with
    shape ``(R, n_jobs)`` from a single stream seeded by ``params.seed``,
    then split row-wise, so the host cost is one pass over the batch. All
    replicas share every static — ``(nodes, n_jobs, n_types)`` — by
    construction, so the whole batch lands in one sweep cohort
    (`repro.core.cohort.group_workloads`) and runs as one batched program.

    Replica r is NOT the same stream as ``generate_workload(seed=...)`` for
    any seed; determinism is per ``(params.seed, n_replicas)`` batch.
    """
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    rng = np.random.default_rng(params.seed)
    shape = (n_replicas, params.n_jobs)

    nodes = _node_counts(rng, shape, params.nodes, params.homogeneous)
    ln_rt = _hyper_gamma_ln_runtime(rng, np.log2(nodes.astype(np.float64)))
    if params.homogeneous:
        mu = ln_rt.mean(axis=-1, keepdims=True)
        ln_rt = mu + (ln_rt - mu) * params.homog_shrink
    runtime = np.clip(np.exp(ln_rt), 1.0, 2 * DAY)

    submit = _arrivals(rng, shape, params.horizon, params.daily_amplitude)

    type_weights = 1.0 / np.arange(1, params.n_types + 1)
    type_weights /= type_weights.sum()
    jtype = rng.choice(params.n_types, size=shape,
                       p=type_weights).astype(np.int64)

    # per-replica load calibration, exactly as in generate_workload
    raw_load = (runtime * nodes).sum(axis=-1, keepdims=True) / \
        (params.nodes * params.horizon)
    runtime = runtime * (params.load / raw_load)

    out = {}
    for r in range(n_replicas):
        order = np.argsort(submit[r], kind="stable")
        sub_r, rt_r = submit[r][order], runtime[r][order]
        nd_r, jt_r = nodes[r][order], jtype[r][order]
        out[name_fmt.format(r=r)] = Workload(
            submit=sub_r, runtime=rt_r, nodes=nd_r.astype(np.int64),
            work=rt_r * nd_r, jtype=jt_r, params=params)
    return out


def workload_statics(wl: Workload) -> tuple[int, int, int]:
    """The static signature that decides batch compatibility: two workloads
    can share one stacked sweep program iff these (plus the simulation
    dtype/ring, which `repro.core.cohort.cohort_key` adds) all match."""
    return (int(wl.params.nodes), wl.n_jobs, int(wl.params.n_types))


def group_by_statics(flows: Mapping[str, Workload]) -> dict[tuple, list[str]]:
    """Workload names grouped by `workload_statics`, insertion-ordered.

    The workload-level half of cohort grouping: `repro.core.cohort` refines
    these groups with the simulation dtype to build `WorkloadCohort`s."""
    groups: dict[tuple, list[str]] = {}
    for name, wl in flows.items():
        groups.setdefault(workload_statics(wl), []).append(name)
    return groups


def paper_workloads(seed: int = 0) -> dict[str, Workload]:
    """The paper's 6 workflows: {hetero,homog} x load {0.85, 0.90, 0.95}.

    Heterogeneous flows run on 500 nodes, homogeneous on 100 (paper §6).
    """
    flows = {}
    for load in (0.85, 0.90, 0.95):
        flows[f"hetero{load:.2f}"] = generate_workload(WorkloadParams(
            nodes=500, load=load, homogeneous=False, seed=seed))
        flows[f"homog{load:.2f}"] = generate_workload(WorkloadParams(
            nodes=100, load=load, homogeneous=True, seed=seed + 1,
            daily_amplitude=0.3))
    return flows
