from repro.workload.lublin import WorkloadParams, Workload, generate_workload, paper_workloads

__all__ = ["WorkloadParams", "Workload", "generate_workload", "paper_workloads"]
