from repro.workload.lublin import (WorkloadParams, Workload,
                                   generate_workload, generate_workload_batch,
                                   paper_workloads, workload_statics)
from repro.workload.windows import (WindowSpec, drift_scenarios,
                                    drift_workload, iter_windows,
                                    iter_windows_batch, n_dropped,
                                    slice_window, window_bounds)

__all__ = ["WorkloadParams", "Workload", "generate_workload",
           "generate_workload_batch", "paper_workloads", "workload_statics",
           "WindowSpec", "drift_scenarios", "drift_workload", "iter_windows",
           "iter_windows_batch", "n_dropped", "slice_window", "window_bounds"]
