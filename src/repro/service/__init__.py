"""Streaming Packet service: a closed-loop, fault-aware scale-ratio
controller.

The offline stack answers "which scale ratio k *was* best" after a full
sweep; this package answers "which k *right now*" while jobs stream in.
It is a monitor → decide → actuate feedback loop, one iteration ("control
tick") per workload window, with the fused (k-candidate) lane engine as
the controller's inner oracle:

* **monitor** (`repro.service.monitor`) — windowed and rolling (EWMA)
  signals over the most recent job window: arrival rate, offered load,
  runtime scale and dispersion (the homogeneity proxy), and the init
  time the paper's s parameter maps to for this window's runtime mix.
  The init time feeds the oracle; the smoothed signals and their deltas
  are provenance that explains *why* the optimum moved. In fault-aware
  mode a `FaultRegimeEstimator` additionally smooths the *realized*
  fault telemetry (failures / requeues / lost work the committed k
  actually saw) and maps it onto the oracle's chaos axis — a weight per
  fault-regime cell, concentrated where the service actually lives.
  Both monitors carry their EWMAs through NaN/Inf telemetry and raise a
  named error only when there is no finite history to carry.

* **decide** (`repro.service.controller`) — each tick, the oracle
  (`repro.core.sweep.run_window_oracle`) evaluates ALL candidate k's on
  the recent window as one batched lane program (the packed window keeps
  a fixed shape, so the program compiles once and only dispatches on
  later ticks); with a `ChaosConfig` axis the same program also sweeps
  every fault regime, returning [K, C] curves. `HysteresisController`
  commits the arg-best k with plateau-aware hysteresis built on
  `plateau_threshold`'s tolerance model: it holds the current k while it
  stays inside the new curve's 5% plateau band and moves only when the
  optimum leaves it — the paper's own observation (a wide flat plateau
  around k*) turned into a stability rule. `FaultAwareController`
  scalarizes the wait/lost-work frontier — cost(k) = E_w[wait] +
  λ·E_w[lost] under the estimator's regime weights — and applies the
  SAME hysteresis to the cost curve, so among near-tied plateau members
  it leans toward the k that loses the least work. `NaiveController`
  commits the arg-best every tick and exists as the A/B foil.

* **actuate** (`repro.service.driver`) — `run_service` plays a trace
  window by window. The k committed at tick t-1 is what the service
  *realizes* on tick t's window (one-tick actuation delay, as a live
  scheduler would); per-tick provenance records the tuning curve, every
  controller's decision, and regret vs. the window's hindsight optima
  (in fault-aware mode, all realized metrics read the designated
  environment cell of the chaos axis). Multiple controllers share one
  oracle call per tick, so A/Bs see identical inputs by construction.

**Degradation.** The service loop itself survives faults.
``ServiceConfig(on_budget_exhausted="degrade")`` turns a budget-
exhausted oracle window (real, or forced through the injectable
`TickFaults` hook) from a mid-stream crash into a *degraded tick*: every
controller holds its last-good k (the median candidate if the very
first tick degrades), the tick is excluded from regret scoring, and the
oracle simply retries at the next tick — bounded by
``max_consecutive_degraded``, past which the loop raises with the tick
index and window bounds. Each degrade-mode (or fault-injected) run
returns per-tick ``health`` records — ``{tick, window, ok, degraded,
cause, consecutive_degraded, ...}`` — plus a top-level
``n_degraded_ticks``, so "the loop completed every tick" is checkable
from the output alone. `TickFaults` can also drop a window's monitor
telemetry (the EWMAs carry forward and the oracle runs on the smoothed
init time) or poison the fault telemetry with NaN (the estimator
carries forward); both are recorded in the health entries.

Regret (avg_wait and useful_util) is measured against the per-tick
hindsight arg-best — the realized k is always one of the oracle's
candidates, so regret is >= 0 by construction and == 0 only when the
controller was already sitting on the optimum — and, signed, against the
offline `plateau_threshold` recommendation applied per window.
`benchmarks/controller_sweep.py` runs the drift-scenario study
(`repro.workload.windows.drift_scenarios`) and gates on it in CI;
``--chaos`` adds the regret-under-faults block (fault-aware vs.
fault-blind on lost work at bounded wait regret, plus the
completes-under-injected-faults proof).
"""
from repro.service.controller import (Decision, FaultAwareController,
                                      HysteresisController, NaiveController)
from repro.service.driver import (ServiceConfig, TickFaults,
                                  default_controllers, run_service)
from repro.service.monitor import (FaultRegimeEstimator, RollingMonitor,
                                   WindowSignals, window_signals)

__all__ = ["Decision", "FaultAwareController", "HysteresisController",
           "NaiveController", "ServiceConfig", "TickFaults",
           "default_controllers", "run_service", "FaultRegimeEstimator",
           "RollingMonitor", "WindowSignals", "window_signals"]
