"""Streaming Packet service: a closed-loop scale-ratio controller.

The offline stack answers "which scale ratio k *was* best" after a full
sweep; this package answers "which k *right now*" while jobs stream in.
It is a monitor → decide → actuate feedback loop, one iteration ("control
tick") per workload window, with the fused (k-candidate) lane engine as
the controller's inner oracle:

* **monitor** (`repro.service.monitor`) — windowed and rolling (EWMA)
  signals over the most recent job window: arrival rate, offered load,
  runtime scale and dispersion (the homogeneity proxy), and the init
  time the paper's s parameter maps to for this window's runtime mix.
  The init time feeds the oracle; the smoothed signals and their deltas
  are provenance that explains *why* the optimum moved.

* **decide** (`repro.service.controller`) — each tick, the oracle
  (`repro.core.sweep.run_window_oracle`) evaluates ALL candidate k's on
  the recent window as one batched lane program (the packed window keeps
  a fixed shape, so the program compiles once and only dispatches on
  later ticks). `HysteresisController` commits the arg-best k with
  plateau-aware hysteresis built on `plateau_threshold`'s tolerance
  model: it holds the current k while it stays inside the new curve's 5%
  plateau band and moves only when the optimum leaves it — the paper's
  own observation (a wide flat plateau around k*) turned into a
  stability rule. `NaiveController` commits the arg-best every tick and
  exists as the A/B foil.

* **actuate** (`repro.service.driver`) — `run_service` plays a trace
  window by window. The k committed at tick t-1 is what the service
  *realizes* on tick t's window (one-tick actuation delay, as a live
  scheduler would); per-tick provenance records the tuning curve, every
  controller's decision, and regret vs. the window's hindsight optima.
  Multiple controllers share one oracle call per tick, so A/Bs see
  identical inputs by construction.

Regret (avg_wait and useful_util) is measured against the per-tick
hindsight arg-best — the realized k is always one of the oracle's
candidates, so regret is >= 0 by construction and == 0 only when the
controller was already sitting on the optimum — and, signed, against the
offline `plateau_threshold` recommendation applied per window.
`benchmarks/controller_sweep.py` runs the drift-scenario study
(`repro.workload.windows.drift_scenarios`) and gates on it in CI.
"""
from repro.service.controller import (Decision, HysteresisController,
                                      NaiveController)
from repro.service.driver import ServiceConfig, run_service
from repro.service.monitor import RollingMonitor, WindowSignals, window_signals

__all__ = ["Decision", "HysteresisController", "NaiveController",
           "ServiceConfig", "run_service", "RollingMonitor", "WindowSignals",
           "window_signals"]
