"""Actuate stage + tick loop: play a trace through the streaming service.

`run_service` is the reference driver for the monitor → decide → actuate
loop (see the package docstring): it cuts a trace into fixed-shape
windows, runs the fused lane oracle once per tick, lets every registered
controller decide on the SAME curve, and scores what each controller's
held k actually realized on that window. The committed k takes effect on
the *next* tick (one-tick actuation delay — a live scheduler retunes for
traffic it hasn't seen yet), except the bootstrap tick, where the service
turns on with the oracle's first recommendation.

Regret bookkeeping per controller and tick:

* ``regret_wait``   = avg_wait(realized k) - min over candidates (>= 0)
* ``regret_useful`` = max useful_util over candidates - realized (>= 0)
* ``wait_vs_plateau`` (signed) = avg_wait(realized k) - avg_wait at the
  offline `plateau_threshold` recommendation for this window's curve —
  the per-window hindsight application of the paper's offline tuning
  rule. Negative means the controller beat the offline rule.

Everything returned is JSON-ready; `benchmarks/controller_sweep.py`
persists it as BENCH_controller.json.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core import precision
from repro.core.des import pack_workload, resolve_ring
from repro.core.sweep import (PAPER_SCALE_RATIOS, plateau_threshold,
                              run_window_oracle)
from repro.service.controller import HysteresisController, NaiveController
from repro.service.monitor import RollingMonitor, window_signals
from repro.workload.lublin import Workload
from repro.workload.windows import WindowSpec, iter_windows, n_dropped


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of one service run (all ticks share them)."""
    ks: tuple[float, ...] = PAPER_SCALE_RATIOS   # candidate scale ratios
    s_prop: float = 0.05          # init proportion fed to the monitor
    window_jobs: int = 400        # jobs per control-tick window
    stride_jobs: int | None = None  # window start spacing (None: tumbling)
    dtype: str = "float32"        # oracle dtype ("float64" opts into x64)
    mode: str = "auto"            # oracle dispatch layout
    rel_tol: float = 0.05         # the 5% plateau band (paper's tolerance)
    abs_tol: float | None = None  # plateau abs slack (None: float32 envelope)
    ewm_alpha: float = 0.5        # monitor smoothing weight
    on_budget_exhausted: str = "raise"

    def np_dtype(self):
        return np.dtype(self.dtype)


def default_controllers(config: ServiceConfig):
    """The study pair: plateau hysteresis vs. the naive arg-best foil."""
    return [HysteresisController(rel_tol=config.rel_tol,
                                 abs_tol=config.abs_tol),
            NaiveController()]


def _controller_summary(rec: dict, aw_best: np.ndarray) -> dict:
    realized = np.asarray(rec["realized_wait"], np.float64)
    regret_w = np.asarray(rec["regret_wait"], np.float64)
    regret_u = np.asarray(rec["regret_useful"], np.float64)
    vs_plat = np.asarray(rec["wait_vs_plateau"], np.float64)
    total_best = float(np.sum(aw_best))
    return {
        "n_ticks": len(realized),
        "switches": int(rec["switches"]),
        "mean_regret_wait": float(regret_w.mean()),
        "total_regret_wait": float(regret_w.sum()),
        # relative to the hindsight per-tick optimum's total wait
        "rel_regret_wait": float(regret_w.sum() / max(total_best, 1e-9)),
        "mean_regret_useful": float(regret_u.mean()),
        "mean_wait_vs_plateau": float(vs_plat.mean()),
        "mean_realized_wait": float(realized.mean()),
        "k_trajectory": [float(k) for k in rec["k"]],
    }


def run_service(wl: Workload,
                config: ServiceConfig = ServiceConfig(),
                controllers: Sequence | None = None) -> dict:
    """Play one trace through the service; score every controller.

    All controllers consume the same per-tick oracle curve (one
    `run_window_oracle` call per tick, shared), so their regrets differ
    only by policy. Controllers are stateful — pass fresh instances.
    """
    if controllers is None:
        controllers = default_controllers(config)
    names = [c.name for c in controllers]
    if len(set(names)) != len(names):
        raise ValueError(f"controller names must be unique, got {names}")

    dtype = config.np_dtype()
    spec = WindowSpec(config.window_jobs, config.stride_jobs)
    m_nodes = int(wl.params.nodes)
    ks = np.asarray(config.ks, np.float64)
    monitor = RollingMonitor(alpha=config.ewm_alpha)

    live: dict[str, float | None] = {n: None for n in names}
    rec = {n: {"k": [], "realized_wait": [], "regret_wait": [],
               "regret_useful": [], "wait_vs_plateau": [], "switches": 0}
           for n in names}
    ticks = []
    aw_best_all = []

    for t, (lo, hi, win) in enumerate(iter_windows(wl, spec)):
        sig = window_signals(win, config.s_prop)
        smooth = monitor.observe(sig)
        with precision.dtype_scope(dtype):
            pw = pack_workload(win, dtype)
            ring = resolve_ring(m_nodes, pw.n_jobs)
        t0 = time.perf_counter()
        m = run_window_oracle(pw, config.ks, sig.init_time, m_nodes,
                              ring=ring, mode=config.mode,
                              on_budget_exhausted=config.on_budget_exhausted)
        oracle_ms = (time.perf_counter() - t0) * 1e3
        aw = np.asarray(m.avg_wait, np.float64)
        uu = np.asarray(m.useful_util, np.float64)
        i_best = int(np.argmin(aw))
        best_uu = float(np.max(uu))
        plat = plateau_threshold(ks, aw, rel_tol=config.rel_tol,
                                 abs_tol=config.abs_tol)
        i_plat = int(np.argmin(np.abs(ks - plat.threshold)))
        aw_best_all.append(float(aw[i_best]))

        tick = {"tick": t, "window": [int(lo), int(hi)],
                "signals": smooth, "oracle_ms": float(oracle_ms),
                "best_k": float(ks[i_best]),
                "best_wait": float(aw[i_best]),
                "plateau_k": float(plat.threshold),
                "plateau_wait": float(aw[i_plat]),
                "controllers": {}}

        for ctl in controllers:
            name = ctl.name
            dec = ctl.decide(ks, aw)
            # actuation delay: tick t realizes the k held coming INTO the
            # tick; the new decision takes effect at t+1. Bootstrap tick
            # realizes the first decision (the service starts with it).
            k_real = live[name] if live[name] is not None else dec.k
            live[name] = dec.k
            i_real = int(np.argmin(np.abs(ks - k_real)))
            r = rec[name]
            r["k"].append(float(k_real))
            r["realized_wait"].append(float(aw[i_real]))
            r["regret_wait"].append(float(aw[i_real] - aw[i_best]))
            r["regret_useful"].append(float(best_uu - uu[i_real]))
            r["wait_vs_plateau"].append(float(aw[i_real] - aw[i_plat]))
            if dec.moved and dec.reason != "bootstrap":
                r["switches"] += 1
            tick["controllers"][name] = {
                "realized_k": float(k_real), "committed_k": float(dec.k),
                "moved": bool(dec.moved), "reason": dec.reason,
                "hold_tol": float(dec.hold_tol)}
        ticks.append(tick)

    if not ticks:
        raise ValueError(
            f"trace of {len(wl.submit)} jobs yields no full "
            f"{config.window_jobs}-job window")

    aw_best_arr = np.asarray(aw_best_all, np.float64)
    return {
        "config": {
            "ks": [float(k) for k in config.ks], "s_prop": config.s_prop,
            "window_jobs": config.window_jobs,
            "stride_jobs": spec.stride, "dtype": str(dtype),
            "mode": config.mode, "rel_tol": config.rel_tol,
            "m_nodes": m_nodes,
            "n_dropped_jobs": int(n_dropped(len(wl.submit), spec)),
        },
        "n_ticks": len(ticks),
        "oracle": {
            "best_k": [t["best_k"] for t in ticks],
            "plateau_k": [t["plateau_k"] for t in ticks],
            "total_best_wait": float(aw_best_arr.sum()),
            "oracle_ms": [t["oracle_ms"] for t in ticks],
        },
        "controllers": {n: _controller_summary(rec[n], aw_best_arr)
                        for n in names},
        "ticks": ticks,
    }
