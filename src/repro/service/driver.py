"""Actuate stage + tick loop: play a trace through the streaming service.

`run_service` is the reference driver for the monitor → decide → actuate
loop (see the package docstring): it cuts a trace into fixed-shape
windows, runs the fused lane oracle once per tick, lets every registered
controller decide on the SAME curve, and scores what each controller's
held k actually realized on that window. The committed k takes effect on
the *next* tick (one-tick actuation delay — a live scheduler retunes for
traffic it hasn't seen yet), except the bootstrap tick, where the service
turns on with the oracle's first recommendation.

Regret bookkeeping per controller and tick:

* ``regret_wait``   = avg_wait(realized k) - min over candidates (>= 0)
* ``regret_useful`` = max useful_util over candidates - realized (>= 0)
* ``wait_vs_plateau`` (signed) = avg_wait(realized k) - avg_wait at the
  offline `plateau_threshold` recommendation for this window's curve —
  the per-window hindsight application of the paper's offline tuning
  rule. Negative means the controller beat the offline rule.

Fault-aware mode (``ServiceConfig.chaos``): the oracle sweeps a C-cell
`ChaosConfig` axis per tick ([K, C] curves from one fused program), one
designated cell (``chaos_env_cell``) plays the true environment — every
hindsight reference and realized metric reads that column — and each
controller owns a `FaultRegimeEstimator` fed by the fault telemetry its
own committed k realized, so decide weights the regime the service
actually lives in. Fault-blind controllers then decide on the
weight-expected wait curve; `FaultAwareController` adds the λ·lost term
(the A/B `benchmarks/controller_sweep.py --chaos` gates).

Degradation (``on_budget_exhausted="degrade"`` + the `TickFaults` hook):
a tick whose oracle exhausted its event budget (or was forced to by
`TickFaults.exhaust_budget`) no longer kills the stream — the service
holds every controller's last-good k, appends a per-tick health entry,
and retries the oracle on the next tick, raising only after
``max_consecutive_degraded`` consecutive degraded ticks. Budget errors
that DO surface (policy "raise") name the tick index and window bounds.

Everything returned is JSON-ready; `benchmarks/controller_sweep.py`
persists it as BENCH_controller.json. The zero-chaos, fault-free default
path is numerically identical to the pre-fault-aware service: the chaos
machinery, health records, and degrade bookkeeping only engage (and only
add their output keys) when configured.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Iterable, Sequence

import numpy as np

from repro.core import precision
from repro.core.des import ChaosConfig, pack_workload, resolve_ring
from repro.core.sweep import (PAPER_SCALE_RATIOS, chaos_axis_len,
                              chaos_is_inert, plateau_threshold,
                              run_window_oracle)
from repro.service.controller import (FaultAwareController,
                                      HysteresisController, NaiveController)
from repro.service.monitor import (FaultRegimeEstimator, RollingMonitor,
                                   window_signals)
from repro.workload.lublin import Workload
from repro.workload.windows import WindowSpec, iter_windows, n_dropped

_ON_BUDGET_POLICIES = ("raise", "warn", "ignore", "degrade")
_ORACLE_MODES = ("auto", "seq", "chunked", "fused")
_DTYPES = ("float32", "float64")

#: WindowSignals float fields blanked by a dropped-telemetry tick fault
_TELEMETRY_FIELDS = ("span", "arrival_rate", "mean_runtime", "runtime_cv",
                     "mean_nodes", "offered_load", "init_time")


@dataclasses.dataclass(frozen=True)
class TickFaults:
    """Injectable service-loop faults, keyed by tick index.

    The degradation harness's test double: deterministic faults on chosen
    ticks so suites and `benchmarks/controller_sweep.py --chaos` can
    prove the loop completes every tick. Three fault kinds:

    * ``exhaust_budget`` — the tick's oracle result is treated as having
      exhausted its event budget (the metrics are discarded under
      "degrade", surfaced per `on_budget_exhausted` otherwise), exactly
      as if the window itself had blown through `event_budget`.
    * ``nan_telemetry`` — the realized fault telemetry fed to the
      `FaultRegimeEstimator` is replaced with NaN (the estimator must
      carry its EWMAs forward).
    * ``drop_telemetry`` — the window's monitor signals never arrive:
      the `RollingMonitor` sees NaN for every float signal (carrying its
      EWMAs forward) and the oracle runs on the last smoothed init time
      instead of the window's raw one.
    """

    exhaust_budget: frozenset = frozenset()
    nan_telemetry: frozenset = frozenset()
    drop_telemetry: frozenset = frozenset()

    def __post_init__(self):
        for name in ("exhaust_budget", "nan_telemetry", "drop_telemetry"):
            val = getattr(self, name)
            if not isinstance(val, frozenset):
                if isinstance(val, (str, bytes)) or not isinstance(
                        val, Iterable):
                    raise ValueError(
                        f"TickFaults.{name} must be an iterable of tick "
                        f"indices, got {val!r}")
                object.__setattr__(self, name, frozenset(val))
            bad = [t for t in getattr(self, name)
                   if not isinstance(t, int) or t < 0]
            if bad:
                raise ValueError(
                    f"TickFaults.{name} must hold non-negative ints, "
                    f"got {sorted(bad, key=repr)}")


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of one service run (all ticks share them).

    Validated eagerly in ``__post_init__`` — a bad dtype / mode /
    tolerance / policy raises at construction, not deep inside tick N.

    The fault-aware block only engages when ``chaos`` is set: the oracle
    then sweeps the config's chaos lane axis each tick ([K, C] curves),
    ``chaos_env_cell`` indexes the axis cell that plays the true
    environment (realized metrics and hindsight references read that
    column), ``risk_lambda`` prices expected lost work (machine-seconds)
    in seconds of wait for `FaultAwareController`, and ``fault_alpha`` /
    ``fault_temperature`` parameterize each controller's
    `FaultRegimeEstimator`. ``on_budget_exhausted="degrade"`` makes the
    loop survive budget-exhausted windows (hold last-good k, health
    entry, retry next tick, raise after ``max_consecutive_degraded``
    consecutive degraded ticks).
    """
    ks: tuple[float, ...] = PAPER_SCALE_RATIOS   # candidate scale ratios
    s_prop: float = 0.05          # init proportion fed to the monitor
    window_jobs: int = 400        # jobs per control-tick window
    stride_jobs: int | None = None  # window start spacing (None: tumbling)
    dtype: str = "float32"        # oracle dtype ("float64" opts into x64)
    mode: str = "auto"            # oracle dispatch layout
    rel_tol: float = 0.05         # the 5% plateau band (paper's tolerance)
    abs_tol: float | None = None  # plateau abs slack (None: float32 envelope)
    ewm_alpha: float = 0.5        # monitor smoothing weight
    on_budget_exhausted: str = "raise"
    chaos: ChaosConfig | None = None   # C-cell fault axis for the oracle
    chaos_env_cell: int = 0       # axis cell playing the true environment
    risk_lambda: float = 1.0      # wait-seconds per machine-second lost
    adapt_lambda: bool = False    # close the λ loop on realized telemetry
    lambda_alpha: float = 0.3     # λ-loop EWMA weight (realized wait/lost)
    lambda_span: float = 10.0     # live λ clipped to [λ0/span, λ0·span]
    fault_alpha: float = 0.5      # fault-regime estimator EWMA weight
    fault_temperature: float = 0.25   # regime-weight softmax temperature
    max_consecutive_degraded: int = 3  # degrade-mode retry bound

    def __post_init__(self):
        if len(self.ks) < 1:
            raise ValueError("ServiceConfig.ks needs at least one candidate")
        if self.window_jobs < 1:
            raise ValueError(
                f"window_jobs must be >= 1, got {self.window_jobs}")
        if self.stride_jobs is not None and self.stride_jobs < 1:
            raise ValueError(
                f"stride_jobs must be >= 1 or None, got {self.stride_jobs}")
        if not (self.s_prop > 0):
            raise ValueError(f"s_prop must be > 0, got {self.s_prop}")
        if self.dtype not in _DTYPES:
            raise ValueError(
                f"dtype must be one of {_DTYPES}, got {self.dtype!r}")
        if self.mode not in _ORACLE_MODES:
            raise ValueError(
                f"mode must be one of {_ORACLE_MODES}, got {self.mode!r} "
                f"(the window oracle has no vmap_k/vmap_s layout)")
        if self.rel_tol < 0:
            raise ValueError(f"rel_tol must be >= 0, got {self.rel_tol}")
        if self.abs_tol is not None and self.abs_tol < 0:
            raise ValueError(
                f"abs_tol must be >= 0 or None, got {self.abs_tol}")
        if not (0.0 < self.ewm_alpha <= 1.0):
            raise ValueError(
                f"ewm_alpha must be in (0, 1], got {self.ewm_alpha}")
        if self.on_budget_exhausted not in _ON_BUDGET_POLICIES:
            raise ValueError(
                f"on_budget_exhausted must be one of {_ON_BUDGET_POLICIES}, "
                f"got {self.on_budget_exhausted!r}")
        if self.risk_lambda < 0:
            raise ValueError(
                f"risk_lambda must be >= 0, got {self.risk_lambda}")
        if not (0.0 < self.lambda_alpha <= 1.0):
            raise ValueError(
                f"lambda_alpha must be in (0, 1], got {self.lambda_alpha}")
        if not (self.lambda_span >= 1.0):
            raise ValueError(
                f"lambda_span must be >= 1, got {self.lambda_span}")
        if not (0.0 < self.fault_alpha <= 1.0):
            raise ValueError(
                f"fault_alpha must be in (0, 1], got {self.fault_alpha}")
        if not (self.fault_temperature > 0):
            raise ValueError(
                f"fault_temperature must be > 0, "
                f"got {self.fault_temperature}")
        if self.max_consecutive_degraded < 1:
            raise ValueError(
                f"max_consecutive_degraded must be >= 1, "
                f"got {self.max_consecutive_degraded}")
        if self.chaos is not None:
            n_cells = chaos_axis_len(self.chaos)    # validates the axis too
            if not (0 <= self.chaos_env_cell < n_cells):
                raise ValueError(
                    f"chaos_env_cell={self.chaos_env_cell} out of range for "
                    f"the {n_cells}-cell chaos axis")
            if chaos_is_inert(self.chaos):
                raise ValueError(
                    "ServiceConfig.chaos is inert (zero failure and "
                    "straggler rates); pass chaos=None for a fault-free "
                    "service instead")

    def np_dtype(self):
        return np.dtype(self.dtype)

    @property
    def n_chaos_cells(self) -> int:
        return 1 if self.chaos is None else chaos_axis_len(self.chaos)


def default_controllers(config: ServiceConfig):
    """The study set for this config: with a chaos axis, the risk-aware
    controller plus its fault-blind foils; without, the PR-8 pair
    (plateau hysteresis vs. the naive arg-best)."""
    blind = [HysteresisController(rel_tol=config.rel_tol,
                                  abs_tol=config.abs_tol),
             NaiveController()]
    if config.chaos is None:
        return blind
    return [FaultAwareController(rel_tol=config.rel_tol,
                                 abs_tol=config.abs_tol,
                                 risk_lambda=config.risk_lambda,
                                 adapt_lambda=config.adapt_lambda,
                                 lambda_alpha=config.lambda_alpha,
                                 lambda_span=config.lambda_span)] + blind


def _controller_summary(rec: dict, aw_best: np.ndarray,
                        with_chaos: bool) -> dict:
    realized = np.asarray(rec["realized_wait"], np.float64)
    regret_w = np.asarray(rec["regret_wait"], np.float64)
    regret_u = np.asarray(rec["regret_useful"], np.float64)
    vs_plat = np.asarray(rec["wait_vs_plateau"], np.float64)
    total_best = float(np.sum(aw_best))
    out = {
        "n_ticks": len(realized),
        "switches": int(rec["switches"]),
        "mean_regret_wait": float(regret_w.mean()) if len(realized) else 0.0,
        "total_regret_wait": float(regret_w.sum()),
        # relative to the hindsight per-tick optimum's total wait
        "rel_regret_wait": float(regret_w.sum() / max(total_best, 1e-9)),
        "mean_regret_useful": (float(regret_u.mean())
                               if len(realized) else 0.0),
        "mean_wait_vs_plateau": (float(vs_plat.mean())
                                 if len(realized) else 0.0),
        "mean_realized_wait": (float(realized.mean())
                               if len(realized) else 0.0),
        "k_trajectory": [float(k) for k in rec["k"]],
    }
    if with_chaos:
        lost = np.asarray(rec["realized_lost"], np.float64)
        out["total_lost_work"] = float(lost.sum())
        out["mean_realized_lost"] = float(lost.mean()) if len(lost) else 0.0
    return out


def _chaos_config_provenance(config: ServiceConfig) -> dict:
    """JSON-ready record of the fault-aware knobs (chaos axes included)."""
    c = config.chaos
    return {
        "n_cells": config.n_chaos_cells,
        "env_cell": int(config.chaos_env_cell),
        "risk_lambda": float(config.risk_lambda),
        "adapt_lambda": bool(config.adapt_lambda),
        "lambda_alpha": float(config.lambda_alpha),
        "lambda_span": float(config.lambda_span),
        "fault_alpha": float(config.fault_alpha),
        "fault_temperature": float(config.fault_temperature),
        "seed": int(c.seed),
        "mtbf_chip_hours": np.asarray(c.mtbf_chip_hours,
                                      np.float64).tolist(),
        "ckpt_period": np.asarray(c.ckpt_period, np.float64).tolist(),
        "straggler_prob": np.asarray(c.straggler_prob, np.float64).tolist(),
        "straggler_factor": np.asarray(c.straggler_factor,
                                       np.float64).tolist(),
        "straggler_deadline": np.asarray(c.straggler_deadline,
                                         np.float64).tolist(),
    }


def _nan_signals(sig):
    """The dropped-telemetry form of a WindowSignals: floats gone NaN."""
    return sig._replace(**{f: float("nan") for f in _TELEMETRY_FIELDS})


def run_service(wl: Workload,
                config: ServiceConfig = ServiceConfig(),
                controllers: Sequence | None = None,
                tick_faults: TickFaults | None = None) -> dict:
    """Play one trace through the service; score every controller.

    All controllers consume the same per-tick oracle curve (one
    `run_window_oracle` call per tick, shared), so their regrets differ
    only by policy. Controllers are stateful — pass fresh instances.
    `tick_faults` injects deterministic faults into chosen ticks (see
    `TickFaults`); with ``config.on_budget_exhausted="degrade"`` the loop
    completes every tick regardless, holding the last-good k and
    recording per-tick ``health`` entries.
    """
    if controllers is None:
        controllers = default_controllers(config)
    names = [c.name for c in controllers]
    if len(set(names)) != len(names):
        raise ValueError(f"controller names must be unique, got {names}")
    faults = tick_faults
    policy = config.on_budget_exhausted
    track_health = policy == "degrade" or faults is not None
    with_chaos = config.chaos is not None
    K, C = len(config.ks), config.n_chaos_cells
    env = int(config.chaos_env_cell)

    dtype = config.np_dtype()
    spec = WindowSpec(config.window_jobs, config.stride_jobs)
    m_nodes = int(wl.params.nodes)
    ks = np.asarray(config.ks, np.float64)
    monitor = RollingMonitor(alpha=config.ewm_alpha)
    estimators = {n: FaultRegimeEstimator(alpha=config.fault_alpha,
                                          temperature=config.fault_temperature)
                  for n in names} if with_chaos else {}
    # per-controller [C] telemetry predictions at last tick's realized k,
    # mapped onto weights at the NEXT tick's decide
    pred: dict[str, dict | None] = {n: None for n in names}

    live: dict[str, float | None] = {n: None for n in names}
    rec = {n: {"k": [], "realized_wait": [], "regret_wait": [],
               "regret_useful": [], "wait_vs_plateau": [],
               "realized_lost": [], "switches": 0}
           for n in names}
    ticks = []
    health = []
    aw_best_all = []
    consec_degraded = 0

    for t, (lo, hi, win) in enumerate(iter_windows(wl, spec)):
        dropped = (faults is not None and t in faults.drop_telemetry
                   and monitor.has_state)
        nan_tel = faults is not None and t in faults.nan_telemetry
        forced = faults is not None and t in faults.exhaust_budget

        sig = window_signals(win, config.s_prop)
        smooth = monitor.observe(_nan_signals(sig) if dropped else sig)
        # dropped telemetry: the raw window never arrived — steer the
        # oracle by the last smoothed init time instead
        s_init = smooth["ewm_init_time"] if dropped else sig.init_time

        with precision.dtype_scope(dtype):
            pw = pack_workload(win, dtype)
            ring = resolve_ring(m_nodes, pw.n_jobs)
        t0 = time.perf_counter()
        m = run_window_oracle(pw, config.ks, s_init, m_nodes,
                              ring=ring, mode=config.mode,
                              chaos=config.chaos,
                              on_budget_exhausted="ignore")
        oracle_ms = (time.perf_counter() - t0) * 1e3
        exhausted = bool(np.any(np.asarray(m.budget_exhausted))) or forced
        tick_label = (f"run_service tick {t} (window jobs "
                      f"[{int(lo)}, {int(hi)}))")

        if exhausted and policy != "ignore":
            why = ("forced budget exhaustion (TickFaults)" if forced
                   else "oracle lane(s) exhausted the event budget")
            msg = (f"{tick_label}: {why} — schedules for this window are "
                   f"untrustworthy; raise the event budget, or run with "
                   f"on_budget_exhausted='degrade' to hold the last-good "
                   f"k and continue")
            if policy == "raise":
                raise RuntimeError(msg)
            if policy == "warn":
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
            else:                   # degrade: hold last-good k, no scoring
                consec_degraded += 1
                if consec_degraded > config.max_consecutive_degraded:
                    raise RuntimeError(
                        f"{tick_label}: {consec_degraded} consecutive "
                        f"degraded ticks exceed max_consecutive_degraded="
                        f"{config.max_consecutive_degraded} — the oracle "
                        f"never recovered; giving up")
                tick = {"tick": t, "window": [int(lo), int(hi)],
                        "signals": smooth, "oracle_ms": float(oracle_ms),
                        "degraded": True, "controllers": {}}
                for ctl in controllers:
                    name = ctl.name
                    if live[name] is None:
                        # degraded before bootstrap: start on the median
                        # candidate — the most conservative plateau guess
                        live[name] = float(ks[len(ks) // 2])
                        reason = "degraded-bootstrap"
                    else:
                        reason = "degraded-hold"
                    rec[name]["k"].append(float(live[name]))
                    tick["controllers"][name] = {
                        "realized_k": float(live[name]),
                        "committed_k": float(live[name]),
                        "moved": False, "reason": reason}
                ticks.append(tick)
                health.append({
                    "tick": t, "window": [int(lo), int(hi)], "ok": False,
                    "degraded": True, "cause": why,
                    "consecutive_degraded": consec_degraded,
                    "dropped_telemetry": bool(dropped),
                    "held_k": {n: float(live[n]) for n in names}})
                continue
        consec_degraded = 0

        aw2 = np.asarray(m.avg_wait, np.float64).reshape(K, -1)
        uu2 = np.asarray(m.useful_util, np.float64).reshape(K, -1)
        lost2 = np.asarray(m.lost_work, np.float64).reshape(K, -1)
        fail2 = np.asarray(m.failures, np.float64).reshape(K, -1)
        req2 = np.asarray(m.requeues, np.float64).reshape(K, -1)
        # hindsight references live in the true environment's cell
        aw = aw2[:, env]
        uu = uu2[:, env]
        i_best = int(np.argmin(aw))
        best_uu = float(np.max(uu))
        plat = plateau_threshold(ks, aw, rel_tol=config.rel_tol,
                                 abs_tol=config.abs_tol)
        i_plat = int(np.argmin(np.abs(ks - plat.threshold)))
        aw_best_all.append(float(aw[i_best]))

        tick = {"tick": t, "window": [int(lo), int(hi)],
                "signals": smooth, "oracle_ms": float(oracle_ms),
                "best_k": float(ks[i_best]),
                "best_wait": float(aw[i_best]),
                "plateau_k": float(plat.threshold),
                "plateau_wait": float(aw[i_plat]),
                "controllers": {}}

        for ctl in controllers:
            name = ctl.name
            if with_chaos:
                est = estimators[name]
                weights = (est.weights(pred[name])
                           if pred[name] is not None
                           else np.full(C, 1.0 / C))
                if getattr(ctl, "fault_aware", False):
                    dec = ctl.decide(ks, aw2, lost=lost2 / m_nodes,
                                     weights=weights)
                else:
                    dec = ctl.decide(ks, aw2 @ weights)
            else:
                dec = ctl.decide(ks, aw)
            # actuation delay: tick t realizes the k held coming INTO the
            # tick; the new decision takes effect at t+1. Bootstrap tick
            # realizes the first decision (the service starts with it).
            k_real = live[name] if live[name] is not None else dec.k
            live[name] = dec.k
            i_real = int(np.argmin(np.abs(ks - k_real)))
            r = rec[name]
            r["k"].append(float(k_real))
            r["realized_wait"].append(float(aw[i_real]))
            r["regret_wait"].append(float(aw[i_real] - aw[i_best]))
            r["regret_useful"].append(float(best_uu - uu[i_real]))
            r["wait_vs_plateau"].append(float(aw[i_real] - aw[i_plat]))
            if dec.moved and dec.reason != "bootstrap":
                r["switches"] += 1
            ctl_tick = {
                "realized_k": float(k_real), "committed_k": float(dec.k),
                "moved": bool(dec.moved), "reason": dec.reason,
                "hold_tol": float(dec.hold_tol)}
            if with_chaos:
                # realized fault telemetry (true environment's cell at the
                # realized k) closes the estimator's loop; NaN injection
                # exercises its carry-forward hardening
                lost_real = float(lost2[i_real, env] / m_nodes)
                r["realized_lost"].append(lost_real)
                obs = ((float("nan"),) * 3 if nan_tel
                       else (float(fail2[i_real, env]),
                             float(req2[i_real, env]),
                             float(lost2[i_real, env])))
                est_out = estimators[name].observe(*obs)
                pred[name] = {"failures": fail2[i_real, :],
                              "requeues": req2[i_real, :],
                              "lost_work": lost2[i_real, :]}
                if getattr(ctl, "fault_aware", False):
                    # close the λ loop: the realized wait/lost pair at
                    # this tick's realized k re-prices lost work for the
                    # NEXT tick's decide (no-op unless adapt_lambda)
                    ctl_tick["risk_lambda"] = float(ctl.live_lambda)
                    obs_wait = float("nan") if nan_tel else float(aw[i_real])
                    obs_lost = float("nan") if nan_tel else lost_real
                    ctl.observe_realized(obs_wait, obs_lost)
                ctl_tick["weights"] = [float(x) for x in weights]
                ctl_tick["realized_lost"] = lost_real
                ctl_tick["fault_ewm"] = {k: v for k, v in est_out.items()
                                         if k != "carried"}
                if est_out["carried"]:
                    ctl_tick["carried_telemetry"] = est_out["carried"]
            tick["controllers"][name] = ctl_tick
        ticks.append(tick)
        if track_health:
            health.append({
                "tick": t, "window": [int(lo), int(hi)], "ok": True,
                "degraded": False, "consecutive_degraded": 0,
                "dropped_telemetry": bool(dropped),
                "nan_telemetry": bool(nan_tel),
                "budget_warned": bool(exhausted and policy == "warn")})

    if not ticks:
        raise ValueError(
            f"trace of {len(wl.submit)} jobs yields no full "
            f"{config.window_jobs}-job window")

    aw_best_arr = np.asarray(aw_best_all, np.float64)
    cfg_out = {
        "ks": [float(k) for k in config.ks], "s_prop": config.s_prop,
        "window_jobs": config.window_jobs,
        "stride_jobs": spec.stride, "dtype": str(dtype),
        "mode": config.mode, "rel_tol": config.rel_tol,
        "m_nodes": m_nodes,
        "n_dropped_jobs": int(n_dropped(len(wl.submit), spec)),
    }
    if policy != "raise":
        cfg_out["on_budget_exhausted"] = policy
    if with_chaos:
        cfg_out["chaos"] = _chaos_config_provenance(config)
    out = {
        "config": cfg_out,
        "n_ticks": len(ticks),
        "oracle": {
            "best_k": [t["best_k"] for t in ticks if "best_k" in t],
            "plateau_k": [t["plateau_k"] for t in ticks if "plateau_k" in t],
            "total_best_wait": float(aw_best_arr.sum()),
            "oracle_ms": [t["oracle_ms"] for t in ticks],
        },
        "controllers": {n: _controller_summary(rec[n], aw_best_arr,
                                               with_chaos)
                        for n in names},
        "ticks": ticks,
    }
    if track_health:
        out["health"] = health
        out["n_degraded_ticks"] = sum(1 for h in health if h["degraded"])
    return out
