"""Decide stage: plateau-aware hysteresis over a tick's tuning curve.

The oracle hands decide() the avg_wait curve over the candidate k's for
the most recent window. The paper's central empirical fact is that this
curve has a wide, flat plateau around its optimum (that's why
`plateau_threshold` reports a *smallest sufficient* k, not a unique
arg-min) — under window noise the arg-best hops between near-tied plateau
members every tick. `HysteresisController` therefore treats the plateau,
not the arg-min, as the stability region: hold the current k while its
wait stays within the plateau band of the new best, move (to the new
arg-best) only when it leaves. `NaiveController` commits the arg-best
unconditionally and is the A/B foil `benchmarks/controller_sweep.py`
gates against (hysteresis must match its regret with fewer switches).

`FaultAwareController` is the risk-aware variant for chaos-axis ticks:
the oracle then returns [K, C] curves (per candidate k, per fault
regime) and the fault-regime estimator a weight per cell. It scalarizes
the wait/lost-work frontier — cost(k) = E_w[wait] + λ · E_w[lost] —
and runs the SAME plateau-band hysteresis on the cost curve, so the
plateau stability story survives going fault-aware: among near-tied
plateau members the λ term breaks ties toward the k that loses the
least work under the regime the service actually lives in. The
fault-blind `HysteresisController` deciding on E_w[wait] alone is its
A/B foil (`benchmarks/controller_sweep.py --chaos` gates the pair).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.sweep import FLOAT32_AVG_WAIT_RTOL, plateau_threshold


class Decision(NamedTuple):
    """One decide() outcome, with the evidence it was based on."""
    k: float            # the committed scale ratio (actuated next tick)
    moved: bool         # did the controller change k this tick
    reason: str         # "bootstrap" | "hold" | "left-plateau" | "argbest"
    best_k: float       # this tick's hindsight arg-best candidate
    best_wait: float    # avg_wait at best_k
    hold_tol: float     # the plateau band half-width used (seconds)
    plateau_k: float    # offline plateau_threshold recommendation (provenance)


def _validate_curve(ks, avg_wait) -> tuple[np.ndarray, np.ndarray]:
    ks = np.asarray(ks, np.float64)
    w = np.asarray(avg_wait, np.float64)
    if ks.ndim != 1 or ks.shape != w.shape or len(ks) == 0:
        raise ValueError(
            f"decide() wants matching 1-D ks/avg_wait, got {ks.shape} "
            f"and {w.shape}")
    if not np.all(np.isfinite(w)):
        raise ValueError("avg_wait curve contains non-finite values")
    return ks, w


class HysteresisController:
    """Commit arg-best k, but only when the held k leaves the 5% plateau.

    The hold band reuses `plateau_threshold`'s tolerance model:
    ``rel_tol * best_wait + abs_tol``, with ``abs_tol`` defaulting to the
    measured float32 avg_wait envelope (`FLOAT32_AVG_WAIT_RTOL`, scaled
    by the plateau level) so float noise alone can never trigger a move.
    Stateful: one instance per controlled stream.
    """

    name = "hysteresis"

    def __init__(self, rel_tol: float = 0.05, abs_tol: float | None = None):
        if rel_tol < 0:
            raise ValueError(f"rel_tol must be >= 0, got {rel_tol}")
        self.rel_tol = float(rel_tol)
        self.abs_tol = abs_tol
        self.k: float | None = None

    def decide(self, ks, avg_wait) -> Decision:
        ks, w = _validate_curve(ks, avg_wait)
        return self._decide_on_curve(ks, w)

    def _decide_on_curve(self, ks: np.ndarray, w: np.ndarray) -> Decision:
        """Plateau-band hysteresis over a validated 1-D cost curve.

        `decide` hands this the avg_wait curve; `FaultAwareController`
        hands it the scalarized wait+λ·lost cost curve. The hold rule is
        identical either way — that IS the refactor's point.
        """
        i_best = int(np.argmin(w))
        best_k, best_w = float(ks[i_best]), float(w[i_best])
        plat = plateau_threshold(ks, w, rel_tol=self.rel_tol,
                                 abs_tol=self.abs_tol)
        abs_tol = (FLOAT32_AVG_WAIT_RTOL * max(best_w, 1.0)
                   if self.abs_tol is None else float(self.abs_tol))
        tol = self.rel_tol * max(best_w, 1.0) + abs_tol

        held = np.flatnonzero(ks == self.k) if self.k is not None else []
        if len(held) == 0:
            # first tick, or the candidate grid changed under us
            self.k = best_k
            return Decision(best_k, True, "bootstrap", best_k, best_w,
                            tol, plat.threshold)
        if float(w[held[0]]) <= best_w + tol:
            return Decision(float(self.k), False, "hold", best_k, best_w,
                            tol, plat.threshold)
        self.k = best_k
        return Decision(best_k, True, "left-plateau", best_k, best_w,
                        tol, plat.threshold)


class FaultAwareController(HysteresisController):
    """Plateau hysteresis on the risk-scalarized wait/lost-work frontier.

    Chaos-axis decide: `avg_wait` and `lost` arrive as [K, C] curves
    (candidate k × chaos cell, from `run_window_oracle(chaos=...)`) and
    ``weights`` as the fault-regime estimator's [C] cell weights. The
    controller scalarizes

        cost(k) = Σ_c w_c · wait[k, c]  +  λ · Σ_c w_c · lost[k, c]

    and applies the inherited plateau-band hysteresis to the cost curve:
    hold the committed k while its cost stays inside the 5% plateau band
    of the new cost-best, move only when it leaves. λ (``risk_lambda``)
    prices one unit of expected lost work (the service driver feeds lost
    work in machine-seconds, i.e. chip-seconds / M) in seconds of
    average wait; λ=0 reduces exactly to the fault-blind
    `HysteresisController` on the expected-wait curve (pinned in
    tests/test_service.py).

    [K] inputs (no chaos axis) and ``lost=None`` / ``weights=None``
    (uniform cells, zero lost work) are accepted, so the controller
    degrades gracefully to fault-blind behavior when the oracle has no
    chaos axis to offer. Decision.best_wait then reports the *cost* at
    the cost-best k — the quantity the hysteresis band was applied to —
    not the raw wait (the driver records realized waits separately).

    Closed λ loop (``adapt_lambda=True``): a fixed λ prices lost work for
    a fault regime the operator guessed at configuration time; when the
    environment drifts (MTBF shifts, a straggler storm), the λ·lost term
    either swamps the wait objective or vanishes from it. The adaptive
    mode re-prices online from the *realized* trade the service actually
    lives: the driver feeds every tick's realized (wait, lost) pair to
    `observe_realized`, two EWMAs track their magnitudes, and decide()
    scalarizes with

        λ_t = clip(λ0 · ewm_wait / max(ewm_lost, eps),
                   λ0 / lambda_span, λ0 · lambda_span)

    i.e. λ0 becomes a unitless risk weight — the fraction of the realized
    wait budget the controller keeps trading against lost work — and the
    EWMA ratio converts it to the live price in wait-seconds per
    machine-second. A loss-heavy regime cheapens each unit (the lost term
    stays commensurate with wait instead of drowning it); a quiet regime
    raises the price, deterring risky k while losses are rare. Until the
    first telemetry arrives — and always when ``adapt_lambda=False`` (the
    default) — `live_lambda` is exactly ``risk_lambda``, so the fixed-λ
    controller's decisions are preserved bitwise (pinned in
    tests/test_adaptive_lambda.py). Non-finite telemetry is carried
    forward, matching the `FaultRegimeEstimator` hardening.
    """

    name = "fault_aware"
    fault_aware = True      # the driver's dispatch marker (extra operands)

    #: floor for the realized-lost EWMA in the λ ratio (machine-seconds);
    #: keeps a quiet regime's price finite before the span clip applies
    LOST_EPS = 1e-9

    def __init__(self, rel_tol: float = 0.05, abs_tol: float | None = None,
                 risk_lambda: float = 1.0, adapt_lambda: bool = False,
                 lambda_alpha: float = 0.3, lambda_span: float = 10.0):
        super().__init__(rel_tol=rel_tol, abs_tol=abs_tol)
        if risk_lambda < 0:
            raise ValueError(
                f"risk_lambda must be >= 0, got {risk_lambda}")
        if not (0.0 < lambda_alpha <= 1.0):
            raise ValueError(
                f"lambda_alpha must be in (0, 1], got {lambda_alpha}")
        if not (lambda_span >= 1.0):
            raise ValueError(
                f"lambda_span must be >= 1, got {lambda_span}")
        self.risk_lambda = float(risk_lambda)
        self.adapt_lambda = bool(adapt_lambda)
        self.lambda_alpha = float(lambda_alpha)
        self.lambda_span = float(lambda_span)
        self.ewm_wait: float | None = None
        self.ewm_lost: float | None = None

    @property
    def live_lambda(self) -> float:
        """The λ decide() prices lost work with on the next curve."""
        if (not self.adapt_lambda or self.ewm_wait is None
                or self.ewm_lost is None):
            return self.risk_lambda
        ratio = self.ewm_wait / max(self.ewm_lost, self.LOST_EPS)
        return float(np.clip(self.risk_lambda * ratio,
                             self.risk_lambda / self.lambda_span,
                             self.risk_lambda * self.lambda_span))

    def observe_realized(self, wait: float, lost: float) -> None:
        """Fold one tick's realized (avg_wait, lost machine-seconds) pair
        into the λ EWMAs. Non-finite samples are carried forward."""
        a = self.lambda_alpha
        if np.isfinite(wait):
            self.ewm_wait = (float(wait) if self.ewm_wait is None
                             else (1 - a) * self.ewm_wait + a * float(wait))
        if np.isfinite(lost):
            self.ewm_lost = (float(lost) if self.ewm_lost is None
                             else (1 - a) * self.ewm_lost + a * float(lost))

    @staticmethod
    def _expect(name: str, curve, weights: np.ndarray | None) -> np.ndarray:
        """[K] expectation of a [K] or [K, C] curve under the cell weights."""
        arr = np.asarray(curve, np.float64)
        if arr.ndim == 1:
            return arr
        if arr.ndim != 2:
            raise ValueError(
                f"decide() wants a [K] or [K, C] {name} curve, got shape "
                f"{arr.shape}")
        if weights is None:
            return arr.mean(axis=1)
        wts = np.asarray(weights, np.float64)
        if wts.shape != (arr.shape[1],):
            raise ValueError(
                f"weights shape {wts.shape} does not match the {name} "
                f"curve's chaos axis [{arr.shape[1]}]")
        return arr @ wts

    def decide(self, ks, avg_wait, lost=None, weights=None) -> Decision:
        e_wait = self._expect("avg_wait", avg_wait, weights)
        ks, e_wait = _validate_curve(ks, e_wait)
        if lost is None:
            cost = e_wait
        else:
            e_lost = self._expect("lost", lost, weights)
            if e_lost.shape != e_wait.shape:
                raise ValueError(
                    f"lost curve reduces to shape {e_lost.shape}, "
                    f"expected {e_wait.shape}")
            if not np.all(np.isfinite(e_lost)):
                raise ValueError("lost curve contains non-finite values")
            cost = e_wait + self.live_lambda * e_lost
        return self._decide_on_curve(ks, cost)


class NaiveController:
    """Every-tick arg-best commit — the no-hysteresis A/B foil."""

    name = "naive"

    def __init__(self):
        self.k: float | None = None

    def decide(self, ks, avg_wait) -> Decision:
        ks, w = _validate_curve(ks, avg_wait)
        i_best = int(np.argmin(w))
        best_k, best_w = float(ks[i_best]), float(w[i_best])
        plat = plateau_threshold(ks, w)
        moved = self.k is None or best_k != self.k
        reason = "bootstrap" if self.k is None else "argbest"
        self.k = best_k
        return Decision(best_k, moved, reason, best_k, best_w, 0.0,
                        plat.threshold)
