"""Decide stage: plateau-aware hysteresis over a tick's tuning curve.

The oracle hands decide() the avg_wait curve over the candidate k's for
the most recent window. The paper's central empirical fact is that this
curve has a wide, flat plateau around its optimum (that's why
`plateau_threshold` reports a *smallest sufficient* k, not a unique
arg-min) — under window noise the arg-best hops between near-tied plateau
members every tick. `HysteresisController` therefore treats the plateau,
not the arg-min, as the stability region: hold the current k while its
wait stays within the plateau band of the new best, move (to the new
arg-best) only when it leaves. `NaiveController` commits the arg-best
unconditionally and is the A/B foil `benchmarks/controller_sweep.py`
gates against (hysteresis must match its regret with fewer switches).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.sweep import FLOAT32_AVG_WAIT_RTOL, plateau_threshold


class Decision(NamedTuple):
    """One decide() outcome, with the evidence it was based on."""
    k: float            # the committed scale ratio (actuated next tick)
    moved: bool         # did the controller change k this tick
    reason: str         # "bootstrap" | "hold" | "left-plateau" | "argbest"
    best_k: float       # this tick's hindsight arg-best candidate
    best_wait: float    # avg_wait at best_k
    hold_tol: float     # the plateau band half-width used (seconds)
    plateau_k: float    # offline plateau_threshold recommendation (provenance)


def _validate_curve(ks, avg_wait) -> tuple[np.ndarray, np.ndarray]:
    ks = np.asarray(ks, np.float64)
    w = np.asarray(avg_wait, np.float64)
    if ks.ndim != 1 or ks.shape != w.shape or len(ks) == 0:
        raise ValueError(
            f"decide() wants matching 1-D ks/avg_wait, got {ks.shape} "
            f"and {w.shape}")
    if not np.all(np.isfinite(w)):
        raise ValueError("avg_wait curve contains non-finite values")
    return ks, w


class HysteresisController:
    """Commit arg-best k, but only when the held k leaves the 5% plateau.

    The hold band reuses `plateau_threshold`'s tolerance model:
    ``rel_tol * best_wait + abs_tol``, with ``abs_tol`` defaulting to the
    measured float32 avg_wait envelope (`FLOAT32_AVG_WAIT_RTOL`, scaled
    by the plateau level) so float noise alone can never trigger a move.
    Stateful: one instance per controlled stream.
    """

    name = "hysteresis"

    def __init__(self, rel_tol: float = 0.05, abs_tol: float | None = None):
        if rel_tol < 0:
            raise ValueError(f"rel_tol must be >= 0, got {rel_tol}")
        self.rel_tol = float(rel_tol)
        self.abs_tol = abs_tol
        self.k: float | None = None

    def decide(self, ks, avg_wait) -> Decision:
        ks, w = _validate_curve(ks, avg_wait)
        i_best = int(np.argmin(w))
        best_k, best_w = float(ks[i_best]), float(w[i_best])
        plat = plateau_threshold(ks, w, rel_tol=self.rel_tol,
                                 abs_tol=self.abs_tol)
        abs_tol = (FLOAT32_AVG_WAIT_RTOL * max(best_w, 1.0)
                   if self.abs_tol is None else float(self.abs_tol))
        tol = self.rel_tol * max(best_w, 1.0) + abs_tol

        held = np.flatnonzero(ks == self.k) if self.k is not None else []
        if len(held) == 0:
            # first tick, or the candidate grid changed under us
            self.k = best_k
            return Decision(best_k, True, "bootstrap", best_k, best_w,
                            tol, plat.threshold)
        if float(w[held[0]]) <= best_w + tol:
            return Decision(float(self.k), False, "hold", best_k, best_w,
                            tol, plat.threshold)
        self.k = best_k
        return Decision(best_k, True, "left-plateau", best_k, best_w,
                        tol, plat.threshold)


class NaiveController:
    """Every-tick arg-best commit — the no-hysteresis A/B foil."""

    name = "naive"

    def __init__(self):
        self.k: float | None = None

    def decide(self, ks, avg_wait) -> Decision:
        ks, w = _validate_curve(ks, avg_wait)
        i_best = int(np.argmin(w))
        best_k, best_w = float(ks[i_best]), float(w[i_best])
        plat = plateau_threshold(ks, w)
        moved = self.k is None or best_k != self.k
        reason = "bootstrap" if self.k is None else "argbest"
        self.k = best_k
        return Decision(best_k, moved, reason, best_k, best_w, 0.0,
                        plat.threshold)
