"""Monitor stage: windowed + rolling workload signals for the controller.

`window_signals` reduces one job window to the quantities the decide
stage and the provenance log care about; `RollingMonitor` smooths them
across ticks (EWMA) and exposes per-tick deltas, so drift shows up as a
signal trend rather than window-to-window noise. The one signal that is
load-bearing (not just observability) is `init_time`: the paper's init
proportion s maps to seconds through the *window's* mean runtime, so the
oracle is always asked about the traffic actually on the floor.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.workload.lublin import Workload


class WindowSignals(NamedTuple):
    """One window, reduced to controller-facing scalars (all float64)."""
    n_jobs: int
    span: float            # seconds covered by the window's submits
    arrival_rate: float    # jobs per second
    mean_runtime: float    # seconds
    runtime_cv: float      # coefficient of variation — homogeneity proxy
    mean_nodes: float      # mean requested node count
    offered_load: float    # sum(work) / (M * span): the calculated rho
    init_time: float       # seconds of group init the s proportion buys here


def window_signals(wl: Workload, s_prop: float) -> WindowSignals:
    """Reduce a window (a `slice_window` output) to `WindowSignals`.

    `init_time` follows `Workload.init_time_for_proportion`: s_prop is a
    proportion of the mean runtime, evaluated on THIS window, so a
    homogeneity or intensity shift moves the oracle's s operand with it.
    """
    submit = np.asarray(wl.submit, np.float64)
    runtime = np.asarray(wl.runtime, np.float64)
    n = len(submit)
    if n == 0:
        raise ValueError("window_signals needs a non-empty window")
    span = float(max(submit[-1] - submit[0], 1.0))
    mean_rt = float(runtime.mean())
    return WindowSignals(
        n_jobs=n,
        span=span,
        arrival_rate=n / span,
        mean_runtime=mean_rt,
        runtime_cv=float(runtime.std() / max(mean_rt, 1e-12)),
        mean_nodes=float(np.asarray(wl.nodes, np.float64).mean()),
        offered_load=float(np.asarray(wl.work, np.float64).sum()
                           / (wl.params.nodes * span)),
        init_time=float(wl.init_time_for_proportion(s_prop)),
    )


#: WindowSignals fields the monitor smooths (the rest are structural)
_SMOOTHED = ("arrival_rate", "mean_runtime", "runtime_cv", "mean_nodes",
             "offered_load", "init_time")


class RollingMonitor:
    """EWMA over window signals, with per-tick drift deltas.

    ``alpha`` is the weight of the newest window (alpha=1 disables
    smoothing). `observe` returns a flat dict — raw signals, their
    smoothed values (``ewm_*``), and the change of each smoothed value
    since the previous tick (``delta_*``) — ready for the driver's
    per-tick provenance log.
    """

    def __init__(self, alpha: float = 0.5):
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self._ewm: dict[str, float] | None = None

    def observe(self, sig: WindowSignals) -> dict[str, float]:
        raw = sig._asdict()
        prev = self._ewm
        ewm = {}
        for name in _SMOOTHED:
            x = float(raw[name])
            ewm[name] = (x if prev is None
                         else self.alpha * x + (1 - self.alpha) * prev[name])
        out = {k: (int(v) if k == "n_jobs" else float(v))
               for k, v in raw.items()}
        out.update({f"ewm_{k}": v for k, v in ewm.items()})
        out.update({f"delta_{k}": (0.0 if prev is None else ewm[k] - prev[k])
                    for k in _SMOOTHED})
        self._ewm = ewm
        return out
