"""Monitor stage: windowed + rolling workload signals for the controller.

`window_signals` reduces one job window to the quantities the decide
stage and the provenance log care about; `RollingMonitor` smooths them
across ticks (EWMA) and exposes per-tick deltas, so drift shows up as a
signal trend rather than window-to-window noise. The one signal that is
load-bearing (not just observability) is `init_time`: the paper's init
proportion s maps to seconds through the *window's* mean runtime, so the
oracle is always asked about the traffic actually on the floor.

`FaultRegimeEstimator` is the fault-side monitor: it smooths the
*realized* fault telemetry (failures / requeues / lost_work the
committed k actually saw last tick) and maps the smoothed rates onto the
chaos lane axis of the tick oracle — a weight per chaos cell,
concentrated on the regime whose predicted telemetry is closest to what
the service is actually living through. The decide stage
(`FaultAwareController`) takes expectations under these weights, so an
environment regime shift moves the weights (within a few EWMA
half-lives) instead of requiring a forecast.

Both monitors survive corrupted telemetry: a NaN/Inf signal component
carries the last finite EWMA forward (counted, reported), and only a
non-finite value at bootstrap — when there is no finite history to carry
— raises a named error. `reset()` returns either monitor to its
pre-first-tick state for reuse across service runs.
"""
from __future__ import annotations

import math
from typing import Mapping, NamedTuple

import numpy as np

from repro.workload.lublin import Workload


class WindowSignals(NamedTuple):
    """One window, reduced to controller-facing scalars (all float64)."""
    n_jobs: int
    span: float            # seconds covered by the window's submits
    arrival_rate: float    # jobs per second
    mean_runtime: float    # seconds
    runtime_cv: float      # coefficient of variation — homogeneity proxy
    mean_nodes: float      # mean requested node count
    offered_load: float    # sum(work) / (M * span): the calculated rho
    init_time: float       # seconds of group init the s proportion buys here


def window_signals(wl: Workload, s_prop: float) -> WindowSignals:
    """Reduce a window (a `slice_window` output) to `WindowSignals`.

    `init_time` follows `Workload.init_time_for_proportion`: s_prop is a
    proportion of the mean runtime, evaluated on THIS window, so a
    homogeneity or intensity shift moves the oracle's s operand with it.
    """
    submit = np.asarray(wl.submit, np.float64)
    runtime = np.asarray(wl.runtime, np.float64)
    n = len(submit)
    if n == 0:
        raise ValueError("window_signals needs a non-empty window")
    span = float(max(submit[-1] - submit[0], 1.0))
    mean_rt = float(runtime.mean())
    return WindowSignals(
        n_jobs=n,
        span=span,
        arrival_rate=n / span,
        mean_runtime=mean_rt,
        runtime_cv=float(runtime.std() / max(mean_rt, 1e-12)),
        mean_nodes=float(np.asarray(wl.nodes, np.float64).mean()),
        offered_load=float(np.asarray(wl.work, np.float64).sum()
                           / (wl.params.nodes * span)),
        init_time=float(wl.init_time_for_proportion(s_prop)),
    )


#: WindowSignals fields the monitor smooths (the rest are structural)
_SMOOTHED = ("arrival_rate", "mean_runtime", "runtime_cv", "mean_nodes",
             "offered_load", "init_time")


class RollingMonitor:
    """EWMA over window signals, with per-tick drift deltas.

    ``alpha`` is the weight of the newest window (alpha=1 disables
    smoothing). `observe` returns a flat dict — raw signals, their
    smoothed values (``ewm_*``), and the change of each smoothed value
    since the previous tick (``delta_*``) — ready for the driver's
    per-tick provenance log.

    Telemetry hardening: a non-finite (NaN/Inf) signal component carries
    the last finite EWMA forward for that component (its ``delta_*`` is
    0.0 and its name lands in the returned ``"carried"`` list, which is
    present only on such degraded ticks). A non-finite component on the
    FIRST observation has no finite history to carry and raises a named
    ValueError. `has_state` is True once a first window was observed;
    `reset()` clears the EWMA state for reuse across service runs.
    """

    def __init__(self, alpha: float = 0.5):
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self._ewm: dict[str, float] | None = None

    @property
    def has_state(self) -> bool:
        return self._ewm is not None

    def reset(self) -> None:
        """Forget all smoothed state (back to the pre-first-tick state)."""
        self._ewm = None

    def observe(self, sig: WindowSignals) -> dict[str, float]:
        raw = sig._asdict()
        prev = self._ewm
        ewm = {}
        carried = []
        for name in _SMOOTHED:
            x = float(raw[name])
            if not math.isfinite(x):
                if prev is None:
                    raise ValueError(
                        f"RollingMonitor.observe: signal {name!r} is "
                        f"non-finite ({x}) on the first observation — no "
                        f"finite EWMA to carry forward")
                carried.append(name)
                ewm[name] = prev[name]
                continue
            ewm[name] = (x if prev is None
                         else self.alpha * x + (1 - self.alpha) * prev[name])
        out = {k: (int(v) if k == "n_jobs" else float(v))
               for k, v in raw.items()}
        out.update({f"ewm_{k}": v for k, v in ewm.items()})
        out.update({f"delta_{k}": (0.0 if prev is None else ewm[k] - prev[k])
                    for k in _SMOOTHED})
        if carried:
            out["carried"] = carried
        self._ewm = ewm
        return out


#: realized fault-telemetry components the regime estimator smooths, in
#: the order `FaultRegimeEstimator.observe` takes them
FAULT_SIGNALS = ("failures", "requeues", "lost_work")


class FaultRegimeEstimator:
    """EWMA fault-regime estimator: realized telemetry → chaos-cell weights.

    Each tick the service *realizes* one (k, chaos-environment) cell and
    observes its fault telemetry — failures, requeue rounds, lost work.
    `observe` smooths those (EWMA, weight ``alpha`` on the newest tick);
    `weights` then scores every cell of the oracle's chaos axis by how
    close its *predicted* telemetry (the previous tick's [K, C] curves at
    the committed k) sits to the smoothed observations, returning a
    normalized weight vector over the C cells:

        d_c   = mean over signals of |pred_c - ewm| / max_c |pred_c|
        w_c   ∝ exp(-d_c / temperature)

    The per-signal normalization makes the distance dimensionless (chip
    -seconds of lost work and failure counts contribute equally); the
    ``temperature`` sets how sharply weight concentrates on the nearest
    regime (→0 approaches one-hot, large values approach uniform).
    Before any finite observation — and whenever no observed signal has a
    matching prediction — `weights` is uniform: the estimator starts
    agnostic and sharpens as realized faults arrive.

    Telemetry hardening mirrors `RollingMonitor`: a non-finite observed
    component keeps its last finite EWMA (carried forward, counted in
    ``n_carried`` and named in the returned ``"carried"`` list); a
    component that was never finite simply stays unobserved and is
    skipped by `weights`, so a NaN-poisoned stream degrades toward the
    uniform prior instead of propagating NaN into the decide step.
    `reset()` forgets all state for reuse across service runs.
    """

    def __init__(self, alpha: float = 0.5, temperature: float = 0.25):
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if not (temperature > 0.0):
            raise ValueError(
                f"temperature must be > 0, got {temperature}")
        self.alpha = float(alpha)
        self.temperature = float(temperature)
        self._ewm: dict[str, float] = {}
        self.n_carried = 0

    @property
    def has_state(self) -> bool:
        return bool(self._ewm)

    def reset(self) -> None:
        """Forget all smoothed state and carry counters."""
        self._ewm = {}
        self.n_carried = 0

    def observe(self, failures: float, requeues: float,
                lost_work: float) -> dict:
        """Fold one tick's realized fault telemetry into the EWMAs.

        Returns the smoothed values (``ewm_*``, only for components that
        have seen at least one finite observation) plus a ``"carried"``
        list naming non-finite components whose EWMA was carried forward
        this tick (empty list when the telemetry was clean).
        """
        obs = dict(zip(FAULT_SIGNALS, (failures, requeues, lost_work)))
        carried = []
        for name, x in obs.items():
            x = float(x)
            if not math.isfinite(x):
                carried.append(name)        # keep the last finite EWMA
                continue
            prev = self._ewm.get(name)
            self._ewm[name] = (x if prev is None
                               else self.alpha * x
                               + (1 - self.alpha) * prev)
        self.n_carried += len(carried)
        out = {f"ewm_{k}": float(v) for k, v in self._ewm.items()}
        out["carried"] = carried
        return out

    def weights(self, cell_signals: Mapping[str, "np.ndarray"]) -> np.ndarray:
        """Weight per chaos cell given each cell's predicted telemetry.

        ``cell_signals`` maps signal names (a subset of `FAULT_SIGNALS`)
        to equal-length [C] arrays — cell c's predicted value of that
        signal at the committed k (from the previous tick's oracle
        curves). Returns a float64 [C] vector summing to 1. Uniform when
        nothing has been observed yet or no observed signal has a
        prediction; mismatched lengths raise, naming the fields.
        """
        lens = {name: np.asarray(v).shape for name, v in cell_signals.items()}
        uniq = set(lens.values())
        if not lens or len(uniq) > 1 or any(len(s) != 1 for s in uniq):
            detail = ", ".join(f"{n}{list(s)}" for n, s in sorted(lens.items()))
            raise ValueError(
                f"cell_signals must be non-empty equal-length 1-D arrays, "
                f"got {detail or 'nothing'}")
        C = next(iter(uniq))[0]
        if C < 1:
            raise ValueError("cell_signals arrays must have length >= 1")
        dist = np.zeros(C, np.float64)
        n_used = 0
        for name in FAULT_SIGNALS:
            if name not in cell_signals or name not in self._ewm:
                continue
            pred = np.asarray(cell_signals[name], np.float64)
            scale = max(float(np.max(np.abs(pred))), 1e-12)
            dist += np.abs(pred - self._ewm[name]) / scale
            n_used += 1
        if n_used == 0:
            return np.full(C, 1.0 / C)
        z = -(dist / n_used) / self.temperature
        w = np.exp(z - z.max())
        return w / w.sum()
