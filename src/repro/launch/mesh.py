"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — tests stay on 1 CPU device; only the
dry-run subprocess sets the 512-device placeholder environment.

Topology: TPU v5e pods of 256 chips. Single pod = (data=16, model=16) —
"model" maps onto the torus dimension with all-to-all ICI so TP/EP
collectives stay one hop; "data" rings over the other dimension. Multi-pod
adds the slowest "pod" axis over DCN: pure data parallelism (gradient
all-reduce only), the standard hierarchy.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (axes exist, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))
