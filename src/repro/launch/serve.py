"""Batched serving driver: prefill + greedy decode of synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
      --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.mesh import make_host_mesh, mesh_axis_sizes
from repro.models.layers import unbox
from repro.models.registry import get_family
from repro.serve.engine import generate
from repro.sharding import policy as policy_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh()
    pol = policy_lib.resolve(cfg, mesh_axis_sizes(mesh), args.batch,
                             "decode")
    fam = get_family(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = unbox(fam.init_params(cfg, pol, key))
    prompts = np.asarray(jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size))
    embeds = None
    if cfg.family == "encdec":
        embeds = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model)) * 0.02

    with mesh:
        t0 = time.time()
        out = generate(cfg, pol, params, prompts, max_new=args.max_new,
                       embeds=embeds)
        dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s); sample: {out[0][:8].tolist()}")
    assert out.shape == (args.batch, args.max_new)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    return out


if __name__ == "__main__":
    main()
