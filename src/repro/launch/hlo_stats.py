"""Parse collective-communication traffic out of optimized HLO text.

``compiled.cost_analysis()`` reports FLOPs and memory bytes but NOT
collective bytes, so the roofline's third term comes from scanning the
post-SPMD HLO for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops and summing their operand sizes.

CRITICAL ACCOUNTING DETAIL: our models scan over layers, so the collectives
live inside ``while`` bodies that XLA's static analyses count ONCE. This
parser builds the computation call graph (ENTRY -> while bodies -> nested
whiles), extracts each loop's trip count from its condition computation
(the ``constant(L)`` of the scan bound), and multiplies every collective by
its loop multiplicity — e.g. a per-layer all-reduce in a 40-layer scan
counts 40x. The same undercount afflicts cost_analysis() FLOPs, which is
why the roofline's compute term is analytic (benchmarks/roofline.py) and
the HLO numbers are a cross-check.

Per-device link traffic uses the standard ring factors with the
replica-group size parsed from each op.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*\S.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")
_CALL_RE = re.compile(
    r"(?:to_apply|calls)=%?([\w\.\-]+)|"
    r"(?:true_computation|false_computation)=%?([\w\.\-]+)|"
    r"branch_computations=\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class _Comp:
    collectives: list            # (opcode, operand_bytes, group_size)
    whiles: list                 # (cond_name, body_name)
    calls: list                  # other computation names (x1)
    max_const: int = 1


@dataclasses.dataclass
class CollectiveStats:
    op_bytes: dict               # opcode -> operand bytes (loop-scaled)
    op_count: dict               # opcode -> instruction count (loop-scaled)
    link_bytes_per_device: float  # ring-model per-device traffic estimate
    n_whiles: int = 0

    def total_bytes(self) -> float:
        return float(sum(self.op_bytes.values()))


def _parse_computations(hlo_text: str) -> tuple[dict, str]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip()) if "{" in line else None
        if m and "->" in line:
            cur = m.group(2)
            comps[cur] = _Comp([], [], [])
            if m.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}":
            cur = None
            continue
        comp = comps[cur]
        for c in _CONST_RE.findall(s):
            comp.max_const = max(comp.max_const, int(c))
        mw = _WHILE_RE.search(s)
        if mw:
            comp.whiles.append((mw.group(1), mw.group(2)))
            continue
        mc = _COLL_RE.search(s)
        if mc and mc.group("start") != "-done":
            # operands are printed without inline types in optimized HLO;
            # use the RESULT shape and per-opcode operand conventions.
            shapes = _SHAPE_RE.findall(mc.group("out"))
            ob = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
            if mc.group("start") == "-start":
                ob //= 2          # start-op results carry (operand, result)
            g = 1
            mg = _GROUPS_RE.search(s)
            if mg:
                g = len(mg.group(1).split(","))
            else:
                mg2 = _GROUPS2_RE.search(s)
                if mg2:
                    g = int(mg2.group(2))
            if ob:
                comp.collectives.append((mc.group("op"), ob, max(g, 2)))
        for mcall in _CALL_RE.finditer(s):
            name = mcall.group(1) or mcall.group(2)
            if name:
                comp.calls.append(name)
            elif mcall.group(3):
                comp.calls.extend(
                    x.strip().lstrip("%") for x in mcall.group(3).split(","))
    return comps, entry


def collective_stats(hlo_text: str) -> CollectiveStats:
    comps, entry = _parse_computations(hlo_text)
    op_bytes: dict[str, float] = defaultdict(float)
    op_count: dict[str, float] = defaultdict(float)
    link = 0.0
    n_whiles = 0

    def visit(name: str, mult: float, depth: int):
        nonlocal link, n_whiles
        comp = comps.get(name)
        if comp is None or depth > 12:
            return
        for opcode, ob, g in comp.collectives:
            # ob = RESULT bytes. Ring-model per-device traffic:
            #   all-reduce:     result == operand,  2*(g-1)/g * bytes
            #   all-gather:     result = g * shard, (g-1)/g * result
            #   reduce-scatter: operand = g * result, (g-1)/g * operand
            #   all-to-all:     (g-1)/g * result
            #   permute:        result
            f = (g - 1) / g
            if opcode == "all-reduce":
                opnd, traffic = ob, 2 * f * ob
            elif opcode == "all-gather":
                opnd, traffic = ob // g, f * ob
            elif opcode == "reduce-scatter":
                opnd, traffic = ob * g, f * ob * g
            elif opcode == "all-to-all":
                opnd, traffic = ob, f * ob
            else:
                opnd, traffic = ob, ob
            op_bytes[opcode] += mult * opnd
            op_count[opcode] += mult
            link += mult * traffic
        for cond, body in comp.whiles:
            trip = comps[cond].max_const if cond in comps else 1
            n_whiles += 1
            visit(body, mult * trip, depth + 1)
            visit(cond, mult * trip, depth + 1)
        for callee in comp.calls:
            visit(callee, mult, depth + 1)

    if entry:
        visit(entry, 1.0, 0)
    else:  # fallback: flat scan, no loop scaling
        for name in comps:
            visit(name, 1.0, 11)
    return CollectiveStats(op_bytes=dict(op_bytes), op_count=dict(op_count),
                           link_bytes_per_device=link, n_whiles=n_whiles)
