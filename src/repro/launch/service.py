"""Streaming-service driver: the closed-loop scale-ratio controller.

  PYTHONPATH=src python -m repro.launch.service --scenario intensity_step \\
      --jobs 2000 --window-jobs 250 --stride-jobs 125
plays one drift scenario (see `repro.workload.windows.drift_scenarios`)
through the monitor → decide → actuate loop of `repro.service` and prints
the tick log plus each controller's regret scorecard. ``--chaos`` runs
the fault-aware service instead: a 3-cell fault-regime axis (harsh /
moderate / calm, the harsh cell playing the true environment), the
risk-aware `FaultAwareController` beside its fault-blind foils, lost
work scored per controller. The full multi-scenario study with gates is
`benchmarks/controller_sweep.py` (same flag).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.des import ChaosConfig
from repro.service import ServiceConfig, run_service
from repro.service.driver import default_controllers
from repro.workload.windows import drift_scenarios


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="intensity_step",
                    help="steady | intensity_ramp | intensity_step | "
                         "homogeneity_ramp | homogeneity_step")
    ap.add_argument("--jobs", type=int, default=2000)
    ap.add_argument("--segments", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=100)
    ap.add_argument("--window-jobs", type=int, default=250)
    ap.add_argument("--stride-jobs", type=int, default=None)
    ap.add_argument("--s-prop", type=float, default=0.05)
    ap.add_argument("--mode", default="auto",
                    help="oracle dispatch layout (auto|seq|chunked|fused)")
    ap.add_argument("--float64", action="store_true",
                    help="run the oracle in float64 (scoped x64 opt-in)")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-aware service: sweep a 3-cell fault-regime "
                         "axis per tick, add the risk-aware controller")
    ap.add_argument("--risk-lambda", type=float, default=0.1,
                    help="wait-seconds per machine-second of expected lost "
                         "work (with --chaos; default 0.1)")
    args = ap.parse_args(argv)

    flows = drift_scenarios(n_jobs=args.jobs, nodes=args.nodes,
                            n_segments=args.segments)
    if args.scenario not in flows:
        raise SystemExit(f"unknown scenario {args.scenario!r}; "
                         f"available: {sorted(flows)}")
    wl = flows[args.scenario]
    chaos = None
    if args.chaos:
        chaos = ChaosConfig(mtbf_chip_hours=np.array([25.0, 100.0, 800.0]),
                            ckpt_period=300.0, straggler_prob=0.1,
                            straggler_factor=np.array([4.0, 1.5, 1.5]),
                            seed=11)
    config = ServiceConfig(window_jobs=args.window_jobs,
                           stride_jobs=args.stride_jobs,
                           s_prop=args.s_prop, mode=args.mode,
                           dtype="float64" if args.float64 else "float32",
                           chaos=chaos, risk_lambda=args.risk_lambda)
    out = run_service(wl, config, default_controllers(config))

    print(f"[service] {args.scenario}: {out['n_ticks']} ticks of "
          f"{config.window_jobs} jobs over {len(wl.submit)} total "
          f"({out['config']['n_dropped_jobs']} dropped past the last "
          f"window), {len(config.ks)} candidate k's per tick"
          + (f", {config.n_chaos_cells}-cell fault axis (env: harsh)"
             if args.chaos else ""))
    if args.chaos:
        print(f"{'tick':>4} {'offered':>8} {'best k':>7} {'fault-aware':>11} "
              f"{'hyst k':>7} {'w(harsh)':>9} {'oracle':>8}")
        for t in out["ticks"]:
            fa = t["controllers"]["fault_aware"]
            print(f"{t['tick']:>4} {t['signals']['offered_load']:>8.3f} "
                  f"{t['best_k']:>7g} {fa['realized_k']:>11g} "
                  f"{t['controllers']['hysteresis']['realized_k']:>7g} "
                  f"{fa['weights'][0]:>9.2f} {t['oracle_ms']:>6.0f}ms")
    else:
        print(f"{'tick':>4} {'offered':>8} {'best k':>7} {'plateau k':>9} "
              f"{'hyst k':>7} {'naive k':>8} {'oracle':>8}")
        for t in out["ticks"]:
            print(f"{t['tick']:>4} {t['signals']['offered_load']:>8.3f} "
                  f"{t['best_k']:>7g} {t['plateau_k']:>9g} "
                  f"{t['controllers']['hysteresis']['realized_k']:>7g} "
                  f"{t['controllers']['naive']['realized_k']:>8g} "
                  f"{t['oracle_ms']:>6.0f}ms")
    for name, s in out["controllers"].items():
        line = (f"[service] {name}: switches={s['switches']} "
                f"rel_regret_wait={s['rel_regret_wait']:.4f} "
                f"mean_regret_useful={s['mean_regret_useful']:.5f} "
                f"vs_plateau={s['mean_wait_vs_plateau']:+.2f}s/tick")
        if args.chaos:
            line += f" lost_work={s['total_lost_work']:.0f} machine-s"
        print(line)


if __name__ == "__main__":
    main()
