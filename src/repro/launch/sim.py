"""Paper-experiment driver: run the Packet DES over a (k x S) grid.

  PYTHONPATH=src python -m repro.launch.sim --workload homog0.85 \\
      --init-prop 0.05 --jobs 5000
prints the scale-ratio sweep for one workload (paper Figs. 5-14), plus the
plateau threshold the paper's method hands the JMS administrator.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import (PAPER_SCALE_RATIOS, plateau_threshold,
                        run_baselines, run_packet_grid)
from repro.workload.lublin import (WorkloadParams, generate_workload,
                                   paper_workloads)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="homog0.85",
                    help="hetero|homog + load, e.g. homog0.90")
    ap.add_argument("--jobs", type=int, default=5000)
    ap.add_argument("--init-prop", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--baselines", action="store_true")
    args = ap.parse_args(argv)

    homog = args.workload.startswith("homog")
    load = float(args.workload[-4:])
    wl = generate_workload(WorkloadParams(
        n_jobs=args.jobs, nodes=100 if homog else 500, load=load,
        homogeneous=homog, seed=args.seed + (1 if homog else 0)))
    print(f"[sim] workload {args.workload}: {wl.n_jobs} jobs, "
          f"calculated load {wl.calculated_load():.3f}, "
          f"M={wl.params.nodes}")

    grid = run_packet_grid(wl, s_props=[args.init_prop])
    ks = np.asarray(PAPER_SCALE_RATIOS)
    aw = np.asarray(grid.avg_wait)[:, 0]
    mw = np.asarray(grid.med_wait)[:, 0]
    fu = np.asarray(grid.full_util)[:, 0]
    uu = np.asarray(grid.useful_util)[:, 0]
    print(f"{'k':>8} {'avg_wait':>10} {'med_wait':>10} "
          f"{'full_util':>9} {'useful':>7}")
    for i, k in enumerate(ks):
        print(f"{k:8.1f} {aw[i]:10.1f} {mw[i]:10.1f} {fu[i]:9.3f} "
              f"{uu[i]:7.3f}")
    thr = plateau_threshold(ks, aw)
    print(f"[sim] queue-time plateau threshold: k >= {thr.threshold} "
          f"(plateau {thr.plateau:.1f}s)")
    if args.baselines:
        bl = run_baselines(wl, s_props=[args.init_prop])
        for name, m in bl.items():
            print(f"[sim] baseline {name}: avg_wait="
                  f"{float(np.asarray(m.avg_wait)[0]):.1f}s "
                  f"useful={float(np.asarray(m.useful_util)[0]):.3f}")


if __name__ == "__main__":
    main()
