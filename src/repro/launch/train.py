"""End-to-end training driver.

Runs any assigned arch (reduced or full config) on the local mesh with the
full substrate: sharding policy, microbatched train step, async
checkpointing with restart-on-failure, synthetic data pipeline. On a real
TPU pod the same script runs under ``jax.distributed.initialize()`` with
the production mesh; on this CPU host use ``--reduced`` (the full configs
are exercised by the dry-run).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \\
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck --resume
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.ckpt import CheckpointManager
from repro.launch.mesh import make_host_mesh, mesh_axis_sizes
from repro.sharding import policy as policy_lib
from repro.train import data as data_lib
from repro.train import optim as optim_lib
from repro.train.step import TrainState, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", type=str, default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh()
    pol = policy_lib.resolve(cfg, mesh_axis_sizes(mesh), args.batch, "train")
    ocfg = optim_lib.AdamWConfig(lr=args.lr, warmup_steps=10,
                                 total_steps=args.steps)
    state, axes = init_state(cfg, pol, jax.random.PRNGKey(args.seed), ocfg)
    step_fn = jax.jit(make_train_step(cfg, pol, ocfg, n_micro=args.n_micro))
    start = 0

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume:
        try:
            state, meta = mgr.restore_latest(state)
            start = meta["step"]
            print(f"[train] resumed from step {start}")
        except FileNotFoundError:
            pass

    it = data_lib.batches(cfg, data_lib.DataConfig(
        batch=args.batch, seq=args.seq, seed=args.seed))
    t0 = time.time()
    with mesh:
        for i in range(start, args.steps):
            state, mets = step_fn(state, next(it))
            if (i + 1) % args.log_every == 0 or i == start:
                tput = args.batch * args.seq * (i + 1 - start) / \
                    (time.time() - t0)
                print(f"[train] step {i + 1:5d} loss={float(mets['loss']):.4f} "
                      f"lr={float(mets['lr']):.2e} "
                      f"gnorm={float(mets['grad_norm']):.3f} "
                      f"tok/s={tput:.0f}", flush=True)
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state, {"arch": cfg.name})
    if mgr:
        mgr.save(args.steps, state, {"arch": cfg.name})
        mgr.wait()
    print(f"[train] done: {args.steps} steps, "
          f"final loss {float(mets['loss']):.4f}")
    return float(mets["loss"])


if __name__ == "__main__":
    main()
