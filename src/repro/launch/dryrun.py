import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

DOC = """Multi-pod dry-run: prove the distribution config is coherent.

For every assigned (architecture x input-shape) cell, on the single-pod
(16 x 16 = 256 chips) and multi-pod (2 x 16 x 16 = 512 chips) production
meshes:

  * resolve the sharding policy (attention mode, KV replication, expert
    padding, batch axes),
  * build the exact step the cell represents (train_step for train shapes,
    last-token prefill for prefill shapes, serve_step/decode for decode
    shapes),
  * ``jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)``
    and ``.compile()`` — no array is ever allocated,
  * record ``memory_analysis()`` (proves it fits), ``cost_analysis()``
    (FLOPs/bytes for the roofline) and the collective traffic parsed from
    the optimized HLO.

Usage:
  python -m repro.launch.dryrun --cells yi-6b:train_4k --multi-pod
  python -m repro.launch.dryrun --all --out benchmarks/results/dryrun.json
"""
__doc__ = DOC

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, Shape, cells, get_config, input_specs
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.models.config import ModelConfig
from repro.models.layers import unbox
from repro.models.registry import get_family
from repro.sharding import policy as policy_lib
from repro.train import optim as optim_lib
from repro.train.step import make_train_step

KEY_SPEC = jax.ShapeDtypeStruct((2,), jnp.uint32)


def param_specs(cfg: ModelConfig, pol, mesh):
    """(ShapeDtypeStruct tree, NamedSharding tree) for the parameters —
    via eval_shape on init: zero allocation."""
    fam = get_family(cfg)
    boxed = jax.eval_shape(lambda k: fam.init_params(cfg, pol, k), KEY_SPEC)
    shapes, axes = unbox(boxed)
    shard = jax.tree.map(
        lambda ax: jax.sharding.NamedSharding(mesh, pol.spec(ax)), axes,
        is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))
    return shapes, shard


def _batch_sharding(cfg, pol, mesh, specs):
    out = {}
    for name, s in specs.items():
        ax = ("batch",) + (None,) * (len(s.shape) - 1)
        out[name] = jax.sharding.NamedSharding(mesh, pol.spec(ax))
    return out


def _moment_dtype(cfg: ModelConfig) -> str:
    # >=100B params: bf16 moments (gradient/optimizer compression)
    return "bfloat16" if cfg.name.startswith("arctic") else "float32"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               remat: str | None = None, strategy: str = "auto"):
    """Lower + compile one cell. Returns a result dict."""
    cfg = get_config(arch)
    if remat is not None:
        cfg = cfg.with_(remat=remat)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes_sizes = mesh_axis_sizes(mesh)
    pol = policy_lib.resolve(cfg, axes_sizes, shape.batch, shape.kind,
                             seq=shape.seq, strategy=strategy)
    fam = get_family(cfg)
    n_dev = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": n_dev, "policy": {
            "strategy": pol.strategy,
            "attn_mode": pol.attn_mode, "decode_attn": pol.decode_attn,
            "kv_repeat": pol.kv_repeat, "expert_pad": pol.expert_pad,
            "batch_axes": str(pol.batch_axes), "notes": list(pol.notes),
        },
    }
    t0 = time.time()

    with mesh:
        p_shapes, p_shard = param_specs(cfg, pol, mesh)
        in_specs = input_specs(cfg, shape)
        b_shard = _batch_sharding(cfg, pol, mesh, in_specs)
        repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

        if shape.kind == "train":
            ocfg = optim_lib.AdamWConfig(moment_dtype=_moment_dtype(cfg))
            step = make_train_step(cfg, pol, ocfg)
            mdt = jnp.dtype(ocfg.moment_dtype)
            m_shapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p_shapes)
            state_shapes = {"params": p_shapes, "opt": optim_lib.OptState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                m=m_shapes, v=m_shapes)}
            state_shard = {"params": p_shard, "opt": optim_lib.OptState(
                step=repl, m=p_shard, v=p_shard)}

            def step_fn(state, batch):
                from repro.train.step import TrainState
                st = TrainState(state["params"], state["opt"])
                st, mets = step(st, batch)
                return {"params": st.params, "opt": st.opt}, mets

            lowered = jax.jit(
                step_fn,
                in_shardings=(state_shard, b_shard),
                out_shardings=(state_shard, repl),
                donate_argnums=(0,),
            ).lower(state_shapes, in_specs)

        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                hidden, _ = fam.forward(cfg, pol, params, batch["tokens"],
                                        batch.get("embeds"))
                from repro.models.layers import unembed
                return unembed(cfg, pol, hidden[:, -1:], params["embed"])

            lowered = jax.jit(
                prefill_fn,
                in_shardings=(p_shard, b_shard),
                out_shardings=repl,
            ).lower(p_shapes, in_specs)

        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: fam.init_cache(cfg, pol, shape.batch, shape.seq))
            cax = fam.cache_axes(cfg)
            cache_shard = jax.tree.map(
                lambda ax: jax.sharding.NamedSharding(mesh, pol.spec(ax)),
                cax, is_leaf=lambda x: isinstance(x, tuple) and
                all(isinstance(e, (str, type(None))) for e in x))

            def decode_fn(params, cache, tokens):
                return fam.decode_step(cfg, pol, params, cache, tokens)

            lowered = jax.jit(
                decode_fn,
                in_shardings=(p_shard, cache_shard,
                              b_shard["tokens"]),
                out_shardings=(repl, cache_shard),
                donate_argnums=(1,),
            ).lower(p_shapes, cache_shapes, in_specs["tokens"])

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ca = compiled.cost_analysis() or {}
        rec["flops"] = float(ca.get("flops", -1.0))
        rec["bytes_accessed"] = float(ca.get("bytes accessed", -1.0))
        rec["transcendentals"] = float(ca.get("transcendentals", 0.0))

        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes")
                if hasattr(ma, k)} if ma is not None else None
        except Exception as e:          # CPU backend may not implement it
            rec["memory_analysis"] = f"unavailable: {e}"

        hlo = compiled.as_text()
        cs = collective_stats(hlo)
        rec["collectives"] = {
            "op_bytes": cs.op_bytes, "op_count": cs.op_count,
            "link_bytes_per_device": cs.link_bytes_per_device,
        }
        rec["hlo_lines"] = hlo.count("\n")
        rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=str, default="",
                    help="comma-separated arch:shape list")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", type=str, default=None)
    ap.add_argument("--strategy", type=str, default="auto",
                    choices=["auto", "tp", "dp_zero1", "dp_zero3", "dp_seq"])
    ap.add_argument("--out", type=str, default="")
    args = ap.parse_args()

    if args.all:
        todo = cells()
    else:
        todo = [tuple(c.split(":")) for c in args.cells.split(",") if c]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch, shape in todo:
        for mp in meshes:
            tag = f"{arch}:{shape}:{'multi' if mp else 'single'}"
            try:
                rec = lower_cell(arch, shape, mp, remat=args.remat,
                                 strategy=args.strategy)
                print(f"[dryrun] OK   {tag:55s} lower={rec['lower_s']}s "
                      f"compile={rec['compile_s']}s "
                      f"flops={rec['flops']:.3e}", flush=True)
            except Exception as e:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single", "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[dryrun] FAIL {tag:55s} {type(e).__name__}: "
                      f"{str(e)[:200]}", flush=True)
            results.append(rec)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {len(results)} records -> {args.out}")
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(results)} cells compiled")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
