"""Multi-host bootstrap for real TPU pods (the non-dry-run path).

On hardware, each host runs this once before building the mesh; the
placeholder-device dry-run never calls it. Supports both explicit
coordinator env vars (SLURM/MPI-style clusters) and TPU-pod autodetection
(GKE/queued resources, where jax.distributed.initialize() needs no args).

Environment (explicit mode):
  REPRO_COORDINATOR   host:port of process 0
  REPRO_NUM_PROCESSES total host count
  REPRO_PROCESS_ID    this host's index

Elastic restarts: the cluster layer requeues a meta-job's remainder after
a failure; the replacement slice may have a different host count. Restart
flow = ``initialize()`` on the new slice -> ``make_production_mesh()`` (or
any slice mesh) -> ``repro.ckpt.restore_checkpoint(..., shardings=...)``
which device_puts every leaf with the *new* mesh's shardings (elastic
re-shard), then resume from the restored step.
"""
from __future__ import annotations

import os

import jax


def initialize(timeout_s: int = 300) -> dict:
    """Initialize jax.distributed; returns topology facts for logging."""
    coord = os.environ.get("REPRO_COORDINATOR")
    if coord:
        jax.distributed.initialize(
            coordinator_address=coord,
            num_processes=int(os.environ["REPRO_NUM_PROCESSES"]),
            process_id=int(os.environ["REPRO_PROCESS_ID"]),
            initialization_timeout=timeout_s)
    else:
        # TPU pod autodetection (GKE / queued resources metadata)
        jax.distributed.initialize()
    return {
        "process_id": jax.process_index(),
        "n_processes": jax.process_count(),
        "local_devices": jax.local_device_count(),
        "global_devices": jax.device_count(),
    }


def host_data_shard() -> tuple[int, int]:
    """(host_id, n_hosts) for the input pipeline — each host generates or
    reads only its own slice of the global batch (repro.train.data)."""
    return jax.process_index(), jax.process_count()


def assert_mesh_spans_processes(mesh) -> None:
    """Sanity check: the production mesh must use every addressable device
    across all hosts (catches mismatched slice bookings)."""
    want = jax.device_count()
    got = mesh.devices.size
    if got != want:
        raise RuntimeError(
            f"mesh has {got} devices but the slice exposes {want}; "
            "slice booking and mesh shape disagree")
