"""xLSTM language model (mLSTM + sLSTM blocks) — attention-free [ssm].

Faithful to the xLSTM block structure (arXiv:2405.04517): the model is a
stack of pre-norm residual blocks following ``cfg.xlstm_pattern`` (e.g. 7
mLSTM : 1 sLSTM). Because mLSTM and sLSTM blocks have different parameter
shapes, the layer scan runs over *pattern repeats* (one superblock = one
pattern period), keeping compiled HLO size O(pattern), not O(depth).

mLSTM: matrix-memory cell C_t = f_t C_{t-1} + i_t v_t k_t^T with per-head
scalar gates, computed in the **chunkwise-parallel form**: within a chunk the
output is an attention-like einsum with decay matrix A_ts = i_s exp(F_t-F_s)
(F = cumsum log f), between chunks a small lax.scan carries (C, n). This is
the TPU-native adaptation: the sequential scan becomes MXU matmuls.
Numerics: we use sigmoid input/forget gates (log-space decay accumulation,
always stable in f32) instead of the paper's exp-gate + running max
stabilizer; DESIGN.md records this simplification.

sLSTM: scalar-memory cell with exponential gating (running-max stabilized,
as in the paper) and block-diagonal hidden-to-hidden recurrence — truly
sequential, implemented as lax.scan over time.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding.policy import Policy

PROJ_FACTOR = 2          # mLSTM up-projection factor
SLSTM_FF = 4 / 3         # sLSTM post-MLP factor (GeGLU)


def _slstm_ff(d: int) -> int:
    """4/3 * d rounded up to 128 so the TP axis (16) always divides it."""
    return ((int(SLSTM_FF * d) + 127) // 128) * 128


def _pattern(cfg: ModelConfig) -> tuple[str, ...]:
    pat = cfg.xlstm_pattern or ("m",)
    assert cfg.n_layers % len(pat) == 0, (cfg.n_layers, pat)
    return pat


# ------------------------------------------------------------------ mLSTM

def _mlstm_dims(cfg: ModelConfig):
    di = PROJ_FACTOR * cfg.d_model
    H = cfg.n_heads
    dh = di // H
    return di, H, dh


def mlstm_block_init(key, cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.pdtype()
    di, H, dh = _mlstm_dims(cfg)
    ku, kc, kq, kk, kv, kg, ko = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(dh)

    def bd(k, axes):  # block-diagonal per-head projection [H, dh, dh]
        w = jax.random.normal(k, (H, dh, dh), jnp.float32) * s
        return L.Boxed(w.astype(dt), axes)

    return {
        "ln": L.norm_init(d, dt, cfg.norm_type),
        "w_up": L.dense_init(ku, d, 2 * di, ("embed_fsdp", "rnn"), dt),
        "conv": L.Boxed(jax.random.normal(kc, (cfg.conv_width, di),
                                          jnp.float32).astype(dt) * 0.1,
                        (None, "rnn")),
        # q/k contract the sharded conv features (psum, replicated out);
        # v shards its *output* dim so the matrix state C and the block
        # output stay model-sharded end to end.
        "wq": bd(kq, (None, "rnn", None)), "wk": bd(kk, (None, "rnn", None)),
        "wv": bd(kv, (None, None, "rnn")),
        "w_gate": L.dense_init(kg, di, 2 * H, ("rnn", None), jnp.float32),
        "gate_bias": L.Boxed(jnp.array([1.0, -1.0] * H, jnp.float32)
                             .reshape(2 * H), (None,)),
        "gn": L.norm_init(di, dt, "rmsnorm"),
        "w_down": L.dense_init(ko, di, d, ("rnn", "embed_fsdp"), dt),
    }


def _causal_conv(x, kernel, state=None):
    """x: [B, S, C]; kernel: [W, C] depthwise causal conv.
    state: [B, W-1, C] trailing inputs of the previous call (decode)."""
    W = kernel.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(W))
    return out, xp[:, -(W - 1):]


class MLSTMState(NamedTuple):
    C: jnp.ndarray     # [B, H, dk, dv]
    n: jnp.ndarray     # [B, H, dk]


def mlstm_scan(q, k, v, logf, logi, state: MLSTMState, chunk: int,
               pol=None):
    """Chunkwise-parallel mLSTM.

    q,k,v: [B, S, H, dh]; logf, logi: [B, S, H] (<= 0).
    Returns (out [B,S,H,dh], final state).
    """
    B, S, H, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    chunk = min(chunk, S)
    S0 = S
    pad = (-S) % chunk
    if pad:
        # pad with identity steps: f=1 (logf=0) carries state, i=0 (logi=-inf)
        # contributes nothing, so the final state is exact.
        zp = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v = zp(q), zp(k), zp(v)
        logf = zp(logf)
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=-1e30)
        S = S + pad
    nc = S // chunk
    r = lambda x: x.reshape(B, nc, chunk, *x.shape[2:]).swapaxes(0, 1)
    qs, ks, vs, lfs, lis = map(r, (q, k, v, logf, logi))

    def step(st: MLSTMState, xs):
        qc, kc, vc, lf, li = xs          # [B, chunk, H, ...]
        F = jnp.cumsum(lf, axis=1)                       # [B, c, H]
        # intra-chunk decay matrix A[t, s] = exp(F_t - F_s + li_s), s <= t
        ti = jnp.arange(chunk)
        causal = ti[:, None] >= ti[None, :]
        logA = (F[:, :, None] - F[:, None, :] + li[:, None, :])  # [B,t,s,H]
        A = jnp.where(causal[None, :, :, None], jnp.exp(logA), 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * scale * A
        num = jnp.einsum("btsh,bshd->bthd", scores, vc)
        # inter-chunk contribution from carried state
        decay = jnp.exp(F)                               # [B, c, H]
        qCin = jnp.einsum("bthd,bhde->bthe", qc, st.C) * scale
        num = num + decay[..., None] * qCin
        nvec = jnp.einsum("btsh,bshd->bthd", scores / scale, kc) \
            + decay[..., None] * st.n[:, None]
        denom = jnp.abs(jnp.einsum("bthd,bthd->bth", qc, nvec)) * scale
        out = num / jnp.maximum(denom, 1.0)[..., None]
        # state update to chunk end
        dAll = jnp.exp(F[:, -1])                         # [B, H]
        w = jnp.exp(F[:, -1][:, None] - F + li)          # [B, c, H]
        C1 = dAll[:, :, None, None] * st.C + \
            jnp.einsum("bsh,bshd,bshe->bhde", w, kc, vc)
        n1 = dAll[:, :, None] * st.n + jnp.einsum("bsh,bshd->bhd", w, kc)
        if pol is not None:   # pin carry sharding (see slstm_seq note)
            C1 = pol.constrain(C1, "batch", None, None, "rnn")
            n1 = pol.constrain(n1, "batch", None, None)
        return MLSTMState(C1, n1), out

    state, outs = jax.lax.scan(step, state, (qs, ks, vs, lfs, lis))
    return outs.swapaxes(0, 1).reshape(B, S, H, dh)[:, :S0], state


def mlstm_forward(p, cfg: ModelConfig, pol: Policy, x, state=None,
                  return_state=False):
    """x: [B, S, d]. Chunked mLSTM block body (everything but residual)."""
    B, S, d = x.shape
    di, H, dh = _mlstm_dims(cfg)
    h = L.apply_norm(p["ln"], x, cfg.norm_eps, cfg.norm_type)
    up = h @ p["w_up"]
    u, z = jnp.split(up, 2, axis=-1)                    # [B, S, di] each
    u = pol.constrain(u, "batch", "seq", "rnn")
    cell_state, conv_state = state if state is not None else (None, None)
    cv, conv_state = _causal_conv(u, p["conv"], conv_state)
    c = jax.nn.silu(cv)
    cH = c.reshape(B, S, H, dh)
    uH = u.reshape(B, S, H, dh)
    q = jnp.einsum("bshd,hde->bshe", cH, p["wq"])
    k = jnp.einsum("bshd,hde->bshe", cH, p["wk"]) / math.sqrt(dh)
    v = jnp.einsum("bshd,hde->bshe", uH, p["wv"])
    v = pol.constrain(v, "batch", "seq", None, "rnn")
    gates = c.astype(jnp.float32) @ p["w_gate"] + p["gate_bias"]
    logf = jax.nn.log_sigmoid(gates[..., :H])
    logi = jax.nn.log_sigmoid(gates[..., H:])
    if cell_state is None:
        # constrain the scan carry: without this SPMD may choose to
        # replicate the state and all-reduce every chunk step
        cell_state = MLSTMState(
            C=pol.constrain(jnp.zeros((B, H, dh, dh), jnp.float32),
                            "batch", None, None, "rnn"),
            n=pol.constrain(jnp.zeros((B, H, dh), jnp.float32),
                            "batch", None, None))
    out, cell_state = mlstm_scan(q.astype(jnp.float32),
                                 k.astype(jnp.float32),
                                 v.astype(jnp.float32), logf, logi,
                                 cell_state, cfg.mlstm_chunk, pol=pol)
    out = out.reshape(B, S, di).astype(x.dtype)
    out = L.apply_norm(p["gn"], out, cfg.norm_eps, "rmsnorm")
    y = (out * jax.nn.silu(z)) @ p["w_down"]
    return (y, (cell_state, conv_state)) if return_state else y


# ------------------------------------------------------------------ sLSTM

def slstm_block_init(key, cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.pdtype()
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    ff = _slstm_ff(d)

    def wmat(k):
        w = jax.random.normal(k, (d, 4 * d), jnp.float32) * s
        return L.Boxed(w.astype(dt), ("embed_fsdp", "rnn"))

    def rmat(k):  # block-diagonal recurrence [H, dh, 4*dh]
        w = jax.random.normal(k, (H, dh, 4 * dh), jnp.float32) / math.sqrt(dh)
        return L.Boxed(w.astype(dt), (None, None, "rnn"))

    return {
        "ln": L.norm_init(d, dt, cfg.norm_type),
        "w": wmat(ks[0]),
        "r": rmat(ks[1]),
        "bias": L.Boxed(jnp.zeros((4 * d,), jnp.float32), (None,)),
        "gn": L.norm_init(d, dt, "rmsnorm"),
        "up": L.dense_init(ks[2], d, 2 * ff, ("embed_fsdp", "mlp"), dt),
        "down": L.dense_init(ks[3], ff, d, ("mlp", "embed_fsdp"), dt),
    }


class SLSTMState(NamedTuple):
    h: jnp.ndarray     # [B, d]
    c: jnp.ndarray     # [B, d]
    n: jnp.ndarray     # [B, d]
    m: jnp.ndarray     # [B, d]  running log-max stabilizer


def slstm_seq(p, cfg: ModelConfig, pol: Policy, wx, state: SLSTMState):
    """wx: [B, S, 4d] precomputed input projections; scan over time."""
    B, S, _ = wx.shape
    H = cfg.n_heads
    d = cfg.d_model
    dh = d // H
    r = p["r"].astype(jnp.float32)
    cb = lambda a: pol.constrain(a, "batch", "rnn")   # pin carry sharding:
    # without this SPMD replicates the scan carry and inserts a per-STEP
    # all-reduce (measured: 24.6k ARs / 424 GB per train step)

    def step(st: SLSTMState, wt):
        hH = st.h.reshape(B, H, dh)
        rec = jnp.einsum("bhd,hde->bhe", hH, r).reshape(B, 4 * d)
        pre = wt + rec + p["bias"]
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(zt)
        o = jax.nn.sigmoid(ot)
        m_new = jnp.maximum(ft + st.m, it)               # exp-gating stabilizer
        i = jnp.exp(it - m_new)
        f = jnp.exp(ft + st.m - m_new)
        c = f * st.c + i * z
        n = f * st.n + i
        h = o * c / jnp.maximum(n, 1.0)
        return SLSTMState(cb(h), cb(c), cb(n), cb(m_new)), h

    state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1).astype(jnp.float32))
    return hs.swapaxes(0, 1), state


def slstm_forward(p, cfg: ModelConfig, pol: Policy, x, state=None,
                  return_state=False):
    B, S, d = x.shape
    h = L.apply_norm(p["ln"], x, cfg.norm_eps, cfg.norm_type)
    wx = h @ p["w"]
    wx = pol.constrain(wx, "batch", "seq", "rnn")
    if state is None:
        z = pol.constrain(jnp.zeros((B, d), jnp.float32), "batch", "rnn")
        m0 = pol.constrain(jnp.full((B, d), -1e9, jnp.float32),
                           "batch", "rnn")
        state = SLSTMState(z, z, z, m0)   # constrained carry: see mLSTM note
    hs, state = slstm_seq(p, cfg, pol, wx, state)
    hs = L.apply_norm(p["gn"], hs.astype(x.dtype), cfg.norm_eps, "rmsnorm")
    # post-up GeGLU MLP (paper's sLSTM block)
    u = hs @ p["up"]
    a, b = jnp.split(u, 2, axis=-1)
    y = (jax.nn.gelu(a) * b) @ p["down"]
    return (y, state) if return_state else y


# ------------------------------------------------------------------ model

class XLSTMCache(NamedTuple):
    mC: jnp.ndarray    # [n_m_layers, B, H, dh, dh]
    mn: jnp.ndarray    # [n_m_layers, B, H, dh]
    mconv: jnp.ndarray  # [n_m_layers, B, W-1, di] causal-conv tails
    sh: jnp.ndarray    # [n_s_layers, B, d] x4
    sc: jnp.ndarray
    sn: jnp.ndarray
    sm: jnp.ndarray
    pos: jnp.ndarray


def init_params(cfg: ModelConfig, pol: Policy, key):
    pat = _pattern(cfg)
    reps = cfg.n_layers // len(pat)
    ke, kl, kn = jax.random.split(key, 3)
    rkeys = jax.random.split(kl, reps)

    def superblock(k):
        sub = jax.random.split(k, len(pat))
        return {f"b{i}_{t}": (mlstm_block_init(sub[i], cfg) if t == "m"
                              else slstm_block_init(sub[i], cfg))
                for i, t in enumerate(pat)}

    stacked = jax.vmap(superblock)(rkeys)
    return {
        "embed": L.embed_init(ke, L.padded_vocab(cfg), cfg.d_model,
                              cfg.pdtype()),
        "blocks": L.stack_layers(stacked),
        "norm": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
    }


def forward(cfg: ModelConfig, pol: Policy, params, tokens, embeds=None,
            positions=None):
    pat = _pattern(cfg)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype())
    x = pol.constrain(x, "batch", "seq", None)

    def body(x, bp):
        for i, t in enumerate(pat):
            p = bp[f"b{i}_{t}"]
            if t == "m":
                x = x + mlstm_forward(p, cfg, pol, x)
            else:
                x = x + slstm_forward(p, cfg, pol, x)
        return pol.constrain(x, "batch", "seq", None), None

    fn = body if cfg.remat == "none" else jax.checkpoint(body)
    x, _ = jax.lax.scan(fn, x, params["blocks"])
    x = L.apply_norm(params["norm"], x, cfg.norm_eps, cfg.norm_type)
    return x, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, pol: Policy, batch: int, max_len: int,
               dtype=jnp.float32) -> XLSTMCache:
    pat = _pattern(cfg)
    reps = cfg.n_layers // len(pat)
    di, H, dh = _mlstm_dims(cfg)
    n_m = reps * sum(1 for t in pat if t == "m")
    n_s = reps * sum(1 for t in pat if t == "s")
    d = cfg.d_model
    return XLSTMCache(
        mC=jnp.zeros((max(n_m, 1), batch, H, dh, dh), dtype),
        mn=jnp.zeros((max(n_m, 1), batch, H, dh), dtype),
        mconv=jnp.zeros((max(n_m, 1), batch, cfg.conv_width - 1, di), dtype),
        sh=jnp.zeros((max(n_s, 1), batch, d), dtype),
        sc=jnp.zeros((max(n_s, 1), batch, d), dtype),
        sn=jnp.zeros((max(n_s, 1), batch, d), dtype),
        sm=jnp.full((max(n_s, 1), batch, d), -1e9, dtype),
        pos=jnp.zeros((), jnp.int32))


def cache_axes(cfg: ModelConfig) -> XLSTMCache:
    return XLSTMCache(
        mC=("layers", "batch", None, None, "rnn"),
        mn=("layers", "batch", None, None),
        mconv=("layers", "batch", None, "rnn"),
        sh=("layers", "batch", None), sc=("layers", "batch", None),
        sn=("layers", "batch", None), sm=("layers", "batch", None),
        pos=())


def decode_step(cfg: ModelConfig, pol: Policy, params, cache: XLSTMCache,
                tokens):
    """One-token decode: recurrent state only, O(1) in context length."""
    pat = _pattern(cfg)
    reps = cfg.n_layers // len(pat)
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype())

    m_per, s_per = (sum(1 for t in pat if t == c) for c in "ms")

    def body(x, xs):
        bp, mC, mn, mcv, sh, sc, sn, sm = xs
        mi = si = 0
        nmC, nmn, nmcv, nsh, nsc, nsn, nsm = ([] for _ in range(7))
        for i, t in enumerate(pat):
            p = bp[f"b{i}_{t}"]
            if t == "m":
                st = (MLSTMState(mC[mi], mn[mi]), mcv[mi])
                y, (cell, conv) = mlstm_forward(p, cfg, pol, x, state=st,
                                                return_state=True)
                nmC.append(cell.C), nmn.append(cell.n), nmcv.append(conv)
                mi += 1
            else:
                st = SLSTMState(sh[si], sc[si], sn[si], sm[si])
                y, st = slstm_forward(p, cfg, pol, x, state=st,
                                      return_state=True)
                nsh.append(st.h), nsc.append(st.c)
                nsn.append(st.n), nsm.append(st.m)
                si += 1
            x = x + y
        pk = lambda xs: jnp.stack(xs) if xs else jnp.zeros((0,))
        return x, (pk(nmC), pk(nmn), pk(nmcv), pk(nsh), pk(nsc), pk(nsn),
                   pk(nsm))

    rs = lambda a, per: a.reshape(reps, max(per, 1), *a.shape[1:]) \
        if per else jnp.zeros((reps, 1) + a.shape[1:], a.dtype)
    xs = (params["blocks"], rs(cache.mC, m_per), rs(cache.mn, m_per),
          rs(cache.mconv, m_per),
          rs(cache.sh, s_per), rs(cache.sc, s_per), rs(cache.sn, s_per),
          rs(cache.sm, s_per))
    x, (mC, mn, mcv, sh, sc, sn, sm) = jax.lax.scan(body, x, xs)
    fl = lambda a, per, old: (a.reshape(-1, *a.shape[2:]).astype(old.dtype)
                              if per else old)
    x = L.apply_norm(params["norm"], x, cfg.norm_eps, cfg.norm_type)
    logits = L.unembed(cfg, pol, x, params["embed"])
    new = XLSTMCache(mC=fl(mC, m_per, cache.mC), mn=fl(mn, m_per, cache.mn),
                     mconv=fl(mcv, m_per, cache.mconv),
                     sh=fl(sh, s_per, cache.sh), sc=fl(sc, s_per, cache.sc),
                     sn=fl(sn, s_per, cache.sn), sm=fl(sm, s_per, cache.sm),
                     pos=cache.pos + 1)
    return logits, new
