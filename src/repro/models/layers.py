"""Shared transformer building blocks (pure JAX, logical-axis annotated).

Parameters are nested dicts of ``Boxed`` leaves — a registered pytree node
whose child is the array and whose aux data is the tuple of *logical* axis
names. Because the axes are aux data, boxed trees pass transparently through
``jax.vmap`` (layer stacking) and ``jax.lax.scan`` (layer loop); ``unbox``
splits a boxed tree into (params, axes) so train/serve code can derive
PartitionSpecs from the axes tree (see repro.sharding.partitioning).

All forward functions take a ``Policy`` (repro.sharding.policy) that decides
how attention shards on the fixed production mesh: head-parallel
(``tp_heads``, with exact GQA KV-head replication), batch-parallel
(``dp_batch``, Ulysses-style, for head counts that do not divide TP), or
unsharded. Softmax attention is computed in query chunks (exact, bounded
memory) so 32k prefill never materializes an S x S logit matrix.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding.policy import Policy

ATTN_CHUNK = 512          # query-chunk length for full-sequence attention
NEG_INF = -1e30


class Boxed:
    """Array + logical axis names. Pytree node: axes are static aux data."""
    __slots__ = ("v", "ax")

    def __init__(self, v, ax):
        self.v = v
        self.ax = tuple(ax)

    def __repr__(self):
        return f"Boxed({getattr(self.v, 'shape', self.v)}, ax={self.ax})"


jax.tree_util.register_pytree_node(
    Boxed, lambda b: ((b.v,), b.ax), lambda ax, ch: Boxed(ch[0], ax))


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    params = jax.tree.map(lambda b: b.v, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.ax, tree, is_leaf=is_boxed)
    return params, axes


def box_tree(params, axes):
    """Inverse of unbox."""
    return jax.tree.map(
        lambda v, ax: Boxed(v, ax), params, axes,
        is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))


def stack_layers(tree):
    """Prepend the 'layers' logical axis to every leaf of a vmapped init."""
    return jax.tree.map(lambda b: Boxed(b.v, ("layers",) + b.ax), tree,
                        is_leaf=is_boxed)


def dense_init(key, in_dim, out_dim, axes, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
    return Boxed(w.astype(dtype), axes)


def embed_init(key, vocab, dim, dtype):
    w = jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
    return Boxed(w.astype(dtype), ("vocab", "embed"))


def norm_init(dim, dtype, norm_type="rmsnorm"):
    p = {"scale": Boxed(jnp.ones((dim,), dtype), ("embed",))}
    if norm_type == "layernorm":
        p["bias"] = Boxed(jnp.zeros((dim,), dtype), ("embed",))
    return p


def apply_norm(p, x, eps, norm_type="rmsnorm"):
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention

def attn_init(key, cfg: ModelConfig, d_model: Optional[int] = None):
    hd = cfg.hd
    d = d_model or cfg.d_model
    dt = cfg.pdtype()
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, cfg.n_heads * hd, ("embed_fsdp", "heads"), dt),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, ("embed_fsdp", "kv_heads"), dt),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, ("embed_fsdp", "kv_heads"), dt),
        "wo": dense_init(ko, cfg.n_heads * hd, d, ("heads", "embed_fsdp"), dt),
    }


def _repeat_kv(k, repeat: int):
    """Exact GQA KV replication: kv head j -> repeat copies, so that query
    head i (group g = H/KV') still reads its own key/value."""
    if repeat == 1:
        return k
    B, T, KV, hd = k.shape
    return jnp.repeat(k, repeat, axis=2)


def _chunked_sdpa(q, k, v, *, causal: bool, window: int, offset: int,
                  softcap: float = 0.0, chunk: int = ATTN_CHUNK):
    """Exact softmax attention computed in query chunks.

    q: [B, S, H, hd]; k, v: [B, T, KV, hd] with H % KV == 0. The full
    [S, T] logit matrix is never materialized — each chunk computes
    [B, KV, g, chunk, T] logits, softmaxes over T exactly, and contracts.
    ``offset`` is the absolute position of q[0] minus that of k[0].
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, S)
    n_chunks = math.ceil(S / chunk)
    pad = n_chunks * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(B, n_chunks, chunk, KV, g, hd)
    ki = jnp.arange(T)

    def one(ci, qi):
        # qi: [B, chunk, KV, g, hd]
        logits = jnp.einsum("bskgh,btkh->bkgst", qi, k,
                            preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        pos_q = ci * chunk + jnp.arange(chunk) + offset       # [chunk]
        mask = jnp.ones((chunk, T), bool)
        if causal:
            mask &= ki[None, :] <= pos_q[:, None]
        if window > 0:
            mask &= ki[None, :] > pos_q[:, None] - window
        logits = jnp.where(mask, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)

    if n_chunks == 1:
        out = one(0, qc[:, 0])[:, None]
    else:
        out = jax.lax.map(lambda args: one(*args),
                          (jnp.arange(n_chunks), qc.swapaxes(0, 1)))
        out = out.swapaxes(0, 1)                 # [B, n_chunks, chunk, KV, g, hd]
    out = out.reshape(B, n_chunks * chunk, H, hd)
    return out[:, :S]


def attn_forward(p, cfg: ModelConfig, pol: Policy, x, positions,
                 window: int = 0, causal: bool = True):
    """Full-sequence (train / prefill) attention. Returns (out, (k, v)).

    The returned k, v have KV heads already replicated per the policy, ready
    to seed a decode cache.
    """
    B, S, d = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    k = _repeat_kv(k, pol.kv_repeat)
    v = _repeat_kv(v, pol.kv_repeat)
    q = pol.constrain(q, "attn_batch", "seq", "heads", None)
    # K/V use the "kv_seq" axis: under dp_seq ("seq" sharded over model)
    # it stays replicated, so XLA inserts one K/V all-gather per layer and
    # each rank attends its query shard against the full keys (exact).
    k = pol.constrain(k, "attn_batch", "kv_seq", "kv_heads", None)
    v = pol.constrain(v, "attn_batch", "kv_seq", "kv_heads", None)
    seq_sharded = pol.rules.get("seq") is not None
    if cfg.attention_impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.logit_softcap)
    else:
        # q-chunking would reshape the sharded seq axis; disable under dp_seq
        out = _chunked_sdpa(q, k, v, causal=causal, window=window, offset=0,
                            softcap=cfg.logit_softcap,
                            chunk=S if seq_sharded else ATTN_CHUNK)
    out = pol.constrain(out, "attn_batch", "seq", "heads", None)
    y = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
    return y, (k, v)


def cross_attn_forward(p, cfg: ModelConfig, pol: Policy, x, memory):
    """Encoder-decoder cross attention (no mask, no rope)."""
    B, S, d = x.shape
    hd = cfg.hd
    Tm = memory.shape[1]
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (memory @ p["wk"]).reshape(B, Tm, cfg.n_kv_heads, hd)
    v = (memory @ p["wv"]).reshape(B, Tm, cfg.n_kv_heads, hd)
    k = _repeat_kv(k, pol.kv_repeat)
    v = _repeat_kv(v, pol.kv_repeat)
    q = pol.constrain(q, "attn_batch", "seq", "heads", None)
    out = _chunked_sdpa(q, k, v, causal=False, window=0, offset=0)
    y = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
    return y, (k, v)


def attn_decode(p, cfg: ModelConfig, pol: Policy, x, cache_k, cache_v, pos,
                window: int = 0):
    """One-token decode step.

    x: [B, 1, d]; cache_[kv]: [B, T, KVr, hd] (KV heads pre-replicated);
    pos: [] or [B] absolute position of the new token. With a ring cache
    (window > 0 and T == window) the write index is pos % T.
    Returns (out [B, 1, d], new_cache_k, new_cache_v).
    """
    B, _, d = x.shape
    hd = cfg.hd
    T = cache_k.shape[1]
    KVr = cache_k.shape[2]
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
    posb = jnp.broadcast_to(jnp.asarray(pos), (B,))
    if cfg.rope_theta > 0:
        q = apply_rope(q, posb[:, None], cfg.rope_theta)
        k = apply_rope(k, posb[:, None], cfg.rope_theta)
    k = _repeat_kv(k, pol.kv_repeat)
    v = _repeat_kv(v, pol.kv_repeat)

    ring = window > 0 and T == window
    slot = posb % T if ring else posb
    oh = jax.nn.one_hot(slot, T, dtype=jnp.float32)     # [B, T]
    upd = lambda c, new: (c * (1 - oh[:, :, None, None]).astype(c.dtype)
                          + oh[:, :, None, None].astype(c.dtype)
                          * new.astype(c.dtype))
    cache_k = upd(cache_k, k)
    cache_v = upd(cache_v, v)
    cache_k = pol.constrain(cache_k, "batch", "cache_seq", "kv_heads", None)
    cache_v = pol.constrain(cache_v, "batch", "cache_seq", "kv_heads", None)

    ki = jnp.arange(T)[None, :]
    if ring:
        # slot i holds absolute position: valid iff within the last `window`
        age = (slot[:, None] - ki) % T
        valid = age <= jnp.minimum(posb[:, None], T - 1)
    else:
        valid = ki <= posb[:, None]
        if window > 0:
            valid &= ki > posb[:, None] - window

    g = cfg.n_heads // KVr
    qg = q.reshape(B, 1, KVr, g, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg,
                        cache_k.astype(x.dtype),
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(x.dtype),
                     cache_v.astype(x.dtype)).reshape(B, 1, cfg.n_heads * hd)
    y = out @ p["wo"]
    return y, cache_k, cache_v


# ---------------------------------------------------------------- MLP

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None,
             d_model: Optional[int] = None):
    d, dt = d_model or cfg.d_model, cfg.pdtype()
    d_ff = d_ff or cfg.d_ff
    if cfg.mlp_type == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"wi": dense_init(k1, d, d_ff, ("embed_fsdp", "mlp"), dt),
                "wg": dense_init(k2, d, d_ff, ("embed_fsdp", "mlp"), dt),
                "wo": dense_init(k3, d_ff, d, ("mlp", "embed_fsdp"), dt)}
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, d, d_ff, ("embed_fsdp", "mlp"), dt),
            "wo": dense_init(k2, d_ff, d, ("mlp", "embed_fsdp"), dt)}


def mlp_forward(p, cfg: ModelConfig, pol: Policy, x):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    h = pol.constrain(h, "batch", "seq", "mlp")
    return h @ p["wo"]


# ---------------------------------------------------------------- head

def unembed(cfg: ModelConfig, pol: Policy, x, embed_w, head_w=None):
    """Project to (padded) vocab logits; padded entries masked to -inf."""
    w = embed_w.T if head_w is None else head_w
    logits = x @ w.astype(x.dtype)
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    pad = logits.shape[-1] - cfg.vocab_size
    if pad > 0:
        mask = jnp.arange(logits.shape[-1]) < cfg.vocab_size
        logits = jnp.where(mask, logits, NEG_INF)
    return pol.constrain(logits, "batch", "seq", "vocab")


def padded_vocab(cfg: ModelConfig, multiple: int = 16) -> int:
    return int(math.ceil(cfg.vocab_size / multiple) * multiple)
