"""Analytic parameter / size models shared by the sharding-policy resolver
(napkin math for strategy selection) and the roofline benchmark."""
from __future__ import annotations

import math

from repro.models.config import ModelConfig


def pad16(v: int) -> int:
    return math.ceil(v / 16) * 16


def family_counts(cfg: ModelConfig):
    """(n_attn_layers, n_rec_layers, n_mlstm, n_slstm)."""
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        reps, tail = divmod(cfg.n_layers, len(pat))
        seq = list(pat) * reps + list(pat[:tail])
        return (sum(1 for t in seq if t == "attn"),
                sum(1 for t in seq if t == "rec"), 0, 0)
    if cfg.family == "ssm":
        pat = cfg.xlstm_pattern or ("m",)
        reps = cfg.n_layers // len(pat)
        return (0, 0, reps * sum(1 for t in pat if t == "m"),
                reps * sum(1 for t in pat if t == "s"))
    return cfg.n_layers, 0, 0, 0


def param_count(cfg: ModelConfig, expert_pad: int = 0) -> float:
    """Element count, matching the model builders (tied embeddings)."""
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    n_attn, n_rec, n_m, n_s = family_counts(cfg)
    P = pad16(cfg.vocab_size) * d
    per_attn = d * (H + 2 * KV) * hd + H * hd * d
    if cfg.family == "encdec":
        ff_n = 2 if cfg.mlp_type == "gelu" else 3
        P += (cfg.n_enc_layers + cfg.n_dec_layers) * \
            (per_attn + ff_n * d * cfg.d_ff)
        P += cfg.n_dec_layers * per_attn
        return float(P)
    if cfg.family == "ssm":
        from repro.models.xlstm import _slstm_ff
        di = 2 * d
        dh = di // H
        P += n_m * (2 * d * di + 3 * H * dh * dh + di * d + di * 2 * H)
        P += n_s * (4 * d * d + 4 * d * (d // H) + 3 * d * _slstm_ff(d))
        return float(P)
    dr = cfg.d_rnn or d
    P += n_attn * per_attn
    P += n_rec * (3 * d * dr + 2 * dr * dr)
    ff_n = 2 if cfg.mlp_type == "gelu" else 3
    if cfg.n_experts:
        E = expert_pad or cfg.n_experts
        P += cfg.n_layers * (d * E + E * 3 * d * cfg.expert_d_ff)
        par_ff = cfg.shared_expert_d_ff or (cfg.d_ff if cfg.dense_residual
                                            else 0)
        if par_ff:
            P += cfg.n_layers * 3 * d * par_ff
    else:
        P += cfg.n_layers * ff_n * d * cfg.d_ff
    return float(P)


def active_param_count(cfg: ModelConfig) -> float:
    """Active path (MoE: top-k experts instead of all)."""
    if not cfg.n_experts:
        return param_count(cfg)
    full = param_count(cfg, cfg.n_experts)
    all_exp = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.expert_d_ff
    return full - all_exp * (1 - cfg.experts_per_token / cfg.n_experts)


def param_dtype_bytes(cfg: ModelConfig) -> int:
    return 2 if cfg.param_dtype == "bfloat16" else 4
