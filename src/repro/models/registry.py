"""Uniform model API over the five families.

Every family exposes:
  init_params(cfg, pol, key)              -> boxed param tree
  forward(cfg, pol, params, tokens, embeds=None) -> (hidden [B,S,d], aux)
  init_cache(cfg, pol, batch, max_len)    -> decode-state pytree
  cache_axes(cfg)                         -> matching logical-axis pytree
  decode_step(cfg, pol, params, cache, tokens) -> (logits [B,1,V], cache)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from repro.models import encdec, hybrid, lm, xlstm
from repro.models.config import ModelConfig


class Family(NamedTuple):
    init_params: Callable
    forward: Callable
    init_cache: Callable
    cache_axes: Callable
    decode_step: Callable


_LM = Family(lm.init_params, lm.forward, lm.init_cache, lm.cache_axes,
             lm.decode_step)

FAMILIES: dict[str, Family] = {
    "dense": _LM,
    "moe": _LM,
    "vlm": _LM,
    "ssm": Family(xlstm.init_params, xlstm.forward, xlstm.init_cache,
                  xlstm.cache_axes, xlstm.decode_step),
    "hybrid": Family(hybrid.init_params, hybrid.forward, hybrid.init_cache,
                     hybrid.cache_axes, hybrid.decode_step),
    "encdec": Family(encdec.init_params, encdec.forward, encdec.init_cache,
                     encdec.cache_axes, encdec.decode_step),
}


def get_family(cfg: ModelConfig) -> Family:
    return FAMILIES[cfg.family]
