"""Encoder-decoder backbone (seamless-m4t-large-v2 [audio]).

The transformer backbone only: the speech frontend is a STUB — per the
assignment, ``input_specs()`` feeds precomputed frame embeddings [B, S_enc, d]
directly to the encoder (in place of the conformer feature extractor).
Encoder: bidirectional self-attention blocks. Decoder: causal self-attention
+ cross-attention over encoder memory. Sinusoidal positions (rope_theta=0),
layernorm + gelu per the NLLB/seamless lineage.

Decode uses a self-attention KV ring cache plus *precomputed* cross-attention
K/V (computed once from the memory at prefill, reused every step).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding.policy import Policy

MEMORY_LEN = 3072          # stub frontend: frames fed to the encoder (decode)


def sinusoid(positions, dim: int):
    """positions: [...]-> [..., dim] standard sinusoidal encoding."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    return {"ln1": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
            "attn": L.attn_init(ka, cfg),
            "ln2": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
            "mlp": L.mlp_init(km, cfg)}


def _dec_layer_init(key, cfg: ModelConfig):
    ka, kx, km = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
            "attn": L.attn_init(ka, cfg),
            "lnx": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
            "xattn": L.attn_init(kx, cfg),
            "ln2": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
            "mlp": L.mlp_init(km, cfg)}


def init_params(cfg: ModelConfig, pol: Policy, key):
    ke, kenc, kdec, kn = jax.random.split(key, 4)
    ne = cfg.n_enc_layers or cfg.n_layers
    nd = cfg.n_dec_layers or cfg.n_layers
    return {
        "embed": L.embed_init(ke, L.padded_vocab(cfg), cfg.d_model,
                              cfg.pdtype()),
        "enc": L.stack_layers(jax.vmap(lambda k: _enc_layer_init(k, cfg))(
            jax.random.split(kenc, ne))),
        "enc_norm": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
        "dec": L.stack_layers(jax.vmap(lambda k: _dec_layer_init(k, cfg))(
            jax.random.split(kdec, nd))),
        "norm": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
    }


def encode(cfg: ModelConfig, pol: Policy, params, frames):
    """frames: [B, S_enc, d] precomputed frontend embeddings -> memory."""
    B, S, d = frames.shape
    x = frames.astype(cfg.cdtype())
    x = x + sinusoid(jnp.arange(S), d)[None].astype(x.dtype)
    x = pol.constrain(x, "batch", "seq", None)
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg.norm_eps, cfg.norm_type)
        a, _ = L.attn_forward(lp["attn"], cfg, pol, h, positions,
                              causal=False)
        x = x + a
        h = L.apply_norm(lp["ln2"], x, cfg.norm_eps, cfg.norm_type)
        x = x + L.mlp_forward(lp["mlp"], cfg, pol, h)
        return pol.constrain(x, "batch", "seq", None), None

    fn = body if cfg.remat == "none" else jax.checkpoint(body)
    x, _ = jax.lax.scan(fn, x, params["enc"])
    return L.apply_norm(params["enc_norm"], x, cfg.norm_eps, cfg.norm_type)


def decode_train(cfg: ModelConfig, pol: Policy, params, tokens, memory):
    """Teacher-forced decoder over full target sequence."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype())
    x = x + sinusoid(jnp.arange(S), cfg.d_model)[None].astype(x.dtype)
    x = pol.constrain(x, "batch", "seq", None)
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg.norm_eps, cfg.norm_type)
        a, _ = L.attn_forward(lp["attn"], cfg, pol, h, positions)
        x = x + a
        h = L.apply_norm(lp["lnx"], x, cfg.norm_eps, cfg.norm_type)
        a, _ = L.cross_attn_forward(lp["xattn"], cfg, pol, h, memory)
        x = x + a
        h = L.apply_norm(lp["ln2"], x, cfg.norm_eps, cfg.norm_type)
        x = x + L.mlp_forward(lp["mlp"], cfg, pol, h)
        return pol.constrain(x, "batch", "seq", None), None

    fn = body if cfg.remat == "none" else jax.checkpoint(body)
    x, _ = jax.lax.scan(fn, x, params["dec"])
    return L.apply_norm(params["norm"], x, cfg.norm_eps, cfg.norm_type)


def forward(cfg: ModelConfig, pol: Policy, params, tokens, embeds=None,
            positions=None):
    """Train/prefill: embeds = encoder frames (stub frontend).

    Returns (decoder hidden [B,S,d], aux)."""
    assert embeds is not None, "encdec needs frontend frames (embeds=...)"
    memory = encode(cfg, pol, params, embeds)
    hidden = decode_train(cfg, pol, params, tokens, memory)
    return hidden, jnp.zeros((), jnp.float32)


class EncDecCache(NamedTuple):
    k: jnp.ndarray      # [Ld, B, T, KVr, hd] decoder self-attn cache
    v: jnp.ndarray
    xk: jnp.ndarray     # [Ld, B, Tm, KVr, hd] precomputed cross K/V
    xv: jnp.ndarray
    pos: jnp.ndarray


def init_cache(cfg: ModelConfig, pol: Policy, batch: int, max_len: int,
               dtype=jnp.bfloat16, memory_len: int = MEMORY_LEN
               ) -> EncDecCache:
    nd = cfg.n_dec_layers or cfg.n_layers
    kvr = cfg.n_kv_heads * pol.kv_repeat
    return EncDecCache(
        k=jnp.zeros((nd, batch, max_len, kvr, cfg.hd), dtype),
        v=jnp.zeros((nd, batch, max_len, kvr, cfg.hd), dtype),
        xk=jnp.zeros((nd, batch, memory_len, kvr, cfg.hd), dtype),
        xv=jnp.zeros((nd, batch, memory_len, kvr, cfg.hd), dtype),
        pos=jnp.zeros((), jnp.int32))


def cache_axes(cfg: ModelConfig) -> EncDecCache:
    ax = ("layers", "batch", "cache_seq", "kv_heads", None)
    xax = ("layers", "batch", None, "kv_heads", None)
    return EncDecCache(k=ax, v=ax, xk=xax, xv=xax, pos=())


def decode_step(cfg: ModelConfig, pol: Policy, params, cache: EncDecCache,
                tokens):
    """One decode step; cross K/V precomputed in the cache."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype())
    x = x + sinusoid(cache.pos[None, None], cfg.d_model).astype(x.dtype)
    pos = cache.pos
    hd = cfg.hd

    def body(x, lp_kv):
        lp, ck, cv, xk, xv = lp_kv
        h = L.apply_norm(lp["ln1"], x, cfg.norm_eps, cfg.norm_type)
        a, ck, cv = L.attn_decode(lp["attn"], cfg, pol, h, ck, cv, pos)
        x = x + a
        # cross attention against fixed memory K/V
        h = L.apply_norm(lp["lnx"], x, cfg.norm_eps, cfg.norm_type)
        q = (h @ lp["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
        KVr = xk.shape[2]
        g = cfg.n_heads // KVr
        qg = q.reshape(B, 1, KVr, g, hd)
        lg = jnp.einsum("bskgh,btkh->bkgst", qg, xk.astype(x.dtype),
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
        w = jax.nn.softmax(lg, axis=-1)
        o = jnp.einsum("bkgst,btkh->bskgh", w.astype(x.dtype),
                       xv.astype(x.dtype)).reshape(B, 1, cfg.n_heads * hd)
        x = x + o @ lp["xattn"]["wo"]
        h = L.apply_norm(lp["ln2"], x, cfg.norm_eps, cfg.norm_type)
        x = x + L.mlp_forward(lp["mlp"], cfg, pol, h)
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(body, x,
                               (params["dec"], cache.k, cache.v,
                                cache.xk, cache.xv))
    x = L.apply_norm(params["norm"], x, cfg.norm_eps, cfg.norm_type)
    logits = L.unembed(cfg, pol, x, params["embed"])
    return logits, EncDecCache(k=nk, v=nv, xk=cache.xk, xv=cache.xv,
                               pos=cache.pos + 1)


def prefill_cross_kv(cfg: ModelConfig, pol: Policy, params, memory):
    """Compute per-layer cross K/V from encoder memory (once per request)."""
    B, Tm, d = memory.shape
    hd = cfg.hd

    def one(lp):
        k = (memory @ lp["xattn"]["wk"]).reshape(B, Tm, cfg.n_kv_heads, hd)
        v = (memory @ lp["xattn"]["wv"]).reshape(B, Tm, cfg.n_kv_heads, hd)
        if pol.kv_repeat > 1:
            k = jnp.repeat(k, pol.kv_repeat, axis=2)
            v = jnp.repeat(v, pol.kv_repeat, axis=2)
        return k, v

    return jax.vmap(one)(params["dec"])
