"""Model configuration for all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None           # default d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    expert_d_ff: int = 0
    shared_expert_d_ff: int = 0               # qwen2-moe shared expert
    dense_residual: bool = False              # arctic: dense MLP + MoE residual
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001
    moe_impl: str = "auto"        # auto | gather | einsum (GShard ref).
    # auto: einsum under expert-parallel TP (measured 2-7x less collective
    # traffic than cross-shard scatter), gather under pure-DP strategies
    # (linear memory, no [.., E, C] tensor). See EXPERIMENTS.md §Perf.
    # hybrid (recurrentgemma): repeating block pattern, e.g. ("rec","rec","attn")
    block_pattern: Tuple[str, ...] = ()
    local_window: int = 0                     # local attention window (0 = full)
    d_rnn: int = 0                            # RG-LRU recurrence width
    conv_width: int = 4
    # ssm (xlstm): pattern of mLSTM/sLSTM blocks
    xlstm_pattern: Tuple[str, ...] = ()
    mlstm_chunk: int = 64
    # enc-dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # modality frontend stubs feed the backbone with precomputed embeddings
    embeds_input: bool = False                # vlm / audio-encoder input
    n_prefix: int = 0                         # vlm: patch-embedding positions
    # block flavour
    mlp_type: str = "swiglu"                  # swiglu | gelu
    norm_type: str = "rmsnorm"                # rmsnorm | layernorm
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # remat: "none" | "full" | "dots"  (activation checkpointing policy)
    remat: str = "none"
    # attention implementation: "xla" (dry-run default) | "pallas"
    attention_impl: str = "xla"
    logit_softcap: float = 0.0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, len(cfg.block_pattern) or
                     len(cfg.xlstm_pattern) or 2),
        d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16, d_ff=128, vocab_size=251,      # odd: exercises pad mask
        param_dtype="float32", compute_dtype="float32", remat="none")
    if cfg.family == "moe":
        kw.update(n_experts=4, experts_per_token=min(2, cfg.experts_per_token),
                  expert_d_ff=64,
                  shared_expert_d_ff=64 if cfg.shared_expert_d_ff else 0)
    if cfg.family == "hybrid":
        kw.update(n_layers=len(cfg.block_pattern) + 1 or 3, d_rnn=64,
                  local_window=16)   # +1 layer exercises the unrolled tail
    if cfg.family == "ssm":
        kw.update(n_layers=len(cfg.xlstm_pattern) or 2, mlstm_chunk=8)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, n_dec_layers=2)
    if cfg.embeds_input and cfg.n_prefix:
        kw.update(n_prefix=4)
    kw.update(overrides)
    return cfg.with_(**kw)
