"""Mixture-of-Experts layer: sort/gather dispatch (default) + GShard einsum.

Routed experts are sharded over the mesh "model" axis (expert parallelism).
Expert counts that do not divide the EP degree are *padded* with dead experts
whose router logits are masked to -inf (policy.expert_pad; exact — dead
experts receive no tokens and contribute no output).

Two dispatch implementations, selectable by ``impl``:

  * ``gather`` (default; §Perf iteration 2) — sort-based: token choices are
    ranked per expert with a stable argsort, scattered into a capacity-
    padded ``[E, C, d]`` buffer, run through the expert matmuls, and
    gathered back. Memory and FLOPs are LINEAR in tokens (no [.., E, C]
    one-hot tensor), which is what makes arctic-480b (E=128) feasible:
    the einsum dispatch at S=4096 costs ~20x the expert matmuls themselves.
  * ``einsum`` — the classic GShard one-hot formulation (kept as the
    reference and for the §Perf before/after measurement). Each batch row
    is a routing group; C = ceil(S * k / E * cf).

Both drop over-capacity tokens (combine weight 0; the residual path carries
them), as in Switch/GShard.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Boxed, dense_init
from repro.sharding.policy import Policy


def moe_init(key, cfg: ModelConfig, pol: Policy):
    """Router + stacked expert SwiGLU weights. E = padded expert count."""
    E = pol.expert_pad or cfg.n_experts
    d, f, dt = cfg.d_model, cfg.expert_d_ff, cfg.pdtype()
    kr, ki, kg, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)

    def ex(k, shape, axes):
        w = jax.random.normal(k, shape, jnp.float32) * s
        return Boxed(w.astype(dt), axes)

    return {
        "router": dense_init(kr, d, E, ("embed", "expert"), jnp.float32,
                             scale=0.02),
        "wi": ex(ki, (E, d, f), ("expert", "embed_fsdp", None)),
        "wg": ex(kg, (E, d, f), ("expert", "embed_fsdp", None)),
        "wo": ex(ko, (E, f, d), ("expert", None, "embed_fsdp")),
    }


def capacity(S: int, top_k: int, E: int, cf: float) -> int:
    return max(1, int(math.ceil(S * top_k / E * cf)))


def _route(p, cfg: ModelConfig, x):
    """Router: returns (gate [B,S,k], idx [B,S,k], probs [B,S,E])."""
    E = p["router"].shape[-1]
    k = cfg.experts_per_token
    logits = x.astype(jnp.float32) @ p["router"]          # [B, S, E]
    if E > cfg.n_experts:                                  # mask padded experts
        live = jnp.arange(E) < cfg.n_experts
        logits = jnp.where(live, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                    # [B, S, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return gate, idx, probs


def _aux_loss(cfg: ModelConfig, idx, probs, E: int):
    """Switch-style load-balance loss: E * sum_e fraction_e * mean_prob_e."""
    oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)         # [B, S, k, E]
    frac = oh.sum(2).reshape(-1, E).mean(0)
    mean_p = probs.reshape(-1, E).mean(0)
    return cfg.n_experts * jnp.sum(frac * mean_p)


def moe_forward(p, cfg: ModelConfig, pol: Policy, x, impl: str = "auto"):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    if impl == "auto":
        # experts sharded over "model" (EP): the einsum formulation lets
        # SPMD route dispatch/combine as all-to-alls; under pure-DP the
        # gather path is batch-local and strictly cheaper.
        impl = "einsum" if pol.rules.get("expert") is not None else "gather"
    if impl == "gather":
        return moe_forward_gather(p, cfg, pol, x)
    return moe_forward_einsum(p, cfg, pol, x)


def moe_forward_gather(p, cfg: ModelConfig, pol: Policy, x):
    """Sort-based dispatch: linear memory/FLOPs in tokens.

    Routing groups are *batch rows* (same as the einsum path) and the
    rank/scatter/gather sequence is vmapped over the batch axis, so the
    whole dispatch stays local to each batch shard — no global prefix sums
    or cross-shard scatters (a global capacity pool measured 5-10x the
    collective traffic under TP; see EXPERIMENTS.md §Perf iteration 3).
    Each choice is ranked within its expert by cumulative count over the
    flattened (s, k) order, scattered into an [E, C, d] capacity buffer,
    transformed, and combined back with its gate.
    """
    B, S, d = x.shape
    E = p["router"].shape[-1]
    k = cfg.experts_per_token
    C = capacity(S, k, E, cfg.capacity_factor)
    dt = x.dtype
    gate, idx, probs = _route(p, cfg, x)

    def row(xr, idr, gater):
        # xr: [S, d]; idr/gater: [S, k]
        eid = idr.reshape(S * k)
        oh = jax.nn.one_hot(eid, E, dtype=jnp.int32)       # [S*k, E]
        rank = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - oh,
                                   eid[:, None], axis=1)[:, 0]
        keep = rank < C
        slot = jnp.where(keep, eid * C + rank, E * C)      # E*C = drop bin
        buf = jnp.zeros((E * C + 1, d), dt).at[slot].set(
            xr[jnp.arange(S * k) // k], mode="drop")
        return buf[:E * C].reshape(E, C, d), slot, keep

    xin, slot, keep = jax.vmap(row)(x, idx, gate)          # [B, E, C, d]
    xin = pol.constrain(xin, "batch", "expert", None, None)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["wg"].astype(dt))) \
        * jnp.einsum("becd,edf->becf", xin, p["wi"].astype(dt))
    eo = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dt))
    eo = pol.constrain(eo, "batch", "expert", None, None)

    def combine(eor, slotr, gater, keepr):
        flat = jnp.concatenate([eor.reshape(E * C, d),
                                jnp.zeros((1, d), dt)], axis=0)
        w = (gater.reshape(S * k) * keepr).astype(dt)
        return (flat[slotr] * w[:, None]).reshape(S, k, d).sum(1)

    out = jax.vmap(combine)(eo, slot, gate.astype(jnp.float32), keep)
    return out, _aux_loss(cfg, idx, probs, E)


def moe_forward_einsum(p, cfg: ModelConfig, pol: Policy, x):
    """GShard one-hot dispatch (reference / §Perf baseline)."""
    B, S, d = x.shape
    E = p["router"].shape[-1]
    k = cfg.experts_per_token
    C = capacity(S, k, E, cfg.capacity_factor)
    dt = x.dtype
    gate, idx, probs = _route(p, cfg, x)

    # position of each (token, choice) within its expert's capacity buffer
    oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)         # [B, S, k, E]
    flat = oh.reshape(B, S * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                  # [B, S*k, E]
    keep = (pos < C).astype(jnp.float32) * flat
    slot = jax.nn.one_hot((pos * flat).sum(-1).astype(jnp.int32), C,
                          dtype=jnp.float32)               # [B, S*k, C]
    # combine[b, s, e, c] = sum_k gate * keep * slot
    gk = (gate.reshape(B, S * k, 1) * keep)                # [B, S*k, E]
    combine = jnp.einsum("bte,btc->btec", gk, slot).reshape(B, S, k, E, C) \
        .sum(2)                                            # [B, S, E, C]
    combine = pol.constrain(combine, "batch", "seq", "expert", None)
    dispatch = (combine > 0).astype(dt)

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch, x)        # [E, B, C, d]
    xin = pol.constrain(xin, "expert", "batch", None, None)
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xin, p["wg"].astype(dt))) \
        * jnp.einsum("ebcd,edf->ebcf", xin, p["wi"].astype(dt))
    eo = jnp.einsum("ebcf,efd->ebcd", h, p["wo"].astype(dt))
    eo = pol.constrain(eo, "expert", "batch", None, None)
    out = jnp.einsum("bsec,ebcd->bsd", combine.astype(dt), eo)
    return out, _aux_loss(cfg, idx, probs, E)
