"""RecurrentGemma-style hybrid LM: RG-LRU recurrent blocks + local attention.

Griffin architecture (arXiv:2402.19427): residual blocks cycle through
``cfg.block_pattern`` (("rec","rec","attn") for recurrentgemma — 2 recurrent
: 1 local-attention). Each block = temporal mixing + gated MLP, pre-norm.

The RG-LRU is a *diagonal* linear recurrence
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Lambda) * r_t),  r_t, i_t = sigmoid gates,
computed with ``jax.lax.associative_scan`` for train/prefill (log-depth on
TPU) or the Pallas chunked-scan kernel (cfg.attention_impl == "pallas"), and
as a single fused step for decode. Local attention uses a ring KV cache of
exactly ``cfg.local_window`` slots, so 500k-token decode holds O(window)
state — this is why this arch runs the ``long_500k`` cell.

Layers scan over pattern *repeats*; the non-multiple tail (26 = 8*3 + 2) is
unrolled.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding.policy import Policy

LRU_C = 8.0


def _pattern(cfg: ModelConfig) -> tuple[str, ...]:
    return cfg.block_pattern or ("rec", "rec", "attn")


def _split(cfg: ModelConfig):
    pat = _pattern(cfg)
    reps, tail = divmod(cfg.n_layers, len(pat))
    return pat, reps, pat[:tail]


# ------------------------------------------------------------------ RG-LRU

def rglru_init(key, cfg: ModelConfig):
    d, dr, dt = cfg.d_model, cfg.d_rnn or cfg.d_model, cfg.pdtype()
    kx, kg, kr, ki, kl, ko, kc = jax.random.split(key, 7)
    # Lambda init so a^c in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(kl, (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / LRU_C))        # softplus^-1
    return {
        "ln": L.norm_init(d, dt, cfg.norm_type),
        "wx": L.dense_init(kx, d, dr, ("embed_fsdp", "rnn"), dt),
        "wy": L.dense_init(kg, d, dr, ("embed_fsdp", "rnn"), dt),
        "conv": L.Boxed(jax.random.normal(kc, (cfg.conv_width, dr),
                                          jnp.float32).astype(dt) * 0.1,
                        (None, "rnn")),
        "wr": L.dense_init(kr, dr, dr, ("rnn", None), jnp.float32, scale=0.02),
        "wi": L.dense_init(ki, dr, dr, ("rnn", None), jnp.float32, scale=0.02),
        "lam": L.Boxed(lam, ("rnn",)),
        "wo": L.dense_init(ko, dr, d, ("rnn", "embed_fsdp"), dt),
    }


def _causal_conv(x, kernel, state: Optional[jnp.ndarray] = None):
    """x: [B, S, C]; kernel: [W, C]. state: [B, W-1, C] tail of prev tokens."""
    W = kernel.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(W))
    return out, xp[:, -(W - 1):]


def rglru_gates(p, u):
    """u: [B, S, dr] conv output -> (a, bx) of h = a*h + bx."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wr"])
    i = jax.nn.sigmoid(uf @ p["wi"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r       # [B, S, dr]
    a = jnp.exp(log_a)
    bx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, bx


def lru_scan(a, bx, h0=None):
    """Diagonal first-order recurrence via associative scan over time."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)
    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(comb, (a, bx), axis=1)
    return h


def rglru_forward(p, cfg: ModelConfig, pol: Policy, x, state=None,
                  return_state=False):
    """Griffin recurrent block body. state = (h [B,dr], conv [B,W-1,dr])."""
    B, S, d = x.shape
    h = L.apply_norm(p["ln"], x, cfg.norm_eps, cfg.norm_type)
    u = h @ p["wx"]
    gate = jax.nn.gelu(h @ p["wy"])
    u = pol.constrain(u, "batch", "seq", "rnn")
    h0, conv_st = state if state is not None else (None, None)
    u, conv_st = _causal_conv(u, p["conv"], conv_st)
    a, bx = rglru_gates(p, u)
    if cfg.attention_impl == "pallas" and S > 1:
        from repro.kernels.rglru_scan.ops import chunked_lru
        hs = chunked_lru(a, bx, h0)
    else:
        hs = lru_scan(a, bx, h0)
    y = (hs.astype(x.dtype) * gate) @ p["wo"]
    if return_state:
        return y, (hs[:, -1], conv_st)
    return y


# ------------------------------------------------------------------ blocks

def _block_init(key, cfg: ModelConfig, kind: str):
    kt, km = jax.random.split(key)
    p = {"kind_" + kind: L.Boxed(jnp.zeros(()), ()),  # structural marker
         "ln2": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
         "mlp": L.mlp_init(km, cfg)}
    if kind == "rec":
        p["rec"] = rglru_init(kt, cfg)
    else:
        p["ln1"] = L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type)
        p["attn"] = L.attn_init(kt, cfg)
    return p


def _block_fwd(p, cfg: ModelConfig, pol: Policy, x, positions, kind: str):
    if kind == "rec":
        x = x + rglru_forward(p["rec"], cfg, pol, x)
    else:
        h = L.apply_norm(p["ln1"], x, cfg.norm_eps, cfg.norm_type)
        a, _ = L.attn_forward(p["attn"], cfg, pol, h, positions,
                              window=cfg.local_window)
        x = x + a
    h = L.apply_norm(p["ln2"], x, cfg.norm_eps, cfg.norm_type)
    x = x + L.mlp_forward(p["mlp"], cfg, pol, h)
    return pol.constrain(x, "batch", "seq", None)


def init_params(cfg: ModelConfig, pol: Policy, key):
    pat, reps, tail = _split(cfg)
    ke, kr, kt, kn = jax.random.split(key, 4)

    def superblock(k):
        sub = jax.random.split(k, len(pat))
        return {f"b{i}_{t}": _block_init(sub[i], cfg, t)
                for i, t in enumerate(pat)}

    params = {
        "embed": L.embed_init(ke, L.padded_vocab(cfg), cfg.d_model,
                              cfg.pdtype()),
        "reps": L.stack_layers(jax.vmap(superblock)(
            jax.random.split(kr, reps))),
        "norm": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
    }
    if tail:
        tkeys = jax.random.split(kt, len(tail))
        params["tail"] = {f"t{i}_{t}": _block_init(tkeys[i], cfg, t)
                          for i, t in enumerate(tail)}
    return params


def forward(cfg: ModelConfig, pol: Policy, params, tokens, embeds=None,
            positions=None):
    pat, reps, tail = _split(cfg)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype())
    x = pol.constrain(x, "batch", "seq", None)
    if positions is None:
        positions = jnp.arange(S)[None, :]

    def body(x, bp):
        for i, t in enumerate(pat):
            x = _block_fwd(bp[f"b{i}_{t}"], cfg, pol, x, positions, t)
        return x, None

    fn = body if cfg.remat == "none" else jax.checkpoint(body)
    x, _ = jax.lax.scan(fn, x, params["reps"])
    for i, t in enumerate(tail):
        x = _block_fwd(params["tail"][f"t{i}_{t}"], cfg, pol, x, positions, t)
    x = L.apply_norm(params["norm"], x, cfg.norm_eps, cfg.norm_type)
    return x, jnp.zeros((), jnp.float32)


# ------------------------------------------------------------------ decode

class HybridCache(NamedTuple):
    h: jnp.ndarray        # [n_rec, B, dr] RG-LRU states
    conv: jnp.ndarray     # [n_rec, B, W-1, dr]
    k: jnp.ndarray        # [n_attn, B, window, KVr, hd] ring caches
    v: jnp.ndarray
    pos: jnp.ndarray


def _counts(cfg: ModelConfig):
    pat, reps, tail = _split(cfg)
    seq = list(pat) * reps + list(tail)
    return seq, sum(1 for t in seq if t == "rec"), \
        sum(1 for t in seq if t == "attn")


def init_cache(cfg: ModelConfig, pol: Policy, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> HybridCache:
    _, n_rec, n_attn = _counts(cfg)
    dr = cfg.d_rnn or cfg.d_model
    W = cfg.conv_width
    T = min(max_len, cfg.local_window) if cfg.local_window else max_len
    kvr = cfg.n_kv_heads * pol.kv_repeat
    return HybridCache(
        h=jnp.zeros((n_rec, batch, dr), jnp.float32),
        conv=jnp.zeros((n_rec, batch, W - 1, dr), jnp.float32),
        k=jnp.zeros((n_attn, batch, T, kvr, cfg.hd), dtype),
        v=jnp.zeros((n_attn, batch, T, kvr, cfg.hd), dtype),
        pos=jnp.zeros((), jnp.int32))


def cache_axes(cfg: ModelConfig) -> HybridCache:
    return HybridCache(
        h=("layers", "batch", "rnn"),
        conv=("layers", "batch", None, "rnn"),
        k=("layers", "batch", "cache_seq", "kv_heads", None),
        v=("layers", "batch", "cache_seq", "kv_heads", None),
        pos=())


def decode_step(cfg: ModelConfig, pol: Policy, params, cache: HybridCache,
                tokens):
    """One-token decode; O(window + d_rnn) state regardless of position."""
    seq_kinds, n_rec, n_attn = _counts(cfg)
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype())
    pos = cache.pos
    pat, reps, tail = _split(cfg)

    ri = ai = 0
    nh, nconv, nk, nv = list(cache.h), list(cache.conv), list(cache.k), \
        list(cache.v)

    def block_params(li):
        if li < reps * len(pat):
            r, i = divmod(li, len(pat))
            t = pat[i]
            bp = jax.tree.map(lambda a: a[r], params["reps"])
            return bp[f"b{i}_{t}"], t
        i = li - reps * len(pat)
        t = tail[i]
        return params["tail"][f"t{i}_{t}"], t

    for li in range(cfg.n_layers):
        p, t = block_params(li)
        if t == "rec":
            y, (h1, c1) = rglru_forward(p["rec"], cfg, pol, x,
                                        state=(cache.h[ri], cache.conv[ri]),
                                        return_state=True)
            nh[ri], nconv[ri] = h1, c1
            ri += 1
            x = x + y
        else:
            h = L.apply_norm(p["ln1"], x, cfg.norm_eps, cfg.norm_type)
            a, k1, v1 = L.attn_decode(p["attn"], cfg, pol, h, cache.k[ai],
                                      cache.v[ai], pos,
                                      window=cfg.local_window)
            nk[ai], nv[ai] = k1, v1
            ai += 1
            x = x + a
        hh = L.apply_norm(p["ln2"], x, cfg.norm_eps, cfg.norm_type)
        x = x + L.mlp_forward(p["mlp"], cfg, pol, hh)

    x = L.apply_norm(params["norm"], x, cfg.norm_eps, cfg.norm_type)
    logits = L.unembed(cfg, pol, x, params["embed"])
    new = HybridCache(h=jnp.stack(nh), conv=jnp.stack(nconv),
                      k=jnp.stack(nk), v=jnp.stack(nv), pos=pos + 1)
    return logits, new
