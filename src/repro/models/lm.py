"""Decoder-only LM covering the dense and MoE families.

Covers: yi-6b, phi3-medium-14b, granite-3-2b, starcoder2-7b (dense GQA),
qwen2-moe-a2.7b, arctic-480b (MoE; shared-expert / dense-residual parallel
branch), and pixtral-12b (decoder backbone whose first ``n_prefix`` positions
are fed precomputed patch embeddings from the stubbed vision frontend).

Layers are stacked with vmap and iterated with ``lax.scan`` so the compiled
HLO is depth-independent; remat policy is applied to the scanned body.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig
from repro.sharding.policy import Policy


class DecodeCache(NamedTuple):
    k: jnp.ndarray        # [Lyr, B, T, KVr, hd]
    v: jnp.ndarray        # [Lyr, B, T, KVr, hd]
    pos: jnp.ndarray      # [] next absolute position


def _layer_init(key, cfg: ModelConfig, pol: Policy):
    ka, km, kp = jax.random.split(key, 3)
    p = {
        "ln1": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
        "attn": L.attn_init(ka, cfg),
        "ln2": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
    }
    if cfg.n_experts:
        p["moe"] = moe_lib.moe_init(km, cfg, pol)
        par_ff = cfg.shared_expert_d_ff or (cfg.d_ff if cfg.dense_residual
                                            else 0)
        if par_ff:
            p["mlp"] = L.mlp_init(kp, cfg, d_ff=par_ff)
    else:
        p["mlp"] = L.mlp_init(km, cfg)
    return p


def init_params(cfg: ModelConfig, pol: Policy, key):
    ke, kl, kn = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg, pol))(lkeys)
    return {
        "embed": L.embed_init(ke, L.padded_vocab(cfg), cfg.d_model,
                              cfg.pdtype()),
        "layers": L.stack_layers(stacked),
        "norm": L.norm_init(cfg.d_model, cfg.pdtype(), cfg.norm_type),
    }


def _block(cfg: ModelConfig, pol: Policy, p, x, positions):
    """One pre-norm transformer block. Returns (x, aux_loss)."""
    h = L.apply_norm(p["ln1"], x, cfg.norm_eps, cfg.norm_type)
    a, _ = L.attn_forward(p["attn"], cfg, pol, h, positions,
                          window=cfg.local_window)
    x = x + a
    h = L.apply_norm(p["ln2"], x, cfg.norm_eps, cfg.norm_type)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        mo, aux = moe_lib.moe_forward(p["moe"], cfg, pol, h, impl=cfg.moe_impl)
        if "mlp" in p:
            par_ff = cfg.shared_expert_d_ff or cfg.d_ff
            mo = mo + L.mlp_forward(p["mlp"], cfg.with_(d_ff=par_ff), pol, h)
        x = x + mo
    else:
        x = x + L.mlp_forward(p["mlp"], cfg, pol, h)
    return pol.constrain(x, "batch", "seq", None), aux


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def embed_tokens(cfg: ModelConfig, pol: Policy, params, tokens,
                 embeds: Optional[jnp.ndarray] = None):
    """Token embedding; for VLM backbones the first embeds.shape[1] positions
    come from the (stubbed) modality frontend instead of the table."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if embeds is not None:
        n = embeds.shape[1]
        x = jnp.concatenate([embeds.astype(x.dtype), x[:, n:]], axis=1)
    return pol.constrain(x.astype(cfg.cdtype()), "batch", "seq", None)


def forward(cfg: ModelConfig, pol: Policy, params, tokens,
            embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None):
    """Full-sequence forward (train / prefill).

    Returns (hidden [B,S,d] post-final-norm, aux_loss). Logits are computed
    by the caller (chunked loss / last-position-only prefill) so a full
    [B, S, vocab] tensor is never materialized for 100k+ vocabularies.
    """
    B, S = tokens.shape
    x = embed_tokens(cfg, pol, params, tokens, embeds)
    if positions is None:
        positions = jnp.arange(S)[None, :]

    def body(carry, lp):
        x, aux = carry
        x, a = _block(cfg, pol, lp, x, positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(_remat(cfg, body),
                               (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = L.apply_norm(params["norm"], x, cfg.norm_eps, cfg.norm_type)
    return x, aux * cfg.router_aux_loss / max(cfg.n_layers, 1)


def prefill(cfg: ModelConfig, pol: Policy, params, tokens, max_len: int,
            embeds: Optional[jnp.ndarray] = None,
            cache_dtype=jnp.bfloat16):
    """Forward over the prompt, returning (hidden, seeded DecodeCache).

    The per-layer K/V produced by the forward scan seed a cache of length
    ``max_len`` (ring-truncated to the local window if the arch has one).
    """
    B, S = tokens.shape
    x = embed_tokens(cfg, pol, params, tokens, embeds)
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg.norm_eps, cfg.norm_type)
        a, (k, v) = L.attn_forward(lp["attn"], cfg, pol, h, positions,
                                   window=cfg.local_window)
        x = x + a
        h = L.apply_norm(lp["ln2"], x, cfg.norm_eps, cfg.norm_type)
        if cfg.n_experts:
            mo, _ = moe_lib.moe_forward(lp["moe"], cfg, pol, h, impl=cfg.moe_impl)
            if "mlp" in lp:
                mo = mo + L.mlp_forward(lp["mlp"], cfg, pol, h)
            x = x + mo
        else:
            x = x + L.mlp_forward(lp["mlp"], cfg, pol, h)
        return x, (k.astype(cache_dtype), v.astype(cache_dtype))

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["norm"], x, cfg.norm_eps, cfg.norm_type)
    cache = init_cache(cfg, pol, B, max_len, cache_dtype)
    T = cache.k.shape[2]
    take = min(S, T)
    # write the last `take` prompt positions; ring layout if windowed
    if cfg.local_window and T == cfg.local_window:
        idx = (jnp.arange(S - take, S)) % T
        k0 = cache.k.at[:, :, idx].set(ks[:, :, S - take:])
        v0 = cache.v.at[:, :, idx].set(vs[:, :, S - take:])
    else:
        k0 = cache.k.at[:, :, :take].set(ks[:, :, S - take:])
        v0 = cache.v.at[:, :, :take].set(vs[:, :, S - take:])
    return x, DecodeCache(k=k0, v=v0, pos=jnp.asarray(S, jnp.int32))


def init_cache(cfg: ModelConfig, pol: Policy, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> DecodeCache:
    kvr = cfg.n_kv_heads * pol.kv_repeat
    T = min(max_len, cfg.local_window) if cfg.local_window else max_len
    shape = (cfg.n_layers, batch, T, kvr, cfg.hd)
    return DecodeCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                       pos=jnp.zeros((), jnp.int32))


def cache_axes(cfg: ModelConfig) -> DecodeCache:
    ax = ("layers", "batch", "cache_seq", "kv_heads", None)
    return DecodeCache(k=ax, v=ax, pos=())


def decode_step(cfg: ModelConfig, pol: Policy, params, cache: DecodeCache,
                tokens):
    """One decode step. tokens: [B, 1]. Returns (logits [B,1,V], new cache)."""
    B = tokens.shape[0]
    x = embed_tokens(cfg, pol, params, tokens)
    pos = cache.pos

    def body(x, lp_kv):
        lp, ck, cv = lp_kv
        h = L.apply_norm(lp["ln1"], x, cfg.norm_eps, cfg.norm_type)
        a, ck, cv = L.attn_decode(lp["attn"], cfg, pol, h, ck, cv, pos,
                                  window=cfg.local_window)
        x = x + a
        h = L.apply_norm(lp["ln2"], x, cfg.norm_eps, cfg.norm_type)
        if cfg.n_experts:
            mo, _ = moe_lib.moe_forward(lp["moe"], cfg, pol, h, impl=cfg.moe_impl)
            if "mlp" in lp:
                par_ff = cfg.shared_expert_d_ff or cfg.d_ff
                mo = mo + L.mlp_forward(lp["mlp"], cfg.with_(d_ff=par_ff),
                                        pol, h)
            x = x + mo
        else:
            x = x + L.mlp_forward(lp["mlp"], cfg, pol, h)
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    x = L.apply_norm(params["norm"], x, cfg.norm_eps, cfg.norm_type)
    logits = L.unembed(cfg, pol, x, params["embed"])
    return logits, DecodeCache(k=nk, v=nv, pos=cache.pos + 1)
