"""Logical-axis partitioning (MaxText-style) for the production mesh.

Every parameter/activation is annotated with a tuple of *logical* axis names;
a rule table maps logical names to mesh axes. Changing the parallelism
strategy (pure TP, TP+FSDP/ZeRO-3, expert parallelism, sequence parallelism)
means swapping rule tables, not touching model code.

Mesh axes (see repro.launch.mesh):
  pod    - slowest (DCN / inter-pod) axis; pure data parallel
  data   - intra-pod data parallel (also hosts FSDP shards and the sequence
           axis of long-context cells)
  model  - tensor/expert parallel axis
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rules: tensor parallel on "model", ZeRO-3-style parameter sharding
# of the non-TP dimension over "data" (large embeds/mlp only; small leaves
# replicated), batch over ("pod","data").
LOGICAL_RULES: dict[str, Optional[str | tuple]] = {
    "batch": ("pod", "data"),
    "attn_batch": ("pod", "data"),  # batch axis *during attention* (policy may
                                    # extend it over "model": dp_batch mode)
    "seq": None,
    "kv_seq": None,              # K/V time axis inside attention; stays
                                 # replicated when "seq" is sharded (dp_seq)
                                 # so XLA all-gathers K/V once per layer
    "cache_seq": None,           # KV-cache time axis (policy: "model" for
                                 # flash-decoding style decode)
    "seq_shard": "data",         # sequence parallelism for long-context decode
    "embed": None,
    "embed_fsdp": "data",        # ZeRO-3: shard hidden dim of big matrices
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "expert": "model",
    "expert_cap": None,
    "layers": None,
    "rnn": "model",
    "conv": None,
}

# Pure tensor-parallel rules (no ZeRO): used on small models / serving.
TP_ONLY_RULES = dict(LOGICAL_RULES, embed_fsdp=None)


def logical_spec(axes: Sequence[Optional[str]],
                 rules: Mapping[str, Optional[str | tuple]] = LOGICAL_RULES
                 ) -> P:
    """Tuple of logical axis names -> PartitionSpec."""
    return P(*[rules.get(a) if a is not None else None for a in axes])


def logical_sharding(mesh: Mesh, axes: Sequence[Optional[str]],
                     rules=LOGICAL_RULES) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(axes, rules))


def shard_params_spec(axes_tree, rules=LOGICAL_RULES):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(lambda ax: logical_spec(ax, rules), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))


def constrain(x, *axes, rules=LOGICAL_RULES):
    """with_sharding_constraint by logical axes; no-op outside a mesh."""
    try:
        return jax.lax.with_sharding_constraint(x, logical_spec(axes, rules))
    except (ValueError, RuntimeError):
        return x
