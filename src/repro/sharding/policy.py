"""Per-(arch x mesh x shape) sharding policy resolution.

The production mesh is fixed by the assignment — ``("data","model")`` =
(16,16) single-pod, ``("pod","data","model")`` = (2,16,16) multi-pod — but
the right *use* of those axes depends on the workload. The resolver picks a
parallelism strategy by napkin math over analytic parameter counts and
token volumes (the §Perf methodology, executed in code), then builds the
logical-rule table the models' ``constrain`` calls read.

Training strategies (estimated collective bytes per step, P = param bytes,
tok_col = tokens per TP column, L = layers):

  dp_zero1  — batch spans every mesh axis, params replicated, optimizer
              sharded over "data". Collective = grad all-reduce ~ 2P.
              Feasible when P fits HBM alongside activations.
  dp_zero3  — batch spans every mesh axis, params sharded over
              ("data","model") (ZeRO-3). Collective ~ 4P (3x param
              all-gather across fwd/remat/bwd + grad reduce-scatter).
  tp        — Megatron tensor parallel over "model" + ZeRO-3 over "data":
              collective ~ 4P/tp + 6 L tok_col d (per-layer activation
              all-reduces). Wins when P is huge (MoE) so the param mass
              dominates, or when the batch cannot span the model axis.

The baseline recorded in EXPERIMENTS.md §Perf is strategy="tp" for every
cell (the first thing a Megatron-shaped framework does); "auto" is the
beyond-paper optimized configuration.

Serving (prefill/decode) always replicates weights over "data" (no ZeRO
gathers on the latency path) and shards attention by head-parallelism when
head counts divide, else falls back per the mode ladder below.

Attention modes:
  tp_heads — Megatron head-parallel attention; GQA KV heads replicated
             ``kv_repeat``x when KV < TP (exact).
  dp_batch — batch-parallel attention (Ulysses-style reshard) for head
             counts that do not divide TP.
  none     — attention unsharded over "model" (always correct, last resort).
Decode: ``seq_kv`` shards the KV-cache time axis over "model"
(flash-decoding) when heads cannot shard.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional

from repro.models import analysis
from repro.models.config import ModelConfig
from repro.sharding import partitioning

Axis = Optional[str | tuple]

HBM_BUDGET = 12e9          # per-chip bytes we allow the plan to claim


@dataclasses.dataclass(frozen=True)
class Policy:
    rules: Mapping[str, Axis]     # logical axis -> mesh axis table
    strategy: str                 # tp | dp_zero1 | dp_zero3 | serve
    attn_mode: str                # tp_heads | dp_batch | none
    decode_attn: str              # tp_heads | seq_kv | none
    kv_repeat: int                # KV head replication factor (tp_heads)
    expert_pad: int               # padded expert count (0 = not MoE)
    batch_axes: Axis              # mesh axes the global batch shards over
    notes: tuple[str, ...] = ()   # human-readable resolution log

    def constrain(self, x, *axes):
        return partitioning.constrain(x, *axes, rules=self.rules)

    def spec(self, axes):
        return partitioning.logical_spec(axes, self.rules)


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def single_device_policy(cfg: ModelConfig) -> Policy:
    """No-op policy for CPU smoke tests / single-device runs."""
    rules = {k: None for k in partitioning.LOGICAL_RULES}
    return Policy(rules=rules, strategy="single", attn_mode="tp_heads",
                  decode_attn="tp_heads", kv_repeat=1,
                  expert_pad=cfg.n_experts, batch_axes=None)


def _batch_axes_for(mesh_axes, dp_axes, global_batch):
    for cut in range(len(dp_axes), 0, -1):
        axes = dp_axes[:cut]
        if global_batch % _prod(mesh_axes[a] for a in axes) == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def _attn_mode(cfg, tp, dp, global_batch, batch_axes, notes):
    H, KV = cfg.n_heads, cfg.n_kv_heads
    kv_repeat = 1
    if H % tp == 0 and (KV % tp == 0 or tp % KV == 0):
        mode = "tp_heads"
        if KV % tp != 0:
            kv_repeat = tp // KV
            notes.append(f"kv_heads {KV} < TP {tp}: replicated x{kv_repeat}")
    elif batch_axes is not None and global_batch % (dp * tp) == 0:
        mode = "dp_batch"
        notes.append(f"heads {H} % TP {tp} != 0: batch-parallel attention")
    else:
        mode = "none"
        notes.append(f"heads {H} % TP {tp} != 0 and batch {global_batch} % "
                     f"{dp * tp} != 0: attention unsharded on model")
    return mode, kv_repeat


def _train_strategy(cfg: ModelConfig, mesh_axes, global_batch: int,
                    seq: int, notes: list) -> str:
    """Napkin-math candidate selection (bytes per step, lower = better)."""
    tp = mesh_axes.get("model", 1)
    dp = _prod(mesh_axes[a] for a in ("pod", "data") if a in mesh_axes)
    all_chips = dp * tp
    P = analysis.param_count(cfg) * analysis.param_dtype_bytes(cfg)
    mom = 2 * analysis.param_count(cfg) * 4
    d, L = cfg.d_model, cfg.n_layers
    bc = 2 if cfg.compute_dtype == "bfloat16" else 4
    tok = global_batch * seq

    # MoE resharding penalty: dispatch/combine traffic scales with the
    # tokens a rank routes x top_k x capacity factor
    moe_pen = 0.0
    if cfg.n_experts:
        moe_pen = 2.0 * L * cfg.experts_per_token * cfg.capacity_factor \
            * d * bc

    cands: dict[str, float] = {}
    if global_batch % all_chips == 0:
        # per-chip residency: replicated params + sharded moments
        if P + mom / dp + P <= HBM_BUDGET:
            cands["dp_zero1"] = 2.0 * P + moe_pen * tok / all_chips
        if (P + mom) / all_chips * 3 <= HBM_BUDGET and \
                d % all_chips == 0:
            cands["dp_zero3"] = 4.0 * P + moe_pen * tok / all_chips
    tok_col = tok / dp
    if (P + mom) / all_chips * 3 <= HBM_BUDGET:
        # activation-AR coefficients calibrated against measured HLO
        # traffic (remat re-gathers + loss-vocab ARs roughly double the
        # 6-AR/layer first-principles count). When heads do not divide TP
        # the tp strategy uses dp_batch attention — no attention ARs, only
        # MLP ARs + the attention reshard — measured ~0.6x.
        coeff = 12.0 if cfg.n_heads % tp == 0 else 7.0
        cands["tp"] = 4.0 * P / tp + coeff * L * tok_col * d * bc \
            + moe_pen * tok_col
    # sequence-parallel DP: batch over (pod, data), seq over "model";
    # K/V all-gathered per attention layer. Not for ssm (the chunked
    # mLSTM reshapes the sequence axis).
    if global_batch % dp == 0 and seq % tp == 0 and cfg.family != "ssm" \
            and (P + mom) / (dp * 3) * 3 <= HBM_BUDGET:
        n_attn = cfg.n_layers if cfg.family != "hybrid" else \
            sum(1 for i in range(cfg.n_layers)
                if (cfg.block_pattern or ("rec", "rec", "attn"))
                [i % len(cfg.block_pattern or (1, 1, 1))] == "attn")
        kv_bytes = (global_batch / dp) * seq * 2 * cfg.n_kv_heads * \
            cfg.hd * bc
        # 6 = fwd + remat-refwd gathers + bwd dK/dV reduce-scatters
        cands["dp_seq"] = 4.0 * P + 6.0 * n_attn * kv_bytes \
            + moe_pen * tok / all_chips
    if not cands:
        cands["tp"] = math.inf
        notes.append("no strategy fits HBM budget cleanly; tp fallback")
    best = min(cands, key=cands.get)
    est = " ".join(f"{k}={v / 1e9:.1f}GB" for k, v in sorted(cands.items()))
    notes.append(f"strategy napkin [{est}] -> {best}")
    return best


def resolve(cfg: ModelConfig, mesh_axes: Mapping[str, int],
            global_batch: int, step: str, seq: int = 4096,
            strategy: str = "auto") -> Policy:
    """Pick a sharding policy.

    Args:
      cfg:          model config (full-size dims).
      mesh_axes:    e.g. {"pod": 2, "data": 16, "model": 16}.
      global_batch: batch size of this shape cell.
      step:         "train" | "prefill" | "decode".
      seq:          sequence length (napkin math for strategy choice).
      strategy:     "auto" | "tp" | "dp_zero1" | "dp_zero3".
                    "tp" reproduces the §Perf baseline.
    """
    tp = mesh_axes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh_axes)
    dp = _prod(mesh_axes[a] for a in dp_axes)
    all_axes = dp_axes + (("model",) if "model" in mesh_axes else ())
    notes: list[str] = []

    if step == "train":
        strat = _train_strategy(cfg, mesh_axes, global_batch, seq, notes) \
            if strategy == "auto" else strategy
    else:
        strat = "serve"

    rules: dict[str, Axis] = dict(partitioning.LOGICAL_RULES)

    # ---------------- pure data-parallel strategies: model axis joins batch
    if strat in ("dp_zero1", "dp_zero3"):
        batch_axes = all_axes
        for ax in ("heads", "kv_heads", "mlp", "expert", "vocab", "rnn"):
            rules[ax] = None
        rules["batch"] = batch_axes
        rules["attn_batch"] = batch_axes
        rules["cache_seq"] = None
        rules["embed_fsdp"] = all_axes if strat == "dp_zero3" else None
        notes.append(f"{strat}: batch spans {batch_axes}; "
                     f"params {'sharded ' + str(all_axes) if strat == 'dp_zero3' else 'replicated'}")
        return Policy(rules=rules, strategy=strat, attn_mode="tp_heads",
                      decode_attn="tp_heads", kv_repeat=1,
                      expert_pad=cfg.n_experts,
                      batch_axes=batch_axes, notes=tuple(notes))

    # ---------------- sequence-parallel DP: seq over "model", ZeRO on data
    if strat == "dp_seq":
        batch_axes = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        for ax in ("heads", "kv_heads", "mlp", "expert", "vocab", "rnn"):
            rules[ax] = None
        rules["batch"] = batch_axes
        rules["attn_batch"] = batch_axes
        rules["seq"] = "model"
        rules["kv_seq"] = None          # K/V gathered per layer (exact)
        rules["cache_seq"] = None
        rules["embed_fsdp"] = "data"
        notes.append(f"dp_seq: batch over {batch_axes}, seq over model "
                     "(per-layer K/V all-gather), ZeRO-3 over data")
        return Policy(rules=rules, strategy=strat, attn_mode="dp_seq",
                      decode_attn="tp_heads", kv_repeat=1,
                      expert_pad=cfg.n_experts,
                      batch_axes=batch_axes, notes=tuple(notes))

    # ---------------- tensor-parallel (train baseline) / serving
    batch_axes = _batch_axes_for(mesh_axes, dp_axes, global_batch)
    if batch_axes is None:
        notes.append(f"batch {global_batch} not shardable on {dp_axes}: "
                     "replicated")
    attn_mode, kv_repeat = _attn_mode(cfg, tp, dp, global_batch, batch_axes,
                                      notes)
    if step == "decode":
        decode_attn = "tp_heads" if attn_mode == "tp_heads" else "seq_kv"
        if decode_attn == "seq_kv":
            notes.append("decode: KV-cache time axis sharded over model "
                         "(flash-decoding)")
    else:
        decode_attn = "tp_heads" if attn_mode == "tp_heads" else "none"

    expert_pad = 0
    if cfg.n_experts:
        expert_pad = int(math.ceil(cfg.n_experts / tp) * tp)
        if expert_pad != cfg.n_experts:
            notes.append(f"experts {cfg.n_experts} padded to {expert_pad} "
                         f"for EP={tp}")

    rules["batch"] = batch_axes
    if attn_mode == "dp_batch":
        flat = (batch_axes if isinstance(batch_axes, tuple)
                else (batch_axes,) if batch_axes else ())
        rules["attn_batch"] = tuple(flat) + ("model",)
        rules["heads"] = None
        rules["kv_heads"] = None
    elif attn_mode == "tp_heads":
        rules["attn_batch"] = batch_axes
        rules["heads"] = "model"
        rules["kv_heads"] = "model"
    else:
        rules["attn_batch"] = batch_axes
        rules["heads"] = None
        rules["kv_heads"] = None
    rules["cache_seq"] = "model" if decode_attn == "seq_kv" else None
    if strat == "serve":
        # serving never pays ZeRO all-gathers on the latency path
        rules["embed_fsdp"] = None
        notes.append("serve: weights replicated over data (no ZeRO gathers)")
    elif cfg.d_model % max(mesh_axes.get("data", 1), 1) != 0:
        rules["embed_fsdp"] = None
        notes.append("d_model not divisible by data axis: FSDP off")
    return Policy(rules=rules, strategy=strat, attn_mode=attn_mode,
                  decode_attn=decode_attn, kv_repeat=kv_repeat,
                  expert_pad=expert_pad, batch_axes=batch_axes,
                  notes=tuple(notes))
