from repro.sharding.partitioning import (LOGICAL_RULES, logical_sharding,
                                         logical_spec, shard_params_spec,
                                         constrain)

__all__ = ["LOGICAL_RULES", "logical_sharding", "logical_spec",
           "shard_params_spec", "constrain"]
