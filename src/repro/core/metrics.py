"""JMS efficiency metrics (paper §3).

  1. full utilization    — busy node-seconds / (M * window)   (init counts)
  2. useful utilization  — useful node-seconds / (M * window) (init is idle)
  3. job queue time      — group start - submit (avg and median)
  4. queue length        — time-average number of waiting jobs

All metrics are measured over the window [0, last submit] (paper: "from the
experiment start to the last job submit"); the simulation itself runs to
drain. All computations are jnp so a whole sweep's metrics stay on device.

Every metric inherits the simulation dtype: float32 by default, float64 when
the workload was packed under the `repro.core.precision` opt-in. The
measured float32-vs-float64 deviations over the paper grid are recorded in
``benchmarks/results/BENCH_dtype.json``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


# The scalar per-experiment metric fields (excludes n_groups/ok bookkeeping),
# and the near-zero floors used whenever a *relative* comparison of metric
# values is made: |a - b| / max(|b|, floor). Both the dtype tolerance study
# (benchmarks/bench_dtype.py) and the golden regression suite
# (tests/test_golden_metrics.py) import these so measured deviations and
# enforced tolerances always share the same denominator.
SCALAR_METRIC_FIELDS = ("avg_wait", "med_wait", "avg_qlen", "full_util",
                        "useful_util", "avg_run_wait")
METRIC_REL_FLOORS = {"avg_wait": 1e-3, "med_wait": 1e-3, "avg_run_wait": 1e-3,
                     "avg_qlen": 1e-6, "full_util": 1e-6, "useful_util": 1e-6}


class Metrics(NamedTuple):
    avg_wait: jnp.ndarray      # seconds
    med_wait: jnp.ndarray      # seconds
    avg_qlen: jnp.ndarray      # jobs
    full_util: jnp.ndarray     # [0, 1]
    useful_util: jnp.ndarray   # [0, 1]
    avg_run_wait: jnp.ndarray  # secondary: wait until job's own run start
    n_groups: jnp.ndarray
    ok: jnp.ndarray
    # chaos lane outputs (zeros / False without a ChaosConfig). These stay
    # out of SCALAR_METRIC_FIELDS: the golden grid and the dtype tolerance
    # study pin the fault-free metric set, chaos suites pin these.
    lost_work: jnp.ndarray         # chip-seconds lost past checkpoints
    failures: jnp.ndarray          # failed groups
    straggler_kills: jnp.ndarray   # deadline kills (failure wins ties)
    requeues: jnp.ndarray          # requeue rounds (failed or killed)
    requeued_jobs: jnp.ndarray     # individual members requeued (exact
                                   # per-member credit; see des.py "requeue")
    budget_exhausted: jnp.ndarray  # event/iteration budget hit: truncated


def efficiency_metrics(submit, result, m_nodes, t_last_submit) -> Metrics:
    """Compute paper §3 metrics from a DesResult-shaped record.

    Args:
      submit: [N] job submit times.
      result: DesResult (from packet or baseline simulators).
      m_nodes: cluster size M.
      t_last_submit: metric window end.
    """
    window = jnp.maximum(t_last_submit, 1e-9)
    denom = m_nodes * window
    wait = jnp.maximum(result.start_t - submit, 0.0)
    run_wait = jnp.maximum(result.run_start_t - submit, 0.0)
    return Metrics(
        avg_wait=wait.mean(),
        med_wait=jnp.median(wait),
        avg_qlen=result.qlen_int / window,
        full_util=result.busy_ns / denom,
        useful_util=result.useful_ns / denom,
        avg_run_wait=run_wait.mean(),
        n_groups=result.n_groups,
        ok=result.ok,
        lost_work=result.lost_work,
        failures=result.failures,
        straggler_kills=result.straggler_kills,
        requeues=result.requeues,
        requeued_jobs=result.requeued_jobs,
        budget_exhausted=result.budget_exhausted)
