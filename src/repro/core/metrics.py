"""JMS efficiency metrics (paper §3).

  1. full utilization    — busy node-seconds / (M * window)   (init counts)
  2. useful utilization  — useful node-seconds / (M * window) (init is idle)
  3. job queue time      — group start - submit (avg and median)
  4. queue length        — time-average number of waiting jobs

All metrics are measured over the window [0, last submit] (paper: "from the
experiment start to the last job submit"); the simulation itself runs to
drain. All computations are jnp so a whole sweep's metrics stay on device.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class Metrics(NamedTuple):
    avg_wait: jnp.ndarray      # seconds
    med_wait: jnp.ndarray      # seconds
    avg_qlen: jnp.ndarray      # jobs
    full_util: jnp.ndarray     # [0, 1]
    useful_util: jnp.ndarray   # [0, 1]
    avg_run_wait: jnp.ndarray  # secondary: wait until job's own run start
    n_groups: jnp.ndarray
    ok: jnp.ndarray


def efficiency_metrics(submit, result, m_nodes, t_last_submit) -> Metrics:
    """Compute paper §3 metrics from a DesResult-shaped record.

    Args:
      submit: [N] job submit times.
      result: DesResult (from packet or baseline simulators).
      m_nodes: cluster size M.
      t_last_submit: metric window end.
    """
    window = jnp.maximum(t_last_submit, 1e-9)
    denom = m_nodes * window
    wait = jnp.maximum(result.start_t - submit, 0.0)
    run_wait = jnp.maximum(result.run_start_t - submit, 0.0)
    return Metrics(
        avg_wait=wait.mean(),
        med_wait=jnp.median(wait),
        avg_qlen=result.qlen_int / window,
        full_util=result.busy_ns / denom,
        useful_util=result.useful_ns / denom,
        avg_run_wait=run_wait.mean(),
        n_groups=result.n_groups,
        ok=result.ok)
