"""Fixed-shape discrete-event simulator of the Packet algorithm (paper §5-6).

This is the JAX/TPU-native replacement for the paper's Alea-based JMS model:
one `lax.while_loop` program with a small, fixed set of state arrays, jit-able
and `vmap`-able over the experiment grid (scale ratio x init proportion), so
the paper's 1332-experiment study runs as a handful of batched XLA programs
instead of 1332 sequential Java simulations.

Why it vectorizes: the Packet algorithm always drains the *entire* selected
queue into one group (paper Step 3), so each per-type queue is a contiguous
window [head_j, tail_j) over that type's jobs in submit order. Queue
aggregates are O(1) reads of precomputed per-type prefix sums, and nodes are
fungible counts (moldable linear-speedup groups on a homogeneous cluster), so
the whole simulator state is ~a dozen small arrays.

Events: (a) job submission, (b) group completion (nodes released). On every
event the greedy scheduling pass (paper Steps 1-5) runs until it is blocked.

Complexity
----------
The event loop runs O(N) events and forms G <= N groups. The original
("reference") implementation wrote per-job metrics eagerly: every group
formation built an `in_grp` mask over all N jobs and did two masked [N]
writes, so the whole simulation cost O(G * N) — dominated by metric
bookkeeping, not scheduling.

The production path (`simulate_packet`) instead keeps a bounded *group log*:
forming a group appends one O(1) record

    key = jtype * (N + 1) + tail_rank,  (t_start, m_grp, head_prefix_work)

to a flat log of capacity N (every group drains >= 1 job, so G <= N). Inside
a type, group tails are strictly increasing and partition [0, count_j), so a
job of type j and rank r belongs to the type-j group with the smallest
tail > r. One post-loop `argsort` of the log keys plus one vectorized
`searchsorted` of each job's `jtype * (N + 1) + rank` recovers every job's
group — and with it `start_t` and `run_start_t` — in O(N log N) total.

Per-event work is therefore O(H + RING) (queue weights over H types plus the
running-group ring), and the whole simulation is O(N * (H + RING) + N log N)
instead of O(N * G). The ring itself is sized `min(M, N)` (every running
group holds >= 1 node, so at most M run concurrently) rather than a fixed
512, which cuts the loop-carried state ~5x for the paper's homogeneous
M = 100 flows; see `resolve_ring`.

Two equivalent engines expose that loop:

  * `simulate_packet` — `lax.while_loop` with a nested scheduling loop and
    the group log carried as [N] state. Fastest for ONE experiment (exact
    early exit per event); this is the sweep's mode="seq" path.
  * `simulate_packet_scan` — a branchless single-step-kind `lax.scan` over
    a precomputed event budget (~3N, segmented early exit) that EMITS log
    records as scan outputs instead of scattering into [N] carry. This is
    the vmap-friendly form: batched lanes cost about the same per
    experiment as sequential dispatch (the vmapped while engine lost ~16x
    on CPU dragging [lanes, N] log state through lockstep iterations); the
    sweep's chunked/fused modes build on it. See repro.core.sweep.

    The PackedWorkload is an *operand*, never a closure, and every one of
    its array leaves (including the scalar `t_last_submit`) is safe to
    batch: ``jax.vmap(simulate_packet_scan, in_axes=(0, 0, 0, None, None))``
    over a `repro.core.cohort.stack_workloads`-stacked pytree runs W
    same-static workloads in one program — the cohort layer of the sweep
    (`run_cohort_grid`) nests exactly that over the per-lane vmap. Only the
    aux statics (n_types, n_jobs) must agree across the batch; `cohort_key`
    groups workloads so they do.

Chaos (fault injection)
-----------------------
Both engines accept an optional `ChaosConfig` operand porting the host-side
`repro.cluster.scheduler.ClusterSim` fault semantics into the fixed-shape
vectorized model, so MTBF / checkpoint-period / straggler parameters become
sweep lane axes (see repro.core.sweep):

  * per-group exponential chip-slice failures — every group formation g
    consumes one row of a PRECOMPUTED per-lane uniform stream
    ``u_all = uniform(fold_in(PRNGKey(seed), lane), (N + max_requeues, 2))``
    and draws ``t_fail = -log(u2) * (mtbf * 3600) / m``. The stream is
    indexed by the group counter, never by step position, so seq / chunked /
    fused dispatch layouts see bit-identical draws (the differential suite
    pins this);
  * failures resolve at group END, exactly like ClusterSim's `_maybe_fail`:
    the group holds its chips until the scheduled finish, work past the
    last checkpoint (``floor(run_done / ckpt_period) * ckpt_period``) is
    lost, and only the checkpointed fraction counts as useful;
  * straggler stretch + deadline kill — with prob `straggler_prob` the run
    span stretches by `straggler_factor`; if the stretched duration exceeds
    ``straggler_deadline x expected``, the group is killed at the deadline
    and only ``(deadline - s) * m / stretch`` of work is credited;
  * requeue — the uncredited remainder re-enters the queue as its TRUE
    member set. A formed group of type j is always one contiguous rank
    span [qlo, tail) of that type (window + previously requeued pool), so
    ClusterSim's per-member credit walk (`_requeue`: credit members in
    order, requeue whoever keeps > 1e-9 of work) reduces to ONE binary
    search (`_credit_cut`) over the type's work prefix sums `tj_prefw[j]`:
    the cut rank is the first member the credit does not finish, the
    remnant is the rank span [cut, tail) with a done-work RESIDUAL
    carried for the partially credited head member. To keep the scan
    step's scatter count flat, formation only STASHES the span identity
    in the group's ring slot — an int32 code ``1 + qlo*(N+1) + tail``
    in `grp_rem_cnt` plus the available credit in `grp_rem_w` — and the
    walk itself is DEFERRED to the finish event (`_resolve_remnant`),
    which is also when ClusterSim credits members. The per-type POOL
    keeps exact work/oldest aggregates (pool_w / pool_oldest) plus ONE
    packed int32 `pool_code` carrying span head, fragmented bit and
    member count (`_pool_decode`); the partially-credited head member's
    done-work residual is not stored at all — a non-fragmented pool is
    one contiguous span, so the next formation recovers it as span work
    minus pool_w. Memory/budget cost: O(H + ring) extra scalars — three
    [H] fields and three [ring] fields (scatter parity with the
    aggregate pool this replaces), never [N] member state, so the scan
    engine's vmap shape and `event_budget(N, R)` are unchanged (a
    requeue batch still funds at most one extra formation + finish). Count, oldest-submit and queue weight of a remnant are
    exact whenever the pool is one rank span credited oldest-first
    (always, in every differential hand case); if two same-type groups
    finish with remnants before the next formation, or a remnant
    returns after newer jobs already drained past it, the pool is marked
    FRAGMENTED and that one batch falls back to the PR-5 aggregate upper
    bound (all members requeued, group-oldest; encoded as a NEGATED
    count in the ring stash) — work stays exact and the flag clears at
    the type's next formation. Rank order equals ClusterSim's append
    order except when jobs submitted during the failed group's run are
    themselves split by the credit;
  * bounded injection — at most `max_requeues` (default N) requeues are
    injected per lane, so group count stays <= N + max_requeues and
    `event_budget(N, max_requeues)` stays analytic. Hitting a genuinely
    too-small user budget is reported as ``budget_exhausted=True`` in the
    result instead of silently truncating the schedule.

With ``chaos=None`` (the default) none of this is traced and the engines
are bitwise-identical to their pre-chaos form; a ChaosConfig with
``mtbf_chip_hours=0, straggler_prob=0`` is also bitwise-identical (every
fault predicate is False and all accumulator increments are exact zeros).

Precision
---------
The simulation dtype is set at `pack_workload(..., dtype=...)` and carried
by every time/accumulator array; float64 requires the scoped opt-in in
`repro.core.precision` (never a global flag flip). Measured against the
float64 reference over the full 37 x 6 paper grid
(benchmarks/results/BENCH_dtype.json, 5000-job flows):

  * homogeneous flows and FCFS stay at rounding level in float32 (max
    same-schedule relative deviation ~7e-3 on waits, ~1e-6 .. 2e-6 on
    utilizations and FCFS metrics), with <= 3 decision flips per 222 cells;
  * heterogeneous 5000-job flows are float32-CHAOTIC: 77-83% of grid cells
    resolve a near-tie in queue weights or event order differently and the
    schedule diverges wholesale (up to ~650% on per-cell avg_wait; EASY
    backfill flips too, up to ~25%). Per-cell metric work on long-horizon
    heterogeneous workloads should use the float64 opt-in.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packet, precision
from repro.workload.lublin import Workload

INF = jnp.inf
RING = 512           # static fallback ring size (used when M is traced)
CREDIT_EPS = 1e-9    # ClusterSim _requeue's "fully credited" threshold


def _register_optimization_barrier_batcher() -> None:
    """Make `lax.optimization_barrier` usable under vmap on jax 0.4.x.

    The chaos engine barriers its per-event float accumulates so both DES
    engines round them identically (no engine-specific FMA fusion — see
    `_chaos_outcome`). The primitive is elementwise-identity, so the rule
    simply passes batch dims through; newer jax registers this upstream,
    in which case (or if the private module moves) this is a no-op.
    """
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching
    except ImportError:     # pragma: no cover - future jax relayout
        return
    if optimization_barrier_p in batching.primitive_batchers:
        return

    def _rule(args, dims):
        return optimization_barrier_p.bind(*args), list(dims)

    batching.primitive_batchers[optimization_barrier_p] = _rule


_register_optimization_barrier_batcher()


def resolve_ring(m_nodes, n_jobs: int, ring: int | None = None) -> int:
    """Ring size for the running-group buffer.

    Every running group (or rigid job) holds at least one node, so at most
    `min(M, N)` can run concurrently. When `m_nodes` is a concrete Python or
    NumPy scalar we size the ring exactly; under tracing (e.g. M itself is a
    vmap axis) we fall back to the static `RING` cap.
    """
    if ring is not None:
        return max(1, int(ring))
    try:
        m = int(m_nodes)
    except Exception:       # traced value — no concrete M at trace time
        return max(1, min(RING, n_jobs)) if n_jobs else 1
    return max(1, min(m, n_jobs if n_jobs else m))


@dataclasses.dataclass(frozen=True)
class PackedWorkload:
    """Device-resident, per-type-indexed form of a Workload.

    H = n_types, N = n_jobs. Per-type tables are rank-indexed (rank r =
    r-th job of that type in submit order), padded with +inf / 0.
    """
    submit: jnp.ndarray      # [N]  global submit order
    work: jnp.ndarray        # [N]  w_i = e_i * n_i
    jtype: jnp.ndarray       # [N]
    rank: jnp.ndarray        # [N]  rank of job i within its type
    cumw: jnp.ndarray        # [N]  per-type prefix work *before* job i
    nodes: jnp.ndarray       # [N]  rigid node request (baselines only)
    runtime: jnp.ndarray     # [N]  e_i on n_i nodes (baselines only)
    tj_submit: jnp.ndarray   # [H, N]   submit of type j's rank-r job (+inf pad)
    tj_prefw: jnp.ndarray    # [H, N+1] prefix sums of work per type
    t_last_submit: jnp.ndarray  # scalar: metric window end (paper §3)
    n_types: int
    n_jobs: int


def _pw_flatten(pw: PackedWorkload):
    children = (pw.submit, pw.work, pw.jtype, pw.rank, pw.cumw, pw.nodes,
                pw.runtime, pw.tj_submit, pw.tj_prefw, pw.t_last_submit)
    return children, (pw.n_types, pw.n_jobs)


def _pw_unflatten(aux, children):
    return PackedWorkload(*children, n_types=aux[0], n_jobs=aux[1])


jax.tree_util.register_pytree_node(PackedWorkload, _pw_flatten, _pw_unflatten)


def pack_workload(wl: Workload, dtype=jnp.float32) -> PackedWorkload:
    """Build the per-type-indexed tables with numpy segment prefix sums.

    A stable sort by type turns each type into one contiguous segment, so
    per-type ranks and prefix work are plain offset arithmetic on one global
    cumsum — no Python loop over jobs.

    `dtype` selects the simulation precision for every float table and, via
    the packed arrays, every downstream accumulator. float64 requires the
    explicit x64 opt-in (`repro.core.precision.dtype_scope`); requesting it
    outside a scope raises instead of silently truncating to float32.
    """
    dtype = precision.canonical_dtype(dtype)
    H, N = wl.params.n_types, wl.n_jobs
    jt = np.asarray(wl.jtype, np.int64)
    w = np.asarray(wl.work, np.float64)
    submit = np.asarray(wl.submit, np.float64)

    order = np.argsort(jt, kind="stable")
    jt_s = jt[order]
    w_s = w[order]
    counts = np.bincount(jt, minlength=H)
    seg_start = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(N)
    rank_s = pos - seg_start[jt_s]                      # rank within type
    cum = np.concatenate([[0.0], np.cumsum(w_s)])
    cumw_s = cum[pos] - cum[seg_start[jt_s]]            # prefix work in type

    rank = np.zeros(N, np.int32)
    cumw = np.zeros(N, np.float64)
    rank[order] = rank_s.astype(np.int32)
    cumw[order] = cumw_s

    tj_submit = np.full((H, N), np.inf)
    tj_submit[jt_s, rank_s] = submit[order]
    tj_prefw = np.zeros((H, N + 1), np.float64)
    tj_prefw[jt_s, rank_s + 1] = cumw_s + w_s
    # extend prefix sums into the padding so prefw[tail] is always valid
    # (work >= 0 makes each row nondecreasing, so a running max fills pads)
    tj_prefw = np.maximum.accumulate(tj_prefw, axis=1)

    f = lambda a: jnp.asarray(a, dtype)
    return PackedWorkload(
        submit=f(wl.submit), work=f(wl.work), jtype=jnp.asarray(wl.jtype, jnp.int32),
        rank=jnp.asarray(rank), cumw=f(cumw), nodes=jnp.asarray(wl.nodes, jnp.int32),
        runtime=f(wl.runtime), tj_submit=f(tj_submit), tj_prefw=f(tj_prefw),
        t_last_submit=f(wl.submit[-1]), n_types=H, n_jobs=N)


# --------------------------------------------------------------------------
# Chaos: fault-injection parameters (ported from cluster/scheduler.py).
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Fault-injection operand for the DES engines (see module docstring).

    The five fault parameters and `lane` are pytree children: scalars for a
    single run, or equal-length arrays when vmapped as a chaos lane axis
    (repro.core.sweep broadcasts them). `lane` is the dispatch-invariant
    per-lane stream id — the sweep overwrites it with the flat grid index,
    so a lane's failure draws do not depend on how lanes were chunked,
    sorted or padded. `seed` and `max_requeues` are static aux (they size
    the uniform stream and the event budget); ``max_requeues=None`` resolves
    to the job count N at simulation time.
    """
    mtbf_chip_hours: object = 0.0     # 0 = no failures (ClusterSim default)
    ckpt_period: object = 300.0
    straggler_prob: object = 0.0
    straggler_factor: object = 1.5
    straggler_deadline: object = 2.0
    lane: object = 0
    seed: int = 0
    max_requeues: int | None = None


def _chaos_flatten(c: ChaosConfig):
    children = (c.mtbf_chip_hours, c.ckpt_period, c.straggler_prob,
                c.straggler_factor, c.straggler_deadline, c.lane)
    return children, (c.seed, c.max_requeues)


def _chaos_unflatten(aux, children):
    return ChaosConfig(*children, seed=aux[0], max_requeues=aux[1])


jax.tree_util.register_pytree_node(ChaosConfig, _chaos_flatten,
                                   _chaos_unflatten)


def resolve_max_requeues(chaos: ChaosConfig | None, n_jobs: int) -> int:
    """Static requeue-injection budget R: 0 without chaos, N by default."""
    if chaos is None:
        return 0
    if chaos.max_requeues is None:
        return max(1, int(n_jobs))
    return max(0, int(chaos.max_requeues))


def chaos_is_inert(chaos: ChaosConfig | None) -> bool:
    """True when `chaos` cannot inject any fault: None, or concrete
    all-zero failure and straggler rates (e.g. the default ChaosConfig()).

    The sweep/cohort drivers normalize inert configs to None before
    compiling, so "chaos disabled" runs the exact pre-chaos programs —
    same engines, same event-budget shapes, bitwise-identical metrics —
    instead of a zero-rate chaos trace. Traced leaves (inside jit/vmap)
    are conservatively treated as active.
    """
    if chaos is None:
        return True
    try:
        mtbf = np.asarray(chaos.mtbf_chip_hours)
        prob = np.asarray(chaos.straggler_prob)
    except Exception:
        return False
    return bool(np.all(mtbf == 0) and np.all(prob == 0))


def chaos_uniforms(chaos: ChaosConfig, dtype, n_groups_cap: int):
    """The per-lane uniform stream: row g = (straggler draw, failure draw)
    of the g-th group FORMED in this lane. Precomputed outside the event
    loop and indexed by the group counter, so every dispatch layout (and
    both engines) consumes identical draws. Exposed for hand tests that
    re-derive expected fault outcomes."""
    key = jax.random.fold_in(jax.random.PRNGKey(chaos.seed),
                             jnp.asarray(chaos.lane, jnp.uint32))
    return jax.random.uniform(key, (max(1, int(n_groups_cap)), 2),
                              dtype=precision.canonical_dtype(dtype))


class _ChaosOutcome(NamedTuple):
    dur: jnp.ndarray        # effective duration (stretch/kill applied)
    failed: jnp.ndarray     # failure strikes before the (effective) end
    killed: jnp.ndarray     # straggler deadline kill (failure wins ties)
    ckpt_done: jnp.ndarray  # checkpointed run seconds at failure time
    credit: jnp.ndarray     # work credited toward completion (chip-seconds)
    lost: jnp.ndarray       # chip-seconds lost past the last checkpoint


def _chaos_outcome(chaos: ChaosConfig, u1, u2, inject, s, work, m_grp,
                   dur0, dtype) -> _ChaosOutcome:
    """Per-group fault outcome, mirroring ClusterSim's _schedule/_finish.

    All branches are `jnp.where` with the no-fault value equal to the exact
    pre-chaos expression, so a zero ChaosConfig changes no bits. `inject`
    gates every fault (the bounded-requeue cap); precedence matches
    ClusterSim: a failure before the effective end wins over a deadline
    kill, which wins over plain completion.
    """
    m_f = m_grp.astype(dtype)
    tiny = jnp.asarray(np.finfo(np.dtype(dtype)).tiny, dtype)
    prob = jnp.asarray(chaos.straggler_prob, dtype)
    factor = jnp.asarray(chaos.straggler_factor, dtype)
    s_dead = jnp.asarray(chaos.straggler_deadline, dtype)
    mtbf = jnp.asarray(chaos.mtbf_chip_hours, dtype)
    ckpt = jnp.asarray(chaos.ckpt_period, dtype)

    stretched = inject & (u1 < prob)
    dur_s = jnp.where(stretched, s + (work / m_f) * factor, dur0)
    deadline = s_dead * dur0                     # x expected duration
    killed = inject & (dur_s > deadline)
    dur = jnp.where(killed, deadline, dur_s)
    t_fail = -jnp.log(jnp.maximum(u2, tiny)) * (mtbf * 3600.0) / m_f
    failed = inject & (mtbf > 0) & (t_fail < dur)
    run_done = jnp.maximum(jnp.minimum(t_fail, dur) - s, 0.0)
    ckpt_done = jnp.floor(run_done / jnp.maximum(ckpt, tiny)) * ckpt
    stretch = jnp.where(stretched, factor, jnp.ones((), dtype))
    credit = jnp.where(
        failed, ckpt_done * m_f / stretch,
        jnp.where(killed, jnp.maximum(dur - s, 0.0) * m_f / stretch, work))
    lost = jnp.where(failed, (run_done - ckpt_done) * m_f,
                     jnp.zeros((), dtype))
    # Barrier the outputs so XLA cannot fuse this arithmetic into the
    # surrounding engine code (e.g. an FMA formed in one program but not
    # another): every downstream consumer sees fault quantities rounded
    # here, once. This pins HLO-level fusion only — LLVM may still
    # contract mul+add at codegen — so the hard bitwise-parity guarantee
    # for fault sweeps comes from all dispatch modes sharing the scan
    # engine (see sweep._packet_one), with the barrier keeping that
    # engine's scalar and vmapped compilations rounding alike.
    return _ChaosOutcome(*jax.lax.optimization_barrier(
        (dur, failed, killed, ckpt_done, credit, lost)))


class DesState(NamedTuple):
    t: jnp.ndarray            # current time
    next_sub: jnp.ndarray     # index of next submission (global order)
    head: jnp.ndarray         # [H] per-type queue window start (rank)
    tail: jnp.ndarray         # [H] per-type queue window end (rank)
    m_free: jnp.ndarray       # free nodes
    grp_end: jnp.ndarray      # [ring] completion time of running groups (+inf = free)
    grp_m: jnp.ndarray        # [ring] nodes held
    log_key: jnp.ndarray      # [N] group log: jtype * (N+1) + tail rank
    log_t: jnp.ndarray        # [N] group start time
    log_m: jnp.ndarray        # [N] group node count
    log_headw: jnp.ndarray    # [N] per-type prefix work at group head
    qlen_int: jnp.ndarray     # integral of queue length over [0, t_last_submit]
    busy_ns: jnp.ndarray      # busy node-seconds within the metric window
    useful_ns: jnp.ndarray    # useful node-seconds within the metric window
    n_groups: jnp.ndarray     # groups formed == next free log slot
    iters: jnp.ndarray        # diagnostic: outer loop iterations
    # chaos state (zeros / untouched when chaos is None)
    pool_w: jnp.ndarray       # [H] requeued remainder work per type
    pool_oldest: jnp.ndarray  # [H] oldest submit among requeued jobs (+inf)
    # packed span identity + count (0 == empty pool):
    #   (head_rank * 2 + fragmented) * (N + 1) + count        (_pool_decode)
    # The head member's done-work residual is NOT stored: a non-fragmented
    # pool is one contiguous span [head_rank, head[j]) merged at a single
    # finish, so formation recovers it as span work - pool_w.
    pool_code: jnp.ndarray    # [H] packed (head rank, fragmented, count)
    grp_jtype: jnp.ndarray    # [ring] type of each running group
    # per-slot requeue stash, resolved by the credit walk at finish:
    #   grp_rem_cnt > 0 — walk path: 1 + qlo * (N+1) + tail span code,
    #     grp_rem_w = credit available (pool residual + chaos credit)
    #   grp_rem_cnt < 0 — fragmented-pool fallback: -count,
    #     grp_rem_w / grp_rem_oldest = the PR-5 aggregate remainder
    #   grp_rem_cnt == 0 — nothing to requeue
    grp_rem_w: jnp.ndarray    # [ring] available credit / aggregate work
    grp_rem_cnt: jnp.ndarray  # [ring] span code / negated count (see above)
    grp_rem_oldest: jnp.ndarray  # [ring] aggregate oldest (frag path only)
    lost_work: jnp.ndarray    # chip-seconds lost past checkpoints
    failures: jnp.ndarray
    straggler_kills: jnp.ndarray
    requeues: jnp.ndarray     # also the injection gate (vs max_requeues)
    requeued_jobs: jnp.ndarray  # members re-entering the queue, total


class DesResult(NamedTuple):
    start_t: jnp.ndarray
    run_start_t: jnp.ndarray
    qlen_int: jnp.ndarray
    busy_ns: jnp.ndarray
    useful_ns: jnp.ndarray
    n_groups: jnp.ndarray
    makespan: jnp.ndarray
    ok: jnp.ndarray           # simulation drained within the iteration cap
    budget_exhausted: jnp.ndarray  # iteration/step budget hit: truncated run
    lost_work: jnp.ndarray    # chip-seconds lost to failures (not clipped)
    failures: jnp.ndarray
    straggler_kills: jnp.ndarray
    requeues: jnp.ndarray     # requeue batches (one per failed/killed group)
    requeued_jobs: jnp.ndarray  # individual members re-entering the queue


def _window_overlap(a, b, t_end):
    """Length of [a, b] clipped to the metric window [0, t_end]."""
    return jnp.maximum(jnp.minimum(b, t_end) - jnp.minimum(a, t_end), 0.0)


def _credit_cut(tj_prefw, j, lo, hi, target):
    """Largest rank in [lo, hi] with ``tj_prefw[j, rank] <= target``.

    Equivalent to ``clip(searchsorted(tj_prefw[j], target, 'right') - 1,
    lo, hi)`` under the caller's invariant ``tj_prefw[j, lo] <= target``
    (prefix rows are non-decreasing, and target = prefw[lo] + nonneg),
    but as a fixed-trip branchless binary search: ceil(log2(N + 1))
    scalar gathers per event instead of materializing the [N + 1] row
    every scan step — the row gather alone pushed the fused chaos sweep
    to ~3x a zero-chaos lane, past the 2x CI bar.
    """
    steps = max(int(tj_prefw.shape[1] - 1).bit_length(), 1)
    for _ in range(steps):
        mid = (lo + hi + 1) >> 1
        go = tj_prefw[j, mid] <= target
        lo = jnp.where(go, mid, lo)
        hi = jnp.where(go, hi, mid - 1)
    return lo


def _resolve_remnant(pw: PackedWorkload, j_f, code, stored_w, stored_old,
                     dtype):
    """Resolve a ring slot's requeue stash at group finish.

    Returns ``(cnt, w, oldest, lo, hi, walk)`` — the remnant member set
    to merge into the type's pool. Walk path (``code > 0``): decode the
    span, run ClusterSim's in-order credit walk via `_credit_cut`, and
    derive count / work / oldest from the static work prefix sums, so
    the scan carries no per-slot member state beyond the (code, credit,
    oldest) triple. ``w`` excludes the partially-credited head member's
    residual, which formation recovers from the span aggregates (see
    `pool_code` in DesState). Frag path (``code < 0``) passes the stored
    aggregates through; ``code == 0`` resolves to an empty remnant
    (cnt 0, w 0, oldest +inf — identity under the pool merge).
    """
    N = pw.n_jobs
    zero_f = jnp.zeros((), dtype)
    eps = jnp.asarray(CREDIT_EPS, dtype)
    walk = code > 0
    span = jnp.maximum(code - 1, 0)
    qlo = (span // (N + 1)).astype(jnp.int32)
    hi = (span % (N + 1)).astype(jnp.int32)
    qlo_w = pw.tj_prefw[j_f, qlo]
    hi_w = pw.tj_prefw[j_f, hi]
    target = qlo_w + stored_w + eps
    cut = _credit_cut(pw.tj_prefw, j_f, qlo, hi, target)
    cut_w = pw.tj_prefw[j_f, cut]
    m_res = jnp.maximum(stored_w - (cut_w - qlo_w), zero_f)
    m_w = jnp.maximum(hi_w - cut_w - m_res, zero_f)
    m_cnt = hi - cut
    m_old = pw.tj_submit[j_f, jnp.minimum(cut, N - 1)]
    return (jnp.where(walk, m_cnt, -code),
            jnp.where(walk, m_w, stored_w),
            jnp.where(walk & (m_cnt > 0), m_old, stored_old),
            jnp.where(walk, cut, jnp.zeros((), jnp.int32)),
            hi,
            walk)


def _pool_decode(code, n_jobs):
    """(count, head rank, fragmented) from a packed `pool_code` value."""
    cnt = code % (n_jobs + 1)
    meta = code // (n_jobs + 1)
    return cnt, meta >> 1, (meta & 1) == 1


def _reconstruct_job_times(pw: PackedWorkload, log_key, log_t, log_m,
                           log_headw, s_j):
    """Vectorized post-pass: job -> its group via per-type searchsorted.

    Within a type, group tails strictly increase and partition that type's
    ranks, so job (j, r) belongs to the type-j group with the smallest
    tail > r. Encoding groups as `j * (N+1) + tail` and jobs as
    `j * (N+1) + rank` makes that one global sorted lookup: tails are in
    1..N so type blocks never interleave. The log may have any capacity
    L >= 1 (the while engine uses L = N, the scan engine L = its step
    budget); unused slots carry the int32-max pad key and sort last. Jobs
    never grouped (only possible when the iteration/budget cap was hit)
    keep start = +inf, which also keeps the `ok` flag's all-finite check
    faithful.
    """
    N = pw.n_jobs
    L = log_key.shape[0]
    dtype = pw.submit.dtype
    order = jnp.argsort(log_key)
    skey = log_key[order]
    q = pw.jtype * (N + 1) + pw.rank
    ppos = jnp.searchsorted(skey, q, side="right")
    g = order[jnp.minimum(ppos, L - 1)]
    covered = (ppos < L) & (log_key[g] // (N + 1) == pw.jtype)
    t0 = log_t[g]
    m_g = jnp.maximum(log_m[g], 1).astype(dtype)
    start_t = jnp.where(covered, t0, INF)
    run_start = t0 + s_j[pw.jtype] + (pw.cumw - log_headw[g]) / m_g
    run_start_t = jnp.where(covered, run_start, INF)
    return start_t, run_start_t


def simulate_packet(pw: PackedWorkload, k, s_init, m_nodes,
                    priority=None, t_max=None, max_iters: int | None = None,
                    ring: int | None = None,
                    chaos: ChaosConfig | None = None) -> DesResult:
    """Run the Packet algorithm DES (group-log event loop).

    Args:
      pw:      PackedWorkload (static shapes; close over for jit).
      k:       scale ratio (traced scalar — vmap axis of the sweep).
      s_init:  constant initialization time (traced scalar; per paper §6 the
               init time is one constant per experiment). Per-type init is
               s_j = s_init for all j.
      m_nodes: cluster size M (traced scalar int).
      priority, t_max: optional [H] job-type priorities / wait normalizers.
      ring:    running-group buffer size; default `resolve_ring(m_nodes, N)`.
      chaos:   optional ChaosConfig (module docstring "Chaos"). None traces
               the exact pre-chaos graph; the log capacity and iteration
               cap grow with the static requeue budget when set.
    """
    H, N = pw.n_types, pw.n_jobs
    ring = resolve_ring(m_nodes, N, ring)
    R = resolve_max_requeues(chaos, N)
    L = N + R                       # group-log capacity: G <= N + requeues
    dtype = precision.canonical_dtype(pw.submit.dtype)
    k = jnp.asarray(k, dtype)
    s_init = jnp.asarray(s_init, dtype)
    m_nodes = jnp.asarray(m_nodes, jnp.int32)
    s_j = jnp.full((H,), s_init, dtype)
    p_j = jnp.ones((H,), dtype) if priority is None else jnp.asarray(priority, dtype)
    tmax_j = (jnp.full((H,), 3600.0, dtype) if t_max is None
              else jnp.asarray(t_max, dtype))
    if max_iters is None:
        max_iters = 4 * N + 64 + 2 * R

    t_end_metric = pw.t_last_submit
    type_ids = jnp.arange(H)
    key_pad = jnp.iinfo(jnp.int32).max     # unused log slots sort last
    zero_f = jnp.zeros((), dtype)
    zero_i = jnp.zeros((), jnp.int32)
    one_i = jnp.ones((), jnp.int32)
    u_all = None if chaos is None else chaos_uniforms(chaos, dtype, L)

    def sched_cond(carry):
        st = carry
        nonempty = st.tail > st.head
        if chaos is not None:
            nonempty = nonempty | (st.pool_code > 0)
        free_slot = jnp.any(jnp.isinf(st.grp_end))
        return (st.m_free > 0) & jnp.any(nonempty) & free_slot

    def sched_body(st: DesState) -> DesState:
        nonempty = st.tail > st.head
        sum_w = (pw.tj_prefw[type_ids, st.tail] -
                 pw.tj_prefw[type_ids, st.head])
        oldest = pw.tj_submit[type_ids, jnp.minimum(st.head, N - 1)]
        if chaos is not None:
            # requeued remainder counts toward weight / age / emptiness
            nonempty = nonempty | (st.pool_code > 0)
            sum_w = sum_w + st.pool_w
            oldest = jnp.minimum(oldest, st.pool_oldest)
        w = packet.queue_weights(sum_w, s_j, p_j, oldest, st.t, tmax_j, nonempty)
        # argmax index dtype follows x64 state; pin int32 so the log key
        # scatter below stays exact under the float64 opt-in.
        j = jnp.argmax(w).astype(jnp.int32)                   # Step 2
        work = sum_w[j]
        m_grp = packet.group_nodes(work, k, s_j[j], st.m_free)  # Step 4
        dur = packet.group_duration(work, s_j[j], m_grp)
        slot = jnp.argmax(jnp.isinf(st.grp_end))

        # O(1) group-log append; job times reconstructed after the loop
        gslot = jnp.minimum(st.n_groups, L - 1)
        head_w = pw.tj_prefw[j, st.head[j]]

        upd = {}
        if chaos is None:
            t_fin = st.t + dur
            useful_end = t_fin
        else:
            out = _chaos_outcome(chaos, u_all[gslot, 0], u_all[gslot, 1],
                                 st.requeues < R, s_j[j], work, m_grp, dur,
                                 dtype)
            t_fin = st.t + out.dur
            useful_end = jnp.where(out.failed,
                                   st.t + s_j[j] + out.ckpt_done, t_fin)
            requeued = out.failed | out.killed
            # Stash the requeue for the group's finish event. The drained
            # queue is the rank span [qlo, tail) of type j with a possible
            # done-work residual on its head member; the per-member credit
            # walk (ClusterSim _requeue, oldest first) is DEFERRED to the
            # finish (_resolve_remnant), so the ring carries only a span
            # code and the available credit — no extra per-slot arrays.
            eps = jnp.asarray(CREDIT_EPS, dtype)
            p_cnt, p_lo, p_frag = _pool_decode(st.pool_code[j], N)
            has_pool = p_cnt > 0
            qlo = jnp.where(has_pool, p_lo, st.head[j])
            # recover the head member's done-work residual from the span
            # aggregates (non-fragmented pool = one contiguous span
            # [qlo, head) merged at a single finish)
            res0 = jnp.where(has_pool, jnp.maximum(
                head_w - pw.tj_prefw[j, qlo] - st.pool_w[j], zero_f),
                zero_f)
            walk_ok = ~(has_pool & p_frag)
            avail = res0 + out.credit
            # span code 1 + qlo*(N+1) + tail stays well inside int32 for
            # the paper's N <= 5000 (bound ~ (N+1)^2)
            span_code = 1 + qlo * (N + 1) + st.tail[j]
            # fragmented pool: PR-5 aggregate upper bound for this batch
            rem_agg = work - out.credit
            a_has = requeued & (rem_agg > eps)
            a_cnt = (st.tail[j] - st.head[j]) + p_cnt
            code = jnp.where(requeued & walk_ok, span_code,
                             jnp.where(a_has, -a_cnt, zero_i))
            stash_w = jnp.where(
                requeued & walk_ok, avail,
                jnp.where(a_has, jnp.maximum(rem_agg, zero_f), zero_f))
            stash_old = jnp.where(a_has & ~walk_ok, oldest[j], INF)
            upd = dict(
                grp_jtype=st.grp_jtype.at[slot].set(j),
                grp_rem_w=st.grp_rem_w.at[slot].set(stash_w),
                grp_rem_cnt=st.grp_rem_cnt.at[slot].set(code),
                grp_rem_oldest=st.grp_rem_oldest.at[slot].set(stash_old),
                pool_w=st.pool_w.at[j].set(zero_f),
                pool_oldest=st.pool_oldest.at[j].set(INF),
                pool_code=st.pool_code.at[j].set(zero_i),
                lost_work=st.lost_work + out.lost,
                failures=st.failures + jnp.where(out.failed, one_i, zero_i),
                straggler_kills=st.straggler_kills + jnp.where(
                    out.killed & ~out.failed, one_i, zero_i),
                requeues=st.requeues + jnp.where(requeued, one_i, zero_i))

        busy_inc = m_grp.astype(dtype) * _window_overlap(
            st.t, t_fin, t_end_metric)
        useful_inc = m_grp.astype(dtype) * _window_overlap(
            st.t + s_j[j], useful_end, t_end_metric)
        if chaos is not None:
            # discourage fused mul-add rounding so the scan engine's
            # separately-rounded accumulates usually match bit for bit
            # (best effort in float32 — see sweep._packet_one; exact in
            # float64, which is what tests assert bitwise cross-engine)
            busy_inc, useful_inc = jax.lax.optimization_barrier(
                (busy_inc, useful_inc))
        busy = st.busy_ns + busy_inc
        useful = st.useful_ns + useful_inc

        return st._replace(
            head=st.head.at[j].set(st.tail[j]),               # Step 3: drain all
            m_free=st.m_free - m_grp,
            grp_end=st.grp_end.at[slot].set(t_fin),
            grp_m=st.grp_m.at[slot].set(m_grp),
            log_key=st.log_key.at[gslot].set(j * (N + 1) + st.tail[j]),
            log_t=st.log_t.at[gslot].set(st.t),
            log_m=st.log_m.at[gslot].set(m_grp),
            log_headw=st.log_headw.at[gslot].set(head_w),
            busy_ns=busy, useful_ns=useful,
            n_groups=st.n_groups + 1, **upd)

    def cond(st: DesState):
        more = (st.next_sub < N) | jnp.any(~jnp.isinf(st.grp_end))
        return more & (st.iters < max_iters)

    def body(st: DesState) -> DesState:
        t_sub = jnp.where(st.next_sub < N,
                          pw.submit[jnp.minimum(st.next_sub, N - 1)], INF)
        slot = jnp.argmin(st.grp_end)
        t_fin = st.grp_end[slot]
        take_sub = t_sub <= t_fin
        t_new = jnp.where(take_sub, t_sub, t_fin)

        # queue-length integral over the elapsed interval (clipped to window)
        qlen = jnp.sum(st.tail - st.head).astype(st.t.dtype)
        q_inc = qlen * _window_overlap(st.t, t_new, t_end_metric)
        if chaos is not None:
            qlen = qlen + jnp.sum(st.pool_code % (N + 1)).astype(st.t.dtype)
            q_inc = jax.lax.optimization_barrier(
                qlen * _window_overlap(st.t, t_new, t_end_metric))
        qint = st.qlen_int + q_inc

        def on_submit(st):
            j = pw.jtype[jnp.minimum(st.next_sub, N - 1)]
            return st._replace(next_sub=st.next_sub + 1,
                               tail=st.tail.at[j].add(1))

        def on_finish(st):
            upd = {}
            if chaos is not None:
                # resolve the stashed requeue into its member set NOW —
                # the queue must not see it before the group's end, and
                # ClusterSim's _requeue credits members at the same time
                j_f = st.grp_jtype[slot]
                cnt, rem_w, rem_old, rem_lo, rem_hi, walk = (
                    _resolve_remnant(pw, j_f, st.grp_rem_cnt[slot],
                                     st.grp_rem_w[slot],
                                     st.grp_rem_oldest[slot], dtype))
                old_cnt, old_lo, old_frag = _pool_decode(
                    st.pool_code[j_f], N)
                inc = cnt > 0
                was_empty = old_cnt == 0
                # the remnant span abuts the live window only if no
                # formation of this type ran while the group held it
                contig = rem_hi == st.head[j_f]
                frag = jnp.where(
                    inc, old_frag | ~walk | ~was_empty | ~contig, old_frag)
                new_lo = jnp.where(was_empty, rem_lo,
                                   jnp.minimum(old_lo, rem_lo))
                new_code = ((new_lo * 2 + frag.astype(jnp.int32))
                            * (N + 1) + old_cnt + cnt)
                upd = dict(
                    pool_w=st.pool_w.at[j_f].add(rem_w),
                    pool_oldest=st.pool_oldest.at[j_f].min(rem_old),
                    pool_code=st.pool_code.at[j_f].set(jnp.where(
                        inc, new_code, st.pool_code[j_f])),
                    grp_rem_w=st.grp_rem_w.at[slot].set(zero_f),
                    grp_rem_cnt=st.grp_rem_cnt.at[slot].set(zero_i),
                    grp_rem_oldest=st.grp_rem_oldest.at[slot].set(INF),
                    requeued_jobs=st.requeued_jobs + cnt)
            return st._replace(m_free=st.m_free + st.grp_m[slot],
                               grp_end=st.grp_end.at[slot].set(INF),
                               grp_m=st.grp_m.at[slot].set(0), **upd)

        st = st._replace(t=t_new, qlen_int=qint)
        st = jax.lax.cond(take_sub, on_submit, on_finish, st)
        st = jax.lax.while_loop(sched_cond, sched_body, st)   # Steps 1-5
        return st._replace(iters=st.iters + 1)

    st0 = DesState(
        t=jnp.zeros((), dtype), next_sub=jnp.zeros((), jnp.int32),
        head=jnp.zeros((H,), jnp.int32), tail=jnp.zeros((H,), jnp.int32),
        m_free=m_nodes, grp_end=jnp.full((ring,), INF, dtype),
        grp_m=jnp.zeros((ring,), jnp.int32),
        log_key=jnp.full((L,), key_pad, jnp.int32),
        log_t=jnp.zeros((L,), dtype), log_m=jnp.zeros((L,), jnp.int32),
        log_headw=jnp.zeros((L,), dtype),
        qlen_int=jnp.zeros((), dtype), busy_ns=jnp.zeros((), dtype),
        useful_ns=jnp.zeros((), dtype), n_groups=jnp.zeros((), jnp.int32),
        iters=jnp.zeros((), jnp.int32),
        pool_w=jnp.zeros((H,), dtype),
        pool_oldest=jnp.full((H,), INF, dtype),
        pool_code=jnp.zeros((H,), jnp.int32),
        grp_jtype=jnp.zeros((ring,), jnp.int32),
        grp_rem_w=jnp.zeros((ring,), dtype),
        grp_rem_cnt=jnp.zeros((ring,), jnp.int32),
        grp_rem_oldest=jnp.full((ring,), INF, dtype),
        lost_work=jnp.zeros((), dtype), failures=jnp.zeros((), jnp.int32),
        straggler_kills=jnp.zeros((), jnp.int32),
        requeues=jnp.zeros((), jnp.int32),
        requeued_jobs=jnp.zeros((), jnp.int32))

    st = jax.lax.while_loop(cond, body, st0)
    start_t, run_start_t = _reconstruct_job_times(
        pw, st.log_key, st.log_t, st.log_m, st.log_headw, s_j)
    drained = (st.next_sub >= N) & jnp.all(jnp.isinf(st.grp_end)) & \
        jnp.all(st.head == st.tail)
    if chaos is not None:
        drained = drained & jnp.all(st.pool_code == 0)
    ok = drained & jnp.all(jnp.isfinite(start_t))
    return DesResult(start_t=start_t, run_start_t=run_start_t,
                     qlen_int=st.qlen_int, busy_ns=st.busy_ns,
                     useful_ns=st.useful_ns, n_groups=st.n_groups,
                     makespan=st.t, ok=ok, budget_exhausted=~drained,
                     lost_work=st.lost_work, failures=st.failures,
                     straggler_kills=st.straggler_kills,
                     requeues=st.requeues, requeued_jobs=st.requeued_jobs)


# --------------------------------------------------------------------------
# Event-budget scan engine: the batched-lane form of the group-log DES.
# --------------------------------------------------------------------------

EVENT_BUDGET_SLACK = 64   # headroom over the 3N analytic step bound
SCAN_SEG = 256            # default segment length (early-exit granularity)


def event_budget(n_jobs: int, max_requeues: int = 0) -> int:
    """Safe per-grid step budget for `simulate_packet_scan`.

    Each scan step either consumes one event (a submission or a group
    completion: at most N + G of those) or forms one group (G of those),
    and every group drains >= 1 job OR the pool content of one prior
    requeue, so G <= N + R where R is the bounded requeue-injection count
    (`ChaosConfig.max_requeues`; 0 without chaos). 3N + 2R + slack steps
    therefore always drain a lane, whatever its (k, s) and fault draws.
    """
    return 3 * max(1, int(n_jobs)) + 2 * max(0, int(max_requeues)) + \
        EVENT_BUDGET_SLACK


class _ScanState(NamedTuple):
    t: jnp.ndarray            # current time
    next_sub: jnp.ndarray     # index of next submission (global order)
    head: jnp.ndarray         # [H] per-type queue window start (rank)
    tail: jnp.ndarray         # [H] per-type queue window end (rank)
    m_free: jnp.ndarray       # free nodes
    grp_end: jnp.ndarray      # [ring] completion time of running groups
    grp_m: jnp.ndarray        # [ring] nodes held
    qlen_int: jnp.ndarray
    busy_ns: jnp.ndarray
    useful_ns: jnp.ndarray
    n_groups: jnp.ndarray
    # chaos state (zeros / untouched when chaos is None)
    pool_w: jnp.ndarray       # [H] requeued remainder work per type
    pool_oldest: jnp.ndarray  # [H] oldest submit among requeued jobs
    pool_code: jnp.ndarray    # [H] packed span/frag/count (DesState)
    grp_jtype: jnp.ndarray    # [ring]
    grp_rem_w: jnp.ndarray    # [ring] available credit / aggregate work
    grp_rem_cnt: jnp.ndarray  # [ring] span code / negated count (DesState)
    grp_rem_oldest: jnp.ndarray  # [ring] aggregate oldest (frag path only)
    lost_work: jnp.ndarray
    failures: jnp.ndarray
    straggler_kills: jnp.ndarray
    requeues: jnp.ndarray
    requeued_jobs: jnp.ndarray


#: the recognized per-event step implementations of the scan engine
STEP_IMPLS = ("xla", "pallas")


def _check_step_impl(step_impl: str) -> str:
    if step_impl not in STEP_IMPLS:
        raise ValueError(f"unknown step_impl {step_impl!r}; "
                         f"available: {STEP_IMPLS}")
    return step_impl


def packet_scan_step(pw: PackedWorkload, k, s_j, p_j, tmax_j,
                     st: _ScanState, *, r_cap: int = 0, chaos=None,
                     u_all=None):
    """ONE fused event step of the scan engine — the canonical semantics.

    Branchlessly either forms one group (greedy pass unblocked) or consumes
    one event (submission / group finish), with every state write masked by
    `do_sched` / `do_event`. This module-level form is shared by BOTH step
    implementations of `simulate_packet_scan`: the XLA engine scans it
    directly, and `repro.kernels.packet_step` re-exports it as the pure-jnp
    reference (`ref.py`) that the lane-batched Pallas kernel body mirrors —
    one source of truth for the event arithmetic, so the engines cannot
    drift apart silently.

    Args mirror `simulate_packet_scan`'s internals: `s_j`/`p_j`/`tmax_j`
    are the [H] per-type init/priority/wait-normalizer rows, `r_cap` the
    static requeue-injection budget R, and `u_all` the [N + R, 2] per-lane
    uniform stream (required iff `chaos` is given). Returns
    ``(new_state, (log_key, log_t, log_m, log_headw))``.
    """
    H, N = pw.n_types, pw.n_jobs
    dtype = st.t.dtype
    t_end_metric = pw.t_last_submit
    type_ids = jnp.arange(H)
    key_pad = jnp.iinfo(jnp.int32).max
    zero_f = jnp.zeros((), dtype)
    zero_i = jnp.zeros((), jnp.int32)
    one_i = jnp.ones((), jnp.int32)
    R = r_cap

    nonempty = st.tail > st.head
    if chaos is not None:
        nonempty = nonempty | (st.pool_code > 0)
    free_mask = jnp.isinf(st.grp_end)
    queued = jnp.any(nonempty)
    active = ((st.next_sub < N) | jnp.any(~jnp.isinf(st.grp_end)) |
              jnp.any(st.tail > st.head))
    if chaos is not None:
        active = active | jnp.any(st.pool_code > 0)
    can_sched = (st.m_free > 0) & queued & jnp.any(free_mask)
    do_sched = active & can_sched
    do_event = active & ~can_sched

    # greedy scheduling pass (paper Steps 1-5), masked unless do_sched
    sum_w = (pw.tj_prefw[type_ids, st.tail] -
             pw.tj_prefw[type_ids, st.head])
    oldest = pw.tj_submit[type_ids, jnp.minimum(st.head, N - 1)]
    if chaos is not None:
        sum_w = sum_w + st.pool_w
        oldest = jnp.minimum(oldest, st.pool_oldest)
    w = packet.queue_weights(sum_w, s_j, p_j, oldest, st.t, tmax_j,
                             nonempty)
    j = jnp.argmax(w).astype(jnp.int32)
    work = sum_w[j]
    m_grp = packet.group_nodes(work, k, s_j[j], st.m_free)
    dur = packet.group_duration(work, s_j[j], m_grp)
    sslot = jnp.argmax(free_mask)
    head_w = pw.tj_prefw[j, st.head[j]]
    if chaos is None:
        t_gfin = st.t + dur
        useful_end = t_gfin
    else:
        L_cap = u_all.shape[0]
        gslot = jnp.minimum(st.n_groups, L_cap - 1)
        out = _chaos_outcome(chaos, u_all[gslot, 0], u_all[gslot, 1],
                             st.requeues < R, s_j[j], work, m_grp, dur,
                             dtype)
        t_gfin = st.t + out.dur
        useful_end = jnp.where(out.failed,
                               st.t + s_j[j] + out.ckpt_done, t_gfin)
        requeued = do_sched & (out.failed | out.killed)
        # stash the requeue span + credit for the finish event — see
        # simulate_packet for the deferred-walk notes
        eps = jnp.asarray(CREDIT_EPS, dtype)
        p_cnt, p_lo, p_frag = _pool_decode(st.pool_code[j], N)
        has_pool = p_cnt > 0
        qlo = jnp.where(has_pool, p_lo, st.head[j])
        res0 = jnp.where(has_pool, jnp.maximum(
            head_w - pw.tj_prefw[j, qlo] - st.pool_w[j], zero_f),
            zero_f)
        walk_ok = ~(has_pool & p_frag)
        avail = res0 + out.credit
        span_code = 1 + qlo * (N + 1) + st.tail[j]
        rem_agg = work - out.credit
        a_has = requeued & (rem_agg > eps)
        a_cnt = (st.tail[j] - st.head[j]) + p_cnt
        code = jnp.where(requeued & walk_ok, span_code,
                         jnp.where(a_has, -a_cnt, zero_i))
        stash_w = jnp.where(
            requeued & walk_ok, avail,
            jnp.where(a_has, jnp.maximum(rem_agg, zero_f), zero_f))
        stash_old = jnp.where(a_has & ~walk_ok, oldest[j], INF)
    busy_inc = m_grp.astype(dtype) * _window_overlap(
        st.t, t_gfin, t_end_metric)
    useful_inc = m_grp.astype(dtype) * _window_overlap(
        st.t + s_j[j], useful_end, t_end_metric)
    if chaos is not None:
        # same best-effort rounding contract as the while engine
        busy_inc, useful_inc = jax.lax.optimization_barrier(
            (busy_inc, useful_inc))

    # event step (submission or completion), masked unless do_event
    t_sub = jnp.where(st.next_sub < N,
                      pw.submit[jnp.minimum(st.next_sub, N - 1)], INF)
    eslot = jnp.argmin(st.grp_end)
    t_efin = st.grp_end[eslot]
    take_sub = t_sub <= t_efin
    t_new = jnp.where(take_sub, t_sub, t_efin)
    qlen = jnp.sum(st.tail - st.head).astype(dtype)
    if chaos is not None:
        qlen = qlen + jnp.sum(st.pool_code % (N + 1)).astype(dtype)
    q_inc = qlen * _window_overlap(st.t, t_new, t_end_metric)
    if chaos is not None:
        q_inc = jax.lax.optimization_barrier(q_inc)
    sub_j = pw.jtype[jnp.minimum(st.next_sub, N - 1)]

    do_submit = do_event & take_sub
    do_finish = do_event & ~take_sub

    head = st.head.at[j].set(jnp.where(do_sched, st.tail[j], st.head[j]))
    tail = st.tail.at[sub_j].add(jnp.where(do_submit, one_i, zero_i))
    m_free = (st.m_free - jnp.where(do_sched, m_grp, zero_i)
              + jnp.where(do_finish, st.grp_m[eslot], zero_i))
    grp_end = st.grp_end.at[sslot].set(
        jnp.where(do_sched, t_gfin, st.grp_end[sslot]))
    grp_end = grp_end.at[eslot].set(
        jnp.where(do_finish, INF, grp_end[eslot]))
    grp_m = st.grp_m.at[sslot].set(
        jnp.where(do_sched, m_grp, st.grp_m[sslot]))
    grp_m = grp_m.at[eslot].set(
        jnp.where(do_finish, zero_i, grp_m[eslot]))

    y = (jnp.where(do_sched, j * (N + 1) + st.tail[j], key_pad),
         jnp.where(do_sched, st.t, zero_f),
         jnp.where(do_sched, m_grp, zero_i),
         jnp.where(do_sched, head_w, zero_f))

    if chaos is None:
        chaos_upd = {}
    else:
        # formation clears the drained pool and stashes the requeue in
        # the ring; the finish event resolves the stash into its member
        # set (_resolve_remnant) and releases it back to the pool
        j_f = st.grp_jtype[eslot]
        cnt_r, rem_w_r, rem_old_r, rem_lo_r, rem_hi_r, walk_r = (
            _resolve_remnant(pw, j_f, st.grp_rem_cnt[eslot],
                             st.grp_rem_w[eslot],
                             st.grp_rem_oldest[eslot], dtype))
        old_cnt, old_lo, old_frag = _pool_decode(st.pool_code[j_f], N)
        inc = do_finish & (cnt_r > 0)
        was_empty = old_cnt == 0
        contig = rem_hi_r == st.head[j_f]
        frag = jnp.where(
            inc, old_frag | ~walk_r | ~was_empty | ~contig, old_frag)
        new_lo = jnp.where(was_empty, rem_lo_r,
                           jnp.minimum(old_lo, rem_lo_r))
        new_code = ((new_lo * 2 + frag.astype(jnp.int32))
                    * (N + 1) + old_cnt + cnt_r)
        pool_w = st.pool_w.at[j].set(
            jnp.where(do_sched, zero_f, st.pool_w[j]))
        pool_w = pool_w.at[j_f].add(
            jnp.where(do_finish, rem_w_r, zero_f))
        pool_oldest = st.pool_oldest.at[j].set(
            jnp.where(do_sched, INF, st.pool_oldest[j]))
        pool_oldest = pool_oldest.at[j_f].min(
            jnp.where(do_finish, rem_old_r, INF))
        pool_code = st.pool_code.at[j].set(
            jnp.where(do_sched, zero_i, st.pool_code[j]))
        pool_code = pool_code.at[j_f].set(
            jnp.where(inc, new_code, pool_code[j_f]))
        grp_rem_w = st.grp_rem_w.at[sslot].set(
            jnp.where(do_sched, stash_w, st.grp_rem_w[sslot]))
        grp_rem_w = grp_rem_w.at[eslot].set(
            jnp.where(do_finish, zero_f, grp_rem_w[eslot]))
        grp_rem_cnt = st.grp_rem_cnt.at[sslot].set(
            jnp.where(do_sched, code, st.grp_rem_cnt[sslot]))
        grp_rem_cnt = grp_rem_cnt.at[eslot].set(
            jnp.where(do_finish, zero_i, grp_rem_cnt[eslot]))
        grp_rem_oldest = st.grp_rem_oldest.at[sslot].set(
            jnp.where(do_sched, stash_old, st.grp_rem_oldest[sslot]))
        grp_rem_oldest = grp_rem_oldest.at[eslot].set(
            jnp.where(do_finish, INF, grp_rem_oldest[eslot]))
        chaos_upd = dict(
            pool_w=pool_w, pool_oldest=pool_oldest,
            pool_code=pool_code,
            grp_jtype=st.grp_jtype.at[sslot].set(
                jnp.where(do_sched, j, st.grp_jtype[sslot])),
            grp_rem_w=grp_rem_w, grp_rem_cnt=grp_rem_cnt,
            grp_rem_oldest=grp_rem_oldest,
            lost_work=st.lost_work + jnp.where(do_sched, out.lost,
                                               zero_f),
            failures=st.failures + jnp.where(do_sched & out.failed,
                                             one_i, zero_i),
            straggler_kills=st.straggler_kills + jnp.where(
                do_sched & out.killed & ~out.failed, one_i, zero_i),
            requeues=st.requeues + jnp.where(requeued, one_i, zero_i),
            requeued_jobs=st.requeued_jobs + jnp.where(
                do_finish, cnt_r, zero_i))

    st = st._replace(
        t=jnp.where(do_event, t_new, st.t),
        next_sub=st.next_sub + jnp.where(do_submit, one_i, zero_i),
        head=head, tail=tail, m_free=m_free,
        grp_end=grp_end, grp_m=grp_m,
        qlen_int=st.qlen_int + jnp.where(do_event, q_inc, zero_f),
        busy_ns=st.busy_ns + jnp.where(do_sched, busy_inc, zero_f),
        useful_ns=st.useful_ns + jnp.where(do_sched, useful_inc, zero_f),
        n_groups=st.n_groups + jnp.where(do_sched, one_i, zero_i),
        **chaos_upd)
    return st, y


def simulate_packet_scan(pw: PackedWorkload, k, s_init, m_nodes,
                         priority=None, t_max=None, ring: int | None = None,
                         budget: int | None = None,
                         seg: int | None = None,
                         chaos: ChaosConfig | None = None,
                         step_impl: str = "xla") -> DesResult:
    """Packet DES as a fixed-budget `lax.scan` — the batched-lane engine.

    Same policy and same per-step arithmetic as `simulate_packet`, but
    restructured for vmapping over many (k, s) lanes at once:

      * ONE flat step kind instead of an outer event loop with a nested
        scheduling `while_loop`: each step either forms one group (when the
        greedy pass is unblocked) or consumes one event, chosen branchlessly
        with masks, so vmapped lanes never pay a both-branches `lax.cond`
        or a lockstep inner loop.
      * the group log is EMITTED as scan outputs (`ys`) instead of carried
        as [N] state and scattered per step — under vmap the while engine
        drags [lanes, N] log arrays through every iteration, which is the
        dominant cost of the old fused mode on CPU.
      * a drained lane carries `active = False` and its step is a no-op
        (masked updates, pad log key), so lanes of different event counts
        can share one program.
      * the scan runs in `seg`-length segments under a `while_loop` that
        stops as soon as every lane in the dispatch has drained ("event
        budget with early exit"): the budget is the analytic worst case
        (`event_budget(N)` ~ 3N), but a dispatch of short lanes pays only
        its own steps, rounded up to a segment.

    `pw` is an ordinary operand and batches like any other: vmapping with
    ``in_axes=(0, 0, 0, None, None)`` over a stacked PackedWorkload (see
    `repro.core.cohort`) runs W same-shape workloads in one program, which
    is how `run_cohort_grid` folds the paper's whole 6-workflow study into
    two dispatched cohorts. Extra budget segments past a lane's drain point
    are masked no-ops (active=False emits pad log keys and freezes state),
    so per-lane results are independent of whatever else shares the
    dispatch — the property every equivalence test in the suite leans on.

    Results are equivalent to `simulate_packet` lane-for-lane (the
    equivalence suite pins every DesResult field); `ok` is False only if
    the budget was insufficient, which the 3N bound rules out for the
    default.

    Engine selection (`step_impl`):

      * ``"xla"`` (default, and the only engine on CPU worth running
        compiled): the per-event step scans `packet_scan_step` directly
        and lanes batch via `vmap`. This stays the default everywhere —
        zero behaviour change for existing callers.
      * ``"pallas"``: the same event arithmetic as a lane-minor Pallas
        kernel (`repro.kernels.packet_step`) with the ring state resident
        in kernel memory across the gather/scatter chain, invoked once
        per event for a whole dispatch of lanes. Wins on accelerators
        where XLA would bounce the [lanes, ring] state through HBM
        between the small fused ops of the step; on CPU it runs in
        interpret mode (discharged back into XLA), so it is a
        correctness/parity path there, not a fast path. Schedules and
        integer counters are bitwise-identical to ``"xla"`` in both
        dtypes, chaos on and off (pinned by tests/test_packet_step.py);
        float time-integrals may differ in final ulps, same as every
        cross-engine contract in this module.

    A single (k, s) pair routed through ``"pallas"`` runs as a 1-lane
    dispatch of `simulate_packet_scan_lanes`; batch callers should use
    the lanes entry point directly.
    """
    _check_step_impl(step_impl)
    if step_impl == "pallas":
        res = simulate_packet_scan_lanes(
            pw, jnp.asarray(k)[None], jnp.asarray(s_init)[None], m_nodes,
            priority=priority, t_max=t_max, ring=ring, budget=budget,
            seg=seg, chaos=chaos, step_impl="pallas")
        return jax.tree.map(lambda x: x[0], res)
    H, N = pw.n_types, pw.n_jobs
    ring = resolve_ring(m_nodes, N, ring)
    R = resolve_max_requeues(chaos, N)
    L_cap = N + R               # formation cap == uniform-stream length
    budget = event_budget(N, R) if budget is None else max(1, int(budget))
    seg = SCAN_SEG if seg is None else max(1, int(seg))
    n_segs = -(-budget // seg)
    budget = n_segs * seg               # segments tile the log exactly
    dtype = precision.canonical_dtype(pw.submit.dtype)
    k = jnp.asarray(k, dtype)
    s_init = jnp.asarray(s_init, dtype)
    m_nodes = jnp.asarray(m_nodes, jnp.int32)
    s_j = jnp.full((H,), s_init, dtype)
    p_j = jnp.ones((H,), dtype) if priority is None else jnp.asarray(priority, dtype)
    tmax_j = (jnp.full((H,), 3600.0, dtype) if t_max is None
              else jnp.asarray(t_max, dtype))

    key_pad = jnp.iinfo(jnp.int32).max
    u_all = None if chaos is None else chaos_uniforms(chaos, dtype, L_cap)

    def lane_active(st: _ScanState):
        active = ((st.next_sub < N) | jnp.any(~jnp.isinf(st.grp_end)) |
                  jnp.any(st.tail > st.head))
        if chaos is not None:
            active = active | jnp.any(st.pool_code > 0)
        return active

    def step(st: _ScanState, _):
        return packet_scan_step(pw, k, s_j, p_j, tmax_j, st,
                                r_cap=R, chaos=chaos, u_all=u_all)

    def seg_cond(carry):
        st, _, s_idx = carry
        return lane_active(st) & (s_idx < n_segs)

    def seg_body(carry):
        st, logs, s_idx = carry
        st, ys = jax.lax.scan(step, st, None, length=seg)
        off = s_idx * seg
        logs = tuple(jax.lax.dynamic_update_slice(buf, y, (off,))
                     for buf, y in zip(logs, ys))
        return st, logs, s_idx + 1

    st0 = _ScanState(
        t=jnp.zeros((), dtype), next_sub=jnp.zeros((), jnp.int32),
        head=jnp.zeros((H,), jnp.int32), tail=jnp.zeros((H,), jnp.int32),
        m_free=m_nodes, grp_end=jnp.full((ring,), INF, dtype),
        grp_m=jnp.zeros((ring,), jnp.int32),
        qlen_int=jnp.zeros((), dtype), busy_ns=jnp.zeros((), dtype),
        useful_ns=jnp.zeros((), dtype), n_groups=jnp.zeros((), jnp.int32),
        pool_w=jnp.zeros((H,), dtype),
        pool_oldest=jnp.full((H,), INF, dtype),
        pool_code=jnp.zeros((H,), jnp.int32),
        grp_jtype=jnp.zeros((ring,), jnp.int32),
        grp_rem_w=jnp.zeros((ring,), dtype),
        grp_rem_cnt=jnp.zeros((ring,), jnp.int32),
        grp_rem_oldest=jnp.full((ring,), INF, dtype),
        lost_work=jnp.zeros((), dtype), failures=jnp.zeros((), jnp.int32),
        straggler_kills=jnp.zeros((), jnp.int32),
        requeues=jnp.zeros((), jnp.int32),
        requeued_jobs=jnp.zeros((), jnp.int32))
    logs0 = (jnp.full((budget,), key_pad, jnp.int32),
             jnp.zeros((budget,), dtype),
             jnp.zeros((budget,), jnp.int32),
             jnp.zeros((budget,), dtype))

    st, logs, _ = jax.lax.while_loop(
        seg_cond, seg_body, (st0, logs0, jnp.zeros((), jnp.int32)))
    log_key, log_t, log_m, log_headw = logs
    start_t, run_start_t = _reconstruct_job_times(
        pw, log_key, log_t, log_m, log_headw, s_j)
    drained = (st.next_sub >= N) & jnp.all(jnp.isinf(st.grp_end)) & \
        jnp.all(st.head == st.tail)
    if chaos is not None:
        drained = drained & jnp.all(st.pool_code == 0)
    ok = drained & jnp.all(jnp.isfinite(start_t))
    return DesResult(start_t=start_t, run_start_t=run_start_t,
                     qlen_int=st.qlen_int, busy_ns=st.busy_ns,
                     useful_ns=st.useful_ns, n_groups=st.n_groups,
                     makespan=st.t, ok=ok, budget_exhausted=~drained,
                     lost_work=st.lost_work, failures=st.failures,
                     straggler_kills=st.straggler_kills,
                     requeues=st.requeues, requeued_jobs=st.requeued_jobs)


def _lane_cols_to_rows(cols: _ScanState) -> _ScanState:
    """Kernel layout [state, T] -> lane-major [T, state] for assembly."""
    return _ScanState(
        t=cols.t[0], next_sub=cols.next_sub[0],
        head=cols.head.T, tail=cols.tail.T, m_free=cols.m_free[0],
        grp_end=cols.grp_end.T, grp_m=cols.grp_m.T,
        qlen_int=cols.qlen_int[0], busy_ns=cols.busy_ns[0],
        useful_ns=cols.useful_ns[0], n_groups=cols.n_groups[0],
        pool_w=cols.pool_w.T, pool_oldest=cols.pool_oldest.T,
        pool_code=cols.pool_code.T, grp_jtype=cols.grp_jtype.T,
        grp_rem_w=cols.grp_rem_w.T, grp_rem_cnt=cols.grp_rem_cnt.T,
        grp_rem_oldest=cols.grp_rem_oldest.T,
        lost_work=cols.lost_work[0], failures=cols.failures[0],
        straggler_kills=cols.straggler_kills[0], requeues=cols.requeues[0],
        requeued_jobs=cols.requeued_jobs[0])


def simulate_packet_scan_lanes(pw: PackedWorkload, k, s_init, m_nodes,
                               priority=None, t_max=None,
                               ring: int | None = None,
                               budget: int | None = None,
                               seg: int | None = None,
                               chaos: ChaosConfig | None = None,
                               step_impl: str = "xla") -> DesResult:
    """A whole dispatch of (k, s) lanes through one scan engine.

    `k` and `s_init` are [T] lane arrays; `chaos` (optional) carries
    scalar or [T] leaves (broadcast here). Returns a DesResult whose
    every field has a leading lane axis — the same contract as vmapping
    `simulate_packet_scan`, which is exactly what ``step_impl="xla"``
    does.

    ``step_impl="pallas"`` instead keeps the lanes TOGETHER in one
    kernel invocation per event: state lives as [state, T] columns with
    lanes on the minor axis, and each scan step calls the fused
    `repro.kernels.packet_step` kernel, which advances every lane one
    event with the ring state resident in kernel memory (VMEM on TPU;
    interpret mode discharges it back into XLA on CPU). The event
    arithmetic is `packet_scan_step` vectorized over the lane axis —
    all per-lane reductions are argmax/argmin/any over the state axis
    and every float op is elementwise, so schedules and integer
    counters are bitwise-identical to the XLA path. Extra budget
    segments past a lane's drain point remain masked no-ops, so a
    lane's result is independent of its dispatch companions (the
    segmented early exit stops only when ALL lanes have drained).

    Call under `jax.jit` — the pallas path issues one kernel call per
    scan step and is built to be traced, not run op-by-op.
    """
    _check_step_impl(step_impl)
    k = jnp.atleast_1d(k)
    s_init = jnp.atleast_1d(s_init)
    T = k.shape[0]
    if step_impl == "xla":
        run = partial(simulate_packet_scan, pw,
                      m_nodes=m_nodes, priority=priority,
                      t_max=t_max, ring=ring, budget=budget,
                      seg=seg)
        if chaos is None:
            return jax.vmap(lambda kk, ss: run(k=kk, s_init=ss))(k, s_init)
        chaos_b = jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.asarray(x), (T,)), chaos)
        return jax.vmap(
            lambda kk, ss, ch: run(k=kk, s_init=ss, chaos=ch))(
                k, s_init, chaos_b)

    from repro.kernels.packet_step import ops as _step_ops  # lazy: cycle

    H, N = pw.n_types, pw.n_jobs
    ring = resolve_ring(m_nodes, N, ring)
    R = resolve_max_requeues(chaos, N)
    L_cap = N + R
    budget = event_budget(N, R) if budget is None else max(1, int(budget))
    seg = SCAN_SEG if seg is None else max(1, int(seg))
    n_segs = -(-budget // seg)
    budget = n_segs * seg
    dtype = precision.canonical_dtype(pw.submit.dtype)
    k = jnp.asarray(k, dtype)
    s = jnp.asarray(s_init, dtype)
    m_nodes = jnp.asarray(m_nodes, jnp.int32)
    p_j = (jnp.ones((H,), dtype) if priority is None
           else jnp.asarray(priority, dtype))
    tmax_j = (jnp.full((H,), 3600.0, dtype) if t_max is None
              else jnp.asarray(t_max, dtype))
    key_pad = jnp.iinfo(jnp.int32).max

    if chaos is None:
        u1 = u2 = chaos_params = None
    else:
        chaos_b = jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.asarray(x), (T,)), chaos)
        u = jax.vmap(
            lambda c: chaos_uniforms(c, dtype, L_cap))(chaos_b)
        u1 = jnp.transpose(u[:, :, 0])          # [L_cap, T]
        u2 = jnp.transpose(u[:, :, 1])
        chaos_params = tuple(
            jnp.broadcast_to(jnp.asarray(x, dtype), (1, T))
            for x in (chaos.mtbf_chip_hours, chaos.ckpt_period,
                      chaos.straggler_prob, chaos.straggler_factor,
                      chaos.straggler_deadline))

    k_col = k[None, :]
    s_col = s[None, :]
    t_last = jnp.reshape(pw.t_last_submit, (1, 1))

    def lane_act(cols: _ScanState):
        act = ((cols.next_sub[0] < N) |
               jnp.any(~jnp.isinf(cols.grp_end), axis=0) |
               jnp.any(cols.tail > cols.head, axis=0))
        if chaos is not None:
            act = act | jnp.any(cols.pool_code > 0, axis=0)
        return act

    def step(cols: _ScanState, _):
        return _step_ops.fused_packet_step(
            pw.tj_prefw, pw.tj_submit, pw.submit, pw.jtype,
            k_col, s_col, p_j, tmax_j, t_last, cols,
            u1=u1, u2=u2, chaos_params=chaos_params, r_cap=R)

    def seg_cond(carry):
        cols, _, s_idx = carry
        return jnp.any(lane_act(cols)) & (s_idx < n_segs)

    def seg_body(carry):
        cols, logs, s_idx = carry
        cols, ys = jax.lax.scan(step, cols, None, length=seg)
        off = s_idx * seg
        logs = tuple(
            jax.lax.dynamic_update_slice(buf, y[:, 0, :],
                                         (off, jnp.zeros_like(off)))
            for buf, y in zip(logs, ys))
        return cols, logs, s_idx + 1

    cols0 = _ScanState(
        t=jnp.zeros((1, T), dtype),
        next_sub=jnp.zeros((1, T), jnp.int32),
        head=jnp.zeros((H, T), jnp.int32),
        tail=jnp.zeros((H, T), jnp.int32),
        m_free=jnp.full((1, T), m_nodes, jnp.int32),
        grp_end=jnp.full((ring, T), INF, dtype),
        grp_m=jnp.zeros((ring, T), jnp.int32),
        qlen_int=jnp.zeros((1, T), dtype),
        busy_ns=jnp.zeros((1, T), dtype),
        useful_ns=jnp.zeros((1, T), dtype),
        n_groups=jnp.zeros((1, T), jnp.int32),
        pool_w=jnp.zeros((H, T), dtype),
        pool_oldest=jnp.full((H, T), INF, dtype),
        pool_code=jnp.zeros((H, T), jnp.int32),
        grp_jtype=jnp.zeros((ring, T), jnp.int32),
        grp_rem_w=jnp.zeros((ring, T), dtype),
        grp_rem_cnt=jnp.zeros((ring, T), jnp.int32),
        grp_rem_oldest=jnp.full((ring, T), INF, dtype),
        lost_work=jnp.zeros((1, T), dtype),
        failures=jnp.zeros((1, T), jnp.int32),
        straggler_kills=jnp.zeros((1, T), jnp.int32),
        requeues=jnp.zeros((1, T), jnp.int32),
        requeued_jobs=jnp.zeros((1, T), jnp.int32))
    logs0 = (jnp.full((budget, T), key_pad, jnp.int32),
             jnp.zeros((budget, T), dtype),
             jnp.zeros((budget, T), jnp.int32),
             jnp.zeros((budget, T), dtype))

    cols, logs, _ = jax.lax.while_loop(
        seg_cond, seg_body, (cols0, logs0, jnp.zeros((), jnp.int32)))
    logs_lane = tuple(jnp.swapaxes(buf, 0, 1) for buf in logs)
    st_lane = _lane_cols_to_rows(cols)

    def assemble(lane_logs, st: _ScanState, s_lane):
        s_row = jnp.full((H,), s_lane, dtype)
        start_t, run_start_t = _reconstruct_job_times(pw, *lane_logs, s_row)
        drained = ((st.next_sub >= N) & jnp.all(jnp.isinf(st.grp_end)) &
                   jnp.all(st.head == st.tail))
        if chaos is not None:
            drained = drained & jnp.all(st.pool_code == 0)
        ok = drained & jnp.all(jnp.isfinite(start_t))
        return DesResult(start_t=start_t, run_start_t=run_start_t,
                         qlen_int=st.qlen_int, busy_ns=st.busy_ns,
                         useful_ns=st.useful_ns, n_groups=st.n_groups,
                         makespan=st.t, ok=ok, budget_exhausted=~drained,
                         lost_work=st.lost_work, failures=st.failures,
                         straggler_kills=st.straggler_kills,
                         requeues=st.requeues,
                         requeued_jobs=st.requeued_jobs)

    return jax.vmap(assemble)(logs_lane, st_lane, s)


# --------------------------------------------------------------------------
# Reference implementation: the original O(N)-masked-writes event body.
# Retained verbatim (fixed RING ring, eager per-job writes) as the oracle
# for the equivalence test suite and the baseline for benchmarks/bench_des.
# --------------------------------------------------------------------------

class _RefState(NamedTuple):
    t: jnp.ndarray
    next_sub: jnp.ndarray
    head: jnp.ndarray
    tail: jnp.ndarray
    m_free: jnp.ndarray
    grp_end: jnp.ndarray
    grp_m: jnp.ndarray
    start_t: jnp.ndarray      # [N] written eagerly per group — O(N)/event
    run_start_t: jnp.ndarray  # [N]
    qlen_int: jnp.ndarray
    busy_ns: jnp.ndarray
    useful_ns: jnp.ndarray
    n_groups: jnp.ndarray
    iters: jnp.ndarray


def simulate_packet_reference(pw: PackedWorkload, k, s_init, m_nodes,
                              priority=None, t_max=None,
                              max_iters: int | None = None) -> DesResult:
    """Seed-equivalent Packet DES with per-event O(N) metric writes."""
    H, N = pw.n_types, pw.n_jobs
    dtype = precision.canonical_dtype(pw.submit.dtype)
    k = jnp.asarray(k, dtype)
    s_init = jnp.asarray(s_init, dtype)
    m_nodes = jnp.asarray(m_nodes, jnp.int32)
    s_j = jnp.full((H,), s_init, dtype)
    p_j = jnp.ones((H,), dtype) if priority is None else jnp.asarray(priority, dtype)
    tmax_j = (jnp.full((H,), 3600.0, dtype) if t_max is None
              else jnp.asarray(t_max, dtype))
    if max_iters is None:
        max_iters = 4 * N + 64

    t_end_metric = pw.t_last_submit
    type_ids = jnp.arange(H)

    def sched_cond(st):
        nonempty = st.tail > st.head
        free_slot = jnp.any(jnp.isinf(st.grp_end))
        return (st.m_free > 0) & jnp.any(nonempty) & free_slot

    def sched_body(st: _RefState) -> _RefState:
        nonempty = st.tail > st.head
        sum_w = (pw.tj_prefw[type_ids, st.tail] -
                 pw.tj_prefw[type_ids, st.head])
        oldest = pw.tj_submit[type_ids, jnp.minimum(st.head, N - 1)]
        w = packet.queue_weights(sum_w, s_j, p_j, oldest, st.t, tmax_j, nonempty)
        j = jnp.argmax(w)
        work = sum_w[j]
        m_grp = packet.group_nodes(work, k, s_j[j], st.m_free)
        dur = packet.group_duration(work, s_j[j], m_grp)
        slot = jnp.argmax(jnp.isinf(st.grp_end))
        t_fin = st.t + dur

        in_grp = ((pw.jtype == j) & (pw.rank >= st.head[j]) &
                  (pw.rank < st.tail[j]))
        start_t = jnp.where(in_grp, st.t, st.start_t)
        head_w = pw.tj_prefw[j, st.head[j]]
        run_start = st.t + s_j[j] + (pw.cumw - head_w) / m_grp.astype(dtype)
        run_start_t = jnp.where(in_grp, run_start, st.run_start_t)

        busy = st.busy_ns + m_grp.astype(dtype) * _window_overlap(
            st.t, t_fin, t_end_metric)
        useful = st.useful_ns + m_grp.astype(dtype) * _window_overlap(
            st.t + s_j[j], t_fin, t_end_metric)

        return st._replace(
            head=st.head.at[j].set(st.tail[j]),
            m_free=st.m_free - m_grp,
            grp_end=st.grp_end.at[slot].set(t_fin),
            grp_m=st.grp_m.at[slot].set(m_grp),
            start_t=start_t, run_start_t=run_start_t,
            busy_ns=busy, useful_ns=useful,
            n_groups=st.n_groups + 1)

    def cond(st: _RefState):
        more = (st.next_sub < N) | jnp.any(~jnp.isinf(st.grp_end))
        return more & (st.iters < max_iters)

    def body(st: _RefState) -> _RefState:
        t_sub = jnp.where(st.next_sub < N,
                          pw.submit[jnp.minimum(st.next_sub, N - 1)], INF)
        slot = jnp.argmin(st.grp_end)
        t_fin = st.grp_end[slot]
        take_sub = t_sub <= t_fin
        t_new = jnp.where(take_sub, t_sub, t_fin)

        qlen = jnp.sum(st.tail - st.head).astype(st.t.dtype)
        qint = st.qlen_int + qlen * _window_overlap(st.t, t_new, t_end_metric)

        def on_submit(st):
            j = pw.jtype[jnp.minimum(st.next_sub, N - 1)]
            return st._replace(next_sub=st.next_sub + 1,
                               tail=st.tail.at[j].add(1))

        def on_finish(st):
            return st._replace(m_free=st.m_free + st.grp_m[slot],
                               grp_end=st.grp_end.at[slot].set(INF),
                               grp_m=st.grp_m.at[slot].set(0))

        st = st._replace(t=t_new, qlen_int=qint)
        st = jax.lax.cond(take_sub, on_submit, on_finish, st)
        st = jax.lax.while_loop(sched_cond, sched_body, st)
        return st._replace(iters=st.iters + 1)

    st0 = _RefState(
        t=jnp.zeros((), dtype), next_sub=jnp.zeros((), jnp.int32),
        head=jnp.zeros((H,), jnp.int32), tail=jnp.zeros((H,), jnp.int32),
        m_free=m_nodes, grp_end=jnp.full((RING,), INF, dtype),
        grp_m=jnp.zeros((RING,), jnp.int32),
        start_t=jnp.full((N,), INF, dtype), run_start_t=jnp.full((N,), INF, dtype),
        qlen_int=jnp.zeros((), dtype), busy_ns=jnp.zeros((), dtype),
        useful_ns=jnp.zeros((), dtype), n_groups=jnp.zeros((), jnp.int32),
        iters=jnp.zeros((), jnp.int32))

    st = jax.lax.while_loop(cond, body, st0)
    drained = (st.next_sub >= N) & jnp.all(jnp.isinf(st.grp_end)) & \
        jnp.all(st.head == st.tail)
    ok = drained & jnp.all(jnp.isfinite(st.start_t))
    zf = jnp.zeros((), dtype)
    zi = jnp.zeros((), jnp.int32)
    return DesResult(start_t=st.start_t, run_start_t=st.run_start_t,
                     qlen_int=st.qlen_int, busy_ns=st.busy_ns,
                     useful_ns=st.useful_ns, n_groups=st.n_groups,
                     makespan=st.t, ok=ok, budget_exhausted=~drained,
                     lost_work=zf, failures=zi, straggler_kills=zi,
                     requeues=zi, requeued_jobs=zi)


@partial(jax.jit, static_argnames=("max_iters", "ring"))
def _simulate_packet_jit(pw, k, s_init, m_nodes, max_iters=None, ring=None):
    return simulate_packet(pw, k, s_init, m_nodes, max_iters=max_iters,
                           ring=ring)


def simulate_packet_host(wl: Workload, k: float, s_prop: float,
                         dtype=jnp.float32) -> DesResult:
    """Convenience host entry point: workload + scale ratio + init proportion.

    Passing ``dtype=jnp.float64`` is the float64 opt-in: the whole
    pack-simulate pipeline runs inside a `precision.dtype_scope`, so the
    session's global x64 state is untouched.
    """
    with precision.dtype_scope(dtype):
        pw = pack_workload(wl, dtype)
        s = wl.init_time_for_proportion(s_prop)
        return jax.tree.map(np.asarray, simulate_packet(
            pw, k, s, wl.params.nodes))
