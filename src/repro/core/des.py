"""Fixed-shape discrete-event simulator of the Packet algorithm (paper §5-6).

This is the JAX/TPU-native replacement for the paper's Alea-based JMS model:
one `lax.while_loop` program with a small, fixed set of state arrays, jit-able
and `vmap`-able over the experiment grid (scale ratio x init proportion), so
the paper's 1332-experiment study runs as a handful of batched XLA programs
instead of 1332 sequential Java simulations.

Why it vectorizes: the Packet algorithm always drains the *entire* selected
queue into one group (paper Step 3), so each per-type queue is a contiguous
window [head_j, tail_j) over that type's jobs in submit order. Queue
aggregates are O(1) reads of precomputed per-type prefix sums, and nodes are
fungible counts (moldable linear-speedup groups on a homogeneous cluster), so
the whole simulator state is ~a dozen small arrays.

Events: (a) job submission, (b) group completion (nodes released). On every
event the greedy scheduling pass (paper Steps 1-5) runs until it is blocked.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packet
from repro.workload.lublin import Workload

INF = jnp.inf
RING = 512           # max concurrent groups; >= max nodes used in the paper


@dataclasses.dataclass(frozen=True)
class PackedWorkload:
    """Device-resident, per-type-indexed form of a Workload.

    H = n_types, N = n_jobs. Per-type tables are rank-indexed (rank r =
    r-th job of that type in submit order), padded with +inf / 0.
    """
    submit: jnp.ndarray      # [N]  global submit order
    work: jnp.ndarray        # [N]  w_i = e_i * n_i
    jtype: jnp.ndarray       # [N]
    rank: jnp.ndarray        # [N]  rank of job i within its type
    cumw: jnp.ndarray        # [N]  per-type prefix work *before* job i
    nodes: jnp.ndarray       # [N]  rigid node request (baselines only)
    runtime: jnp.ndarray     # [N]  e_i on n_i nodes (baselines only)
    tj_submit: jnp.ndarray   # [H, N]   submit of type j's rank-r job (+inf pad)
    tj_prefw: jnp.ndarray    # [H, N+1] prefix sums of work per type
    t_last_submit: jnp.ndarray  # scalar: metric window end (paper §3)
    n_types: int
    n_jobs: int


def _pw_flatten(pw: PackedWorkload):
    children = (pw.submit, pw.work, pw.jtype, pw.rank, pw.cumw, pw.nodes,
                pw.runtime, pw.tj_submit, pw.tj_prefw, pw.t_last_submit)
    return children, (pw.n_types, pw.n_jobs)


def _pw_unflatten(aux, children):
    return PackedWorkload(*children, n_types=aux[0], n_jobs=aux[1])


jax.tree_util.register_pytree_node(PackedWorkload, _pw_flatten, _pw_unflatten)


def pack_workload(wl: Workload, dtype=jnp.float32) -> PackedWorkload:
    H, N = wl.params.n_types, wl.n_jobs
    rank = np.zeros(N, np.int32)
    cumw = np.zeros(N, np.float64)
    tj_submit = np.full((H, N), np.inf)
    tj_prefw = np.zeros((H, N + 1), np.float64)
    counts = np.zeros(H, np.int64)
    acc = np.zeros(H, np.float64)
    for i in range(N):
        j = wl.jtype[i]
        r = counts[j]
        rank[i] = r
        cumw[i] = acc[j]
        tj_submit[j, r] = wl.submit[i]
        acc[j] += wl.work[i]
        tj_prefw[j, r + 1] = acc[j]
        counts[j] += 1
    # extend prefix sums into the padding so prefw[tail] is always valid
    for j in range(H):
        tj_prefw[j, counts[j] + 1:] = acc[j]
    f = lambda a: jnp.asarray(a, dtype)
    return PackedWorkload(
        submit=f(wl.submit), work=f(wl.work), jtype=jnp.asarray(wl.jtype, jnp.int32),
        rank=jnp.asarray(rank), cumw=f(cumw), nodes=jnp.asarray(wl.nodes, jnp.int32),
        runtime=f(wl.runtime), tj_submit=f(tj_submit), tj_prefw=f(tj_prefw),
        t_last_submit=f(wl.submit[-1]), n_types=H, n_jobs=N)


class DesState(NamedTuple):
    t: jnp.ndarray            # current time
    next_sub: jnp.ndarray     # index of next submission (global order)
    head: jnp.ndarray         # [H] per-type queue window start (rank)
    tail: jnp.ndarray         # [H] per-type queue window end (rank)
    m_free: jnp.ndarray       # free nodes
    grp_end: jnp.ndarray      # [RING] completion time of running groups (+inf = free)
    grp_m: jnp.ndarray        # [RING] nodes held
    start_t: jnp.ndarray      # [N] group-start time per job (queue-time metric)
    run_start_t: jnp.ndarray  # [N] job's own run start within its group
    qlen_int: jnp.ndarray     # integral of queue length over [0, t_last_submit]
    busy_ns: jnp.ndarray      # busy node-seconds within the metric window
    useful_ns: jnp.ndarray    # useful node-seconds within the metric window
    n_groups: jnp.ndarray     # diagnostic: groups formed
    iters: jnp.ndarray        # diagnostic: outer loop iterations


class DesResult(NamedTuple):
    start_t: jnp.ndarray
    run_start_t: jnp.ndarray
    qlen_int: jnp.ndarray
    busy_ns: jnp.ndarray
    useful_ns: jnp.ndarray
    n_groups: jnp.ndarray
    makespan: jnp.ndarray
    ok: jnp.ndarray           # simulation drained within the iteration cap


def _window_overlap(a, b, t_end):
    """Length of [a, b] clipped to the metric window [0, t_end]."""
    return jnp.maximum(jnp.minimum(b, t_end) - jnp.minimum(a, t_end), 0.0)


def simulate_packet(pw: PackedWorkload, k, s_init, m_nodes,
                    priority=None, t_max=None, max_iters: int | None = None
                    ) -> DesResult:
    """Run the Packet algorithm DES.

    Args:
      pw:      PackedWorkload (static shapes; close over for jit).
      k:       scale ratio (traced scalar — vmap axis of the sweep).
      s_init:  constant initialization time (traced scalar; per paper §6 the
               init time is one constant per experiment). Per-type init is
               s_j = s_init for all j.
      m_nodes: cluster size M (traced scalar int).
      priority, t_max: optional [H] job-type priorities / wait normalizers.
    """
    H, N = pw.n_types, pw.n_jobs
    dtype = pw.submit.dtype
    k = jnp.asarray(k, dtype)
    s_init = jnp.asarray(s_init, dtype)
    m_nodes = jnp.asarray(m_nodes, jnp.int32)
    s_j = jnp.full((H,), s_init, dtype)
    p_j = jnp.ones((H,), dtype) if priority is None else jnp.asarray(priority, dtype)
    tmax_j = (jnp.full((H,), 3600.0, dtype) if t_max is None
              else jnp.asarray(t_max, dtype))
    if max_iters is None:
        max_iters = 4 * N + 64

    t_end_metric = pw.t_last_submit
    type_ids = jnp.arange(H)

    def sched_cond(carry):
        st = carry
        nonempty = st.tail > st.head
        free_slot = jnp.any(jnp.isinf(st.grp_end))
        return (st.m_free > 0) & jnp.any(nonempty) & free_slot

    def sched_body(st: DesState) -> DesState:
        nonempty = st.tail > st.head
        sum_w = (pw.tj_prefw[type_ids, st.tail] -
                 pw.tj_prefw[type_ids, st.head])
        oldest = pw.tj_submit[type_ids, jnp.minimum(st.head, N - 1)]
        w = packet.queue_weights(sum_w, s_j, p_j, oldest, st.t, tmax_j, nonempty)
        j = jnp.argmax(w)                                     # Step 2
        work = sum_w[j]
        m_grp = packet.group_nodes(work, k, s_j[j], st.m_free)  # Step 4
        dur = packet.group_duration(work, s_j[j], m_grp)
        slot = jnp.argmax(jnp.isinf(st.grp_end))
        t_fin = st.t + dur

        # per-job metric writes for every job in the drained queue window
        in_grp = ((pw.jtype == j) & (pw.rank >= st.head[j]) &
                  (pw.rank < st.tail[j]))
        start_t = jnp.where(in_grp, st.t, st.start_t)
        head_w = pw.tj_prefw[j, st.head[j]]
        run_start = st.t + s_j[j] + (pw.cumw - head_w) / m_grp.astype(dtype)
        run_start_t = jnp.where(in_grp, run_start, st.run_start_t)

        busy = st.busy_ns + m_grp.astype(dtype) * _window_overlap(
            st.t, t_fin, t_end_metric)
        useful = st.useful_ns + m_grp.astype(dtype) * _window_overlap(
            st.t + s_j[j], t_fin, t_end_metric)

        return st._replace(
            head=st.head.at[j].set(st.tail[j]),               # Step 3: drain all
            m_free=st.m_free - m_grp,
            grp_end=st.grp_end.at[slot].set(t_fin),
            grp_m=st.grp_m.at[slot].set(m_grp),
            start_t=start_t, run_start_t=run_start_t,
            busy_ns=busy, useful_ns=useful,
            n_groups=st.n_groups + 1)

    def cond(st: DesState):
        more = (st.next_sub < N) | jnp.any(~jnp.isinf(st.grp_end))
        return more & (st.iters < max_iters)

    def body(st: DesState) -> DesState:
        t_sub = jnp.where(st.next_sub < N,
                          pw.submit[jnp.minimum(st.next_sub, N - 1)], INF)
        slot = jnp.argmin(st.grp_end)
        t_fin = st.grp_end[slot]
        take_sub = t_sub <= t_fin
        t_new = jnp.where(take_sub, t_sub, t_fin)

        # queue-length integral over the elapsed interval (clipped to window)
        qlen = jnp.sum(st.tail - st.head).astype(st.t.dtype)
        qint = st.qlen_int + qlen * _window_overlap(st.t, t_new, t_end_metric)

        def on_submit(st):
            j = pw.jtype[jnp.minimum(st.next_sub, N - 1)]
            return st._replace(next_sub=st.next_sub + 1,
                               tail=st.tail.at[j].add(1))

        def on_finish(st):
            return st._replace(m_free=st.m_free + st.grp_m[slot],
                               grp_end=st.grp_end.at[slot].set(INF),
                               grp_m=st.grp_m.at[slot].set(0))

        st = st._replace(t=t_new, qlen_int=qint)
        st = jax.lax.cond(take_sub, on_submit, on_finish, st)
        st = jax.lax.while_loop(sched_cond, sched_body, st)   # Steps 1-5
        return st._replace(iters=st.iters + 1)

    st0 = DesState(
        t=jnp.zeros((), dtype), next_sub=jnp.zeros((), jnp.int32),
        head=jnp.zeros((H,), jnp.int32), tail=jnp.zeros((H,), jnp.int32),
        m_free=m_nodes, grp_end=jnp.full((RING,), INF, dtype),
        grp_m=jnp.zeros((RING,), jnp.int32),
        start_t=jnp.full((N,), INF, dtype), run_start_t=jnp.full((N,), INF, dtype),
        qlen_int=jnp.zeros((), dtype), busy_ns=jnp.zeros((), dtype),
        useful_ns=jnp.zeros((), dtype), n_groups=jnp.zeros((), jnp.int32),
        iters=jnp.zeros((), jnp.int32))

    st = jax.lax.while_loop(cond, body, st0)
    ok = (st.next_sub >= N) & jnp.all(jnp.isinf(st.grp_end)) & \
        jnp.all(st.head == st.tail) & jnp.all(jnp.isfinite(st.start_t))
    return DesResult(start_t=st.start_t, run_start_t=st.run_start_t,
                     qlen_int=st.qlen_int, busy_ns=st.busy_ns,
                     useful_ns=st.useful_ns, n_groups=st.n_groups,
                     makespan=st.t, ok=ok)


@partial(jax.jit, static_argnames=("max_iters",))
def _simulate_packet_jit(pw, k, s_init, m_nodes, max_iters=None):
    return simulate_packet(pw, k, s_init, m_nodes, max_iters=max_iters)


def simulate_packet_host(wl: Workload, k: float, s_prop: float,
                         dtype=jnp.float32) -> DesResult:
    """Convenience host entry point: workload + scale ratio + init proportion."""
    pw = pack_workload(wl, dtype)
    s = wl.init_time_for_proportion(s_prop)
    return jax.tree.map(np.asarray, simulate_packet(
        pw, k, s, wl.params.nodes))
