"""Fixed-shape discrete-event simulator of the Packet algorithm (paper §5-6).

This is the JAX/TPU-native replacement for the paper's Alea-based JMS model:
one `lax.while_loop` program with a small, fixed set of state arrays, jit-able
and `vmap`-able over the experiment grid (scale ratio x init proportion), so
the paper's 1332-experiment study runs as a handful of batched XLA programs
instead of 1332 sequential Java simulations.

Why it vectorizes: the Packet algorithm always drains the *entire* selected
queue into one group (paper Step 3), so each per-type queue is a contiguous
window [head_j, tail_j) over that type's jobs in submit order. Queue
aggregates are O(1) reads of precomputed per-type prefix sums, and nodes are
fungible counts (moldable linear-speedup groups on a homogeneous cluster), so
the whole simulator state is ~a dozen small arrays.

Events: (a) job submission, (b) group completion (nodes released). On every
event the greedy scheduling pass (paper Steps 1-5) runs until it is blocked.

Complexity
----------
The event loop runs O(N) events and forms G <= N groups. The original
("reference") implementation wrote per-job metrics eagerly: every group
formation built an `in_grp` mask over all N jobs and did two masked [N]
writes, so the whole simulation cost O(G * N) — dominated by metric
bookkeeping, not scheduling.

The production path (`simulate_packet`) instead keeps a bounded *group log*:
forming a group appends one O(1) record

    key = jtype * (N + 1) + tail_rank,  (t_start, m_grp, head_prefix_work)

to a flat log of capacity N (every group drains >= 1 job, so G <= N). Inside
a type, group tails are strictly increasing and partition [0, count_j), so a
job of type j and rank r belongs to the type-j group with the smallest
tail > r. One post-loop `argsort` of the log keys plus one vectorized
`searchsorted` of each job's `jtype * (N + 1) + rank` recovers every job's
group — and with it `start_t` and `run_start_t` — in O(N log N) total.

Per-event work is therefore O(H + RING) (queue weights over H types plus the
running-group ring), and the whole simulation is O(N * (H + RING) + N log N)
instead of O(N * G). The ring itself is sized `min(M, N)` (every running
group holds >= 1 node, so at most M run concurrently) rather than a fixed
512, which cuts the loop-carried state ~5x for the paper's homogeneous
M = 100 flows; see `resolve_ring`.

Two equivalent engines expose that loop:

  * `simulate_packet` — `lax.while_loop` with a nested scheduling loop and
    the group log carried as [N] state. Fastest for ONE experiment (exact
    early exit per event); this is the sweep's mode="seq" path.
  * `simulate_packet_scan` — a branchless single-step-kind `lax.scan` over
    a precomputed event budget (~3N, segmented early exit) that EMITS log
    records as scan outputs instead of scattering into [N] carry. This is
    the vmap-friendly form: batched lanes cost about the same per
    experiment as sequential dispatch (the vmapped while engine lost ~16x
    on CPU dragging [lanes, N] log state through lockstep iterations); the
    sweep's chunked/fused modes build on it. See repro.core.sweep.

    The PackedWorkload is an *operand*, never a closure, and every one of
    its array leaves (including the scalar `t_last_submit`) is safe to
    batch: ``jax.vmap(simulate_packet_scan, in_axes=(0, 0, 0, None, None))``
    over a `repro.core.cohort.stack_workloads`-stacked pytree runs W
    same-static workloads in one program — the cohort layer of the sweep
    (`run_cohort_grid`) nests exactly that over the per-lane vmap. Only the
    aux statics (n_types, n_jobs) must agree across the batch; `cohort_key`
    groups workloads so they do.

Precision
---------
The simulation dtype is set at `pack_workload(..., dtype=...)` and carried
by every time/accumulator array; float64 requires the scoped opt-in in
`repro.core.precision` (never a global flag flip). Measured against the
float64 reference over the full 37 x 6 paper grid
(benchmarks/results/BENCH_dtype.json, 5000-job flows):

  * homogeneous flows and FCFS stay at rounding level in float32 (max
    same-schedule relative deviation ~7e-3 on waits, ~1e-6 .. 2e-6 on
    utilizations and FCFS metrics), with <= 3 decision flips per 222 cells;
  * heterogeneous 5000-job flows are float32-CHAOTIC: 77-83% of grid cells
    resolve a near-tie in queue weights or event order differently and the
    schedule diverges wholesale (up to ~650% on per-cell avg_wait; EASY
    backfill flips too, up to ~25%). Per-cell metric work on long-horizon
    heterogeneous workloads should use the float64 opt-in.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packet, precision
from repro.workload.lublin import Workload

INF = jnp.inf
RING = 512           # static fallback ring size (used when M is traced)


def resolve_ring(m_nodes, n_jobs: int, ring: int | None = None) -> int:
    """Ring size for the running-group buffer.

    Every running group (or rigid job) holds at least one node, so at most
    `min(M, N)` can run concurrently. When `m_nodes` is a concrete Python or
    NumPy scalar we size the ring exactly; under tracing (e.g. M itself is a
    vmap axis) we fall back to the static `RING` cap.
    """
    if ring is not None:
        return max(1, int(ring))
    try:
        m = int(m_nodes)
    except Exception:       # traced value — no concrete M at trace time
        return max(1, min(RING, n_jobs)) if n_jobs else 1
    return max(1, min(m, n_jobs if n_jobs else m))


@dataclasses.dataclass(frozen=True)
class PackedWorkload:
    """Device-resident, per-type-indexed form of a Workload.

    H = n_types, N = n_jobs. Per-type tables are rank-indexed (rank r =
    r-th job of that type in submit order), padded with +inf / 0.
    """
    submit: jnp.ndarray      # [N]  global submit order
    work: jnp.ndarray        # [N]  w_i = e_i * n_i
    jtype: jnp.ndarray       # [N]
    rank: jnp.ndarray        # [N]  rank of job i within its type
    cumw: jnp.ndarray        # [N]  per-type prefix work *before* job i
    nodes: jnp.ndarray       # [N]  rigid node request (baselines only)
    runtime: jnp.ndarray     # [N]  e_i on n_i nodes (baselines only)
    tj_submit: jnp.ndarray   # [H, N]   submit of type j's rank-r job (+inf pad)
    tj_prefw: jnp.ndarray    # [H, N+1] prefix sums of work per type
    t_last_submit: jnp.ndarray  # scalar: metric window end (paper §3)
    n_types: int
    n_jobs: int


def _pw_flatten(pw: PackedWorkload):
    children = (pw.submit, pw.work, pw.jtype, pw.rank, pw.cumw, pw.nodes,
                pw.runtime, pw.tj_submit, pw.tj_prefw, pw.t_last_submit)
    return children, (pw.n_types, pw.n_jobs)


def _pw_unflatten(aux, children):
    return PackedWorkload(*children, n_types=aux[0], n_jobs=aux[1])


jax.tree_util.register_pytree_node(PackedWorkload, _pw_flatten, _pw_unflatten)


def pack_workload(wl: Workload, dtype=jnp.float32) -> PackedWorkload:
    """Build the per-type-indexed tables with numpy segment prefix sums.

    A stable sort by type turns each type into one contiguous segment, so
    per-type ranks and prefix work are plain offset arithmetic on one global
    cumsum — no Python loop over jobs.

    `dtype` selects the simulation precision for every float table and, via
    the packed arrays, every downstream accumulator. float64 requires the
    explicit x64 opt-in (`repro.core.precision.dtype_scope`); requesting it
    outside a scope raises instead of silently truncating to float32.
    """
    dtype = precision.canonical_dtype(dtype)
    H, N = wl.params.n_types, wl.n_jobs
    jt = np.asarray(wl.jtype, np.int64)
    w = np.asarray(wl.work, np.float64)
    submit = np.asarray(wl.submit, np.float64)

    order = np.argsort(jt, kind="stable")
    jt_s = jt[order]
    w_s = w[order]
    counts = np.bincount(jt, minlength=H)
    seg_start = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(N)
    rank_s = pos - seg_start[jt_s]                      # rank within type
    cum = np.concatenate([[0.0], np.cumsum(w_s)])
    cumw_s = cum[pos] - cum[seg_start[jt_s]]            # prefix work in type

    rank = np.zeros(N, np.int32)
    cumw = np.zeros(N, np.float64)
    rank[order] = rank_s.astype(np.int32)
    cumw[order] = cumw_s

    tj_submit = np.full((H, N), np.inf)
    tj_submit[jt_s, rank_s] = submit[order]
    tj_prefw = np.zeros((H, N + 1), np.float64)
    tj_prefw[jt_s, rank_s + 1] = cumw_s + w_s
    # extend prefix sums into the padding so prefw[tail] is always valid
    # (work >= 0 makes each row nondecreasing, so a running max fills pads)
    tj_prefw = np.maximum.accumulate(tj_prefw, axis=1)

    f = lambda a: jnp.asarray(a, dtype)
    return PackedWorkload(
        submit=f(wl.submit), work=f(wl.work), jtype=jnp.asarray(wl.jtype, jnp.int32),
        rank=jnp.asarray(rank), cumw=f(cumw), nodes=jnp.asarray(wl.nodes, jnp.int32),
        runtime=f(wl.runtime), tj_submit=f(tj_submit), tj_prefw=f(tj_prefw),
        t_last_submit=f(wl.submit[-1]), n_types=H, n_jobs=N)


class DesState(NamedTuple):
    t: jnp.ndarray            # current time
    next_sub: jnp.ndarray     # index of next submission (global order)
    head: jnp.ndarray         # [H] per-type queue window start (rank)
    tail: jnp.ndarray         # [H] per-type queue window end (rank)
    m_free: jnp.ndarray       # free nodes
    grp_end: jnp.ndarray      # [ring] completion time of running groups (+inf = free)
    grp_m: jnp.ndarray        # [ring] nodes held
    log_key: jnp.ndarray      # [N] group log: jtype * (N+1) + tail rank
    log_t: jnp.ndarray        # [N] group start time
    log_m: jnp.ndarray        # [N] group node count
    log_headw: jnp.ndarray    # [N] per-type prefix work at group head
    qlen_int: jnp.ndarray     # integral of queue length over [0, t_last_submit]
    busy_ns: jnp.ndarray      # busy node-seconds within the metric window
    useful_ns: jnp.ndarray    # useful node-seconds within the metric window
    n_groups: jnp.ndarray     # groups formed == next free log slot
    iters: jnp.ndarray        # diagnostic: outer loop iterations


class DesResult(NamedTuple):
    start_t: jnp.ndarray
    run_start_t: jnp.ndarray
    qlen_int: jnp.ndarray
    busy_ns: jnp.ndarray
    useful_ns: jnp.ndarray
    n_groups: jnp.ndarray
    makespan: jnp.ndarray
    ok: jnp.ndarray           # simulation drained within the iteration cap


def _window_overlap(a, b, t_end):
    """Length of [a, b] clipped to the metric window [0, t_end]."""
    return jnp.maximum(jnp.minimum(b, t_end) - jnp.minimum(a, t_end), 0.0)


def _reconstruct_job_times(pw: PackedWorkload, log_key, log_t, log_m,
                           log_headw, s_j):
    """Vectorized post-pass: job -> its group via per-type searchsorted.

    Within a type, group tails strictly increase and partition that type's
    ranks, so job (j, r) belongs to the type-j group with the smallest
    tail > r. Encoding groups as `j * (N+1) + tail` and jobs as
    `j * (N+1) + rank` makes that one global sorted lookup: tails are in
    1..N so type blocks never interleave. The log may have any capacity
    L >= 1 (the while engine uses L = N, the scan engine L = its step
    budget); unused slots carry the int32-max pad key and sort last. Jobs
    never grouped (only possible when the iteration/budget cap was hit)
    keep start = +inf, which also keeps the `ok` flag's all-finite check
    faithful.
    """
    N = pw.n_jobs
    L = log_key.shape[0]
    dtype = pw.submit.dtype
    order = jnp.argsort(log_key)
    skey = log_key[order]
    q = pw.jtype * (N + 1) + pw.rank
    ppos = jnp.searchsorted(skey, q, side="right")
    g = order[jnp.minimum(ppos, L - 1)]
    covered = (ppos < L) & (log_key[g] // (N + 1) == pw.jtype)
    t0 = log_t[g]
    m_g = jnp.maximum(log_m[g], 1).astype(dtype)
    start_t = jnp.where(covered, t0, INF)
    run_start = t0 + s_j[pw.jtype] + (pw.cumw - log_headw[g]) / m_g
    run_start_t = jnp.where(covered, run_start, INF)
    return start_t, run_start_t


def simulate_packet(pw: PackedWorkload, k, s_init, m_nodes,
                    priority=None, t_max=None, max_iters: int | None = None,
                    ring: int | None = None) -> DesResult:
    """Run the Packet algorithm DES (group-log event loop).

    Args:
      pw:      PackedWorkload (static shapes; close over for jit).
      k:       scale ratio (traced scalar — vmap axis of the sweep).
      s_init:  constant initialization time (traced scalar; per paper §6 the
               init time is one constant per experiment). Per-type init is
               s_j = s_init for all j.
      m_nodes: cluster size M (traced scalar int).
      priority, t_max: optional [H] job-type priorities / wait normalizers.
      ring:    running-group buffer size; default `resolve_ring(m_nodes, N)`.
    """
    H, N = pw.n_types, pw.n_jobs
    ring = resolve_ring(m_nodes, N, ring)
    dtype = precision.canonical_dtype(pw.submit.dtype)
    k = jnp.asarray(k, dtype)
    s_init = jnp.asarray(s_init, dtype)
    m_nodes = jnp.asarray(m_nodes, jnp.int32)
    s_j = jnp.full((H,), s_init, dtype)
    p_j = jnp.ones((H,), dtype) if priority is None else jnp.asarray(priority, dtype)
    tmax_j = (jnp.full((H,), 3600.0, dtype) if t_max is None
              else jnp.asarray(t_max, dtype))
    if max_iters is None:
        max_iters = 4 * N + 64

    t_end_metric = pw.t_last_submit
    type_ids = jnp.arange(H)
    key_pad = jnp.iinfo(jnp.int32).max     # unused log slots sort last

    def sched_cond(carry):
        st = carry
        nonempty = st.tail > st.head
        free_slot = jnp.any(jnp.isinf(st.grp_end))
        return (st.m_free > 0) & jnp.any(nonempty) & free_slot

    def sched_body(st: DesState) -> DesState:
        nonempty = st.tail > st.head
        sum_w = (pw.tj_prefw[type_ids, st.tail] -
                 pw.tj_prefw[type_ids, st.head])
        oldest = pw.tj_submit[type_ids, jnp.minimum(st.head, N - 1)]
        w = packet.queue_weights(sum_w, s_j, p_j, oldest, st.t, tmax_j, nonempty)
        # argmax index dtype follows x64 state; pin int32 so the log key
        # scatter below stays exact under the float64 opt-in.
        j = jnp.argmax(w).astype(jnp.int32)                   # Step 2
        work = sum_w[j]
        m_grp = packet.group_nodes(work, k, s_j[j], st.m_free)  # Step 4
        dur = packet.group_duration(work, s_j[j], m_grp)
        slot = jnp.argmax(jnp.isinf(st.grp_end))
        t_fin = st.t + dur

        # O(1) group-log append; job times reconstructed after the loop
        gslot = jnp.minimum(st.n_groups, N - 1)
        head_w = pw.tj_prefw[j, st.head[j]]

        busy = st.busy_ns + m_grp.astype(dtype) * _window_overlap(
            st.t, t_fin, t_end_metric)
        useful = st.useful_ns + m_grp.astype(dtype) * _window_overlap(
            st.t + s_j[j], t_fin, t_end_metric)

        return st._replace(
            head=st.head.at[j].set(st.tail[j]),               # Step 3: drain all
            m_free=st.m_free - m_grp,
            grp_end=st.grp_end.at[slot].set(t_fin),
            grp_m=st.grp_m.at[slot].set(m_grp),
            log_key=st.log_key.at[gslot].set(j * (N + 1) + st.tail[j]),
            log_t=st.log_t.at[gslot].set(st.t),
            log_m=st.log_m.at[gslot].set(m_grp),
            log_headw=st.log_headw.at[gslot].set(head_w),
            busy_ns=busy, useful_ns=useful,
            n_groups=st.n_groups + 1)

    def cond(st: DesState):
        more = (st.next_sub < N) | jnp.any(~jnp.isinf(st.grp_end))
        return more & (st.iters < max_iters)

    def body(st: DesState) -> DesState:
        t_sub = jnp.where(st.next_sub < N,
                          pw.submit[jnp.minimum(st.next_sub, N - 1)], INF)
        slot = jnp.argmin(st.grp_end)
        t_fin = st.grp_end[slot]
        take_sub = t_sub <= t_fin
        t_new = jnp.where(take_sub, t_sub, t_fin)

        # queue-length integral over the elapsed interval (clipped to window)
        qlen = jnp.sum(st.tail - st.head).astype(st.t.dtype)
        qint = st.qlen_int + qlen * _window_overlap(st.t, t_new, t_end_metric)

        def on_submit(st):
            j = pw.jtype[jnp.minimum(st.next_sub, N - 1)]
            return st._replace(next_sub=st.next_sub + 1,
                               tail=st.tail.at[j].add(1))

        def on_finish(st):
            return st._replace(m_free=st.m_free + st.grp_m[slot],
                               grp_end=st.grp_end.at[slot].set(INF),
                               grp_m=st.grp_m.at[slot].set(0))

        st = st._replace(t=t_new, qlen_int=qint)
        st = jax.lax.cond(take_sub, on_submit, on_finish, st)
        st = jax.lax.while_loop(sched_cond, sched_body, st)   # Steps 1-5
        return st._replace(iters=st.iters + 1)

    st0 = DesState(
        t=jnp.zeros((), dtype), next_sub=jnp.zeros((), jnp.int32),
        head=jnp.zeros((H,), jnp.int32), tail=jnp.zeros((H,), jnp.int32),
        m_free=m_nodes, grp_end=jnp.full((ring,), INF, dtype),
        grp_m=jnp.zeros((ring,), jnp.int32),
        log_key=jnp.full((N,), key_pad, jnp.int32),
        log_t=jnp.zeros((N,), dtype), log_m=jnp.zeros((N,), jnp.int32),
        log_headw=jnp.zeros((N,), dtype),
        qlen_int=jnp.zeros((), dtype), busy_ns=jnp.zeros((), dtype),
        useful_ns=jnp.zeros((), dtype), n_groups=jnp.zeros((), jnp.int32),
        iters=jnp.zeros((), jnp.int32))

    st = jax.lax.while_loop(cond, body, st0)
    start_t, run_start_t = _reconstruct_job_times(
        pw, st.log_key, st.log_t, st.log_m, st.log_headw, s_j)
    ok = (st.next_sub >= N) & jnp.all(jnp.isinf(st.grp_end)) & \
        jnp.all(st.head == st.tail) & jnp.all(jnp.isfinite(start_t))
    return DesResult(start_t=start_t, run_start_t=run_start_t,
                     qlen_int=st.qlen_int, busy_ns=st.busy_ns,
                     useful_ns=st.useful_ns, n_groups=st.n_groups,
                     makespan=st.t, ok=ok)


# --------------------------------------------------------------------------
# Event-budget scan engine: the batched-lane form of the group-log DES.
# --------------------------------------------------------------------------

EVENT_BUDGET_SLACK = 64   # headroom over the 3N analytic step bound
SCAN_SEG = 256            # default segment length (early-exit granularity)


def event_budget(n_jobs: int) -> int:
    """Safe per-grid step budget for `simulate_packet_scan`.

    Each scan step either consumes one event (a submission or a group
    completion: at most N + G of those) or forms one group (G of those),
    and every group drains >= 1 job so G <= N. 3N + slack steps therefore
    always drain a lane, whatever its (k, s).
    """
    return 3 * max(1, int(n_jobs)) + EVENT_BUDGET_SLACK


class _ScanState(NamedTuple):
    t: jnp.ndarray            # current time
    next_sub: jnp.ndarray     # index of next submission (global order)
    head: jnp.ndarray         # [H] per-type queue window start (rank)
    tail: jnp.ndarray         # [H] per-type queue window end (rank)
    m_free: jnp.ndarray       # free nodes
    grp_end: jnp.ndarray      # [ring] completion time of running groups
    grp_m: jnp.ndarray        # [ring] nodes held
    qlen_int: jnp.ndarray
    busy_ns: jnp.ndarray
    useful_ns: jnp.ndarray
    n_groups: jnp.ndarray


def simulate_packet_scan(pw: PackedWorkload, k, s_init, m_nodes,
                         priority=None, t_max=None, ring: int | None = None,
                         budget: int | None = None,
                         seg: int | None = None) -> DesResult:
    """Packet DES as a fixed-budget `lax.scan` — the batched-lane engine.

    Same policy and same per-step arithmetic as `simulate_packet`, but
    restructured for vmapping over many (k, s) lanes at once:

      * ONE flat step kind instead of an outer event loop with a nested
        scheduling `while_loop`: each step either forms one group (when the
        greedy pass is unblocked) or consumes one event, chosen branchlessly
        with masks, so vmapped lanes never pay a both-branches `lax.cond`
        or a lockstep inner loop.
      * the group log is EMITTED as scan outputs (`ys`) instead of carried
        as [N] state and scattered per step — under vmap the while engine
        drags [lanes, N] log arrays through every iteration, which is the
        dominant cost of the old fused mode on CPU.
      * a drained lane carries `active = False` and its step is a no-op
        (masked updates, pad log key), so lanes of different event counts
        can share one program.
      * the scan runs in `seg`-length segments under a `while_loop` that
        stops as soon as every lane in the dispatch has drained ("event
        budget with early exit"): the budget is the analytic worst case
        (`event_budget(N)` ~ 3N), but a dispatch of short lanes pays only
        its own steps, rounded up to a segment.

    `pw` is an ordinary operand and batches like any other: vmapping with
    ``in_axes=(0, 0, 0, None, None)`` over a stacked PackedWorkload (see
    `repro.core.cohort`) runs W same-shape workloads in one program, which
    is how `run_cohort_grid` folds the paper's whole 6-workflow study into
    two dispatched cohorts. Extra budget segments past a lane's drain point
    are masked no-ops (active=False emits pad log keys and freezes state),
    so per-lane results are independent of whatever else shares the
    dispatch — the property every equivalence test in the suite leans on.

    Results are equivalent to `simulate_packet` lane-for-lane (the
    equivalence suite pins every DesResult field); `ok` is False only if
    the budget was insufficient, which the 3N bound rules out for the
    default.
    """
    H, N = pw.n_types, pw.n_jobs
    ring = resolve_ring(m_nodes, N, ring)
    budget = event_budget(N) if budget is None else max(1, int(budget))
    seg = SCAN_SEG if seg is None else max(1, int(seg))
    n_segs = -(-budget // seg)
    budget = n_segs * seg               # segments tile the log exactly
    dtype = precision.canonical_dtype(pw.submit.dtype)
    k = jnp.asarray(k, dtype)
    s_init = jnp.asarray(s_init, dtype)
    m_nodes = jnp.asarray(m_nodes, jnp.int32)
    s_j = jnp.full((H,), s_init, dtype)
    p_j = jnp.ones((H,), dtype) if priority is None else jnp.asarray(priority, dtype)
    tmax_j = (jnp.full((H,), 3600.0, dtype) if t_max is None
              else jnp.asarray(t_max, dtype))

    t_end_metric = pw.t_last_submit
    type_ids = jnp.arange(H)
    key_pad = jnp.iinfo(jnp.int32).max
    zero_f = jnp.zeros((), dtype)
    zero_i = jnp.zeros((), jnp.int32)
    one_i = jnp.ones((), jnp.int32)

    def lane_active(st: _ScanState):
        return ((st.next_sub < N) | jnp.any(~jnp.isinf(st.grp_end)) |
                jnp.any(st.tail > st.head))

    def step(st: _ScanState, _):
        nonempty = st.tail > st.head
        free_mask = jnp.isinf(st.grp_end)
        queued = jnp.any(nonempty)
        active = lane_active(st)
        can_sched = (st.m_free > 0) & queued & jnp.any(free_mask)
        do_sched = active & can_sched
        do_event = active & ~can_sched

        # greedy scheduling pass (paper Steps 1-5), masked unless do_sched
        sum_w = (pw.tj_prefw[type_ids, st.tail] -
                 pw.tj_prefw[type_ids, st.head])
        oldest = pw.tj_submit[type_ids, jnp.minimum(st.head, N - 1)]
        w = packet.queue_weights(sum_w, s_j, p_j, oldest, st.t, tmax_j,
                                 nonempty)
        j = jnp.argmax(w).astype(jnp.int32)
        work = sum_w[j]
        m_grp = packet.group_nodes(work, k, s_j[j], st.m_free)
        dur = packet.group_duration(work, s_j[j], m_grp)
        sslot = jnp.argmax(free_mask)
        t_gfin = st.t + dur
        head_w = pw.tj_prefw[j, st.head[j]]
        busy_inc = m_grp.astype(dtype) * _window_overlap(
            st.t, t_gfin, t_end_metric)
        useful_inc = m_grp.astype(dtype) * _window_overlap(
            st.t + s_j[j], t_gfin, t_end_metric)

        # event step (submission or completion), masked unless do_event
        t_sub = jnp.where(st.next_sub < N,
                          pw.submit[jnp.minimum(st.next_sub, N - 1)], INF)
        eslot = jnp.argmin(st.grp_end)
        t_efin = st.grp_end[eslot]
        take_sub = t_sub <= t_efin
        t_new = jnp.where(take_sub, t_sub, t_efin)
        qlen = jnp.sum(st.tail - st.head).astype(dtype)
        q_inc = qlen * _window_overlap(st.t, t_new, t_end_metric)
        sub_j = pw.jtype[jnp.minimum(st.next_sub, N - 1)]

        do_submit = do_event & take_sub
        do_finish = do_event & ~take_sub

        head = st.head.at[j].set(jnp.where(do_sched, st.tail[j], st.head[j]))
        tail = st.tail.at[sub_j].add(jnp.where(do_submit, one_i, zero_i))
        m_free = (st.m_free - jnp.where(do_sched, m_grp, zero_i)
                  + jnp.where(do_finish, st.grp_m[eslot], zero_i))
        grp_end = st.grp_end.at[sslot].set(
            jnp.where(do_sched, t_gfin, st.grp_end[sslot]))
        grp_end = grp_end.at[eslot].set(
            jnp.where(do_finish, INF, grp_end[eslot]))
        grp_m = st.grp_m.at[sslot].set(
            jnp.where(do_sched, m_grp, st.grp_m[sslot]))
        grp_m = grp_m.at[eslot].set(
            jnp.where(do_finish, zero_i, grp_m[eslot]))

        y = (jnp.where(do_sched, j * (N + 1) + st.tail[j], key_pad),
             jnp.where(do_sched, st.t, zero_f),
             jnp.where(do_sched, m_grp, zero_i),
             jnp.where(do_sched, head_w, zero_f))

        st = _ScanState(
            t=jnp.where(do_event, t_new, st.t),
            next_sub=st.next_sub + jnp.where(do_submit, one_i, zero_i),
            head=head, tail=tail, m_free=m_free,
            grp_end=grp_end, grp_m=grp_m,
            qlen_int=st.qlen_int + jnp.where(do_event, q_inc, zero_f),
            busy_ns=st.busy_ns + jnp.where(do_sched, busy_inc, zero_f),
            useful_ns=st.useful_ns + jnp.where(do_sched, useful_inc, zero_f),
            n_groups=st.n_groups + jnp.where(do_sched, one_i, zero_i))
        return st, y

    def seg_cond(carry):
        st, _, s_idx = carry
        return lane_active(st) & (s_idx < n_segs)

    def seg_body(carry):
        st, logs, s_idx = carry
        st, ys = jax.lax.scan(step, st, None, length=seg)
        off = s_idx * seg
        logs = tuple(jax.lax.dynamic_update_slice(buf, y, (off,))
                     for buf, y in zip(logs, ys))
        return st, logs, s_idx + 1

    st0 = _ScanState(
        t=jnp.zeros((), dtype), next_sub=jnp.zeros((), jnp.int32),
        head=jnp.zeros((H,), jnp.int32), tail=jnp.zeros((H,), jnp.int32),
        m_free=m_nodes, grp_end=jnp.full((ring,), INF, dtype),
        grp_m=jnp.zeros((ring,), jnp.int32),
        qlen_int=jnp.zeros((), dtype), busy_ns=jnp.zeros((), dtype),
        useful_ns=jnp.zeros((), dtype), n_groups=jnp.zeros((), jnp.int32))
    logs0 = (jnp.full((budget,), key_pad, jnp.int32),
             jnp.zeros((budget,), dtype),
             jnp.zeros((budget,), jnp.int32),
             jnp.zeros((budget,), dtype))

    st, logs, _ = jax.lax.while_loop(
        seg_cond, seg_body, (st0, logs0, jnp.zeros((), jnp.int32)))
    log_key, log_t, log_m, log_headw = logs
    start_t, run_start_t = _reconstruct_job_times(
        pw, log_key, log_t, log_m, log_headw, s_j)
    ok = (st.next_sub >= N) & jnp.all(jnp.isinf(st.grp_end)) & \
        jnp.all(st.head == st.tail) & jnp.all(jnp.isfinite(start_t))
    return DesResult(start_t=start_t, run_start_t=run_start_t,
                     qlen_int=st.qlen_int, busy_ns=st.busy_ns,
                     useful_ns=st.useful_ns, n_groups=st.n_groups,
                     makespan=st.t, ok=ok)


# --------------------------------------------------------------------------
# Reference implementation: the original O(N)-masked-writes event body.
# Retained verbatim (fixed RING ring, eager per-job writes) as the oracle
# for the equivalence test suite and the baseline for benchmarks/bench_des.
# --------------------------------------------------------------------------

class _RefState(NamedTuple):
    t: jnp.ndarray
    next_sub: jnp.ndarray
    head: jnp.ndarray
    tail: jnp.ndarray
    m_free: jnp.ndarray
    grp_end: jnp.ndarray
    grp_m: jnp.ndarray
    start_t: jnp.ndarray      # [N] written eagerly per group — O(N)/event
    run_start_t: jnp.ndarray  # [N]
    qlen_int: jnp.ndarray
    busy_ns: jnp.ndarray
    useful_ns: jnp.ndarray
    n_groups: jnp.ndarray
    iters: jnp.ndarray


def simulate_packet_reference(pw: PackedWorkload, k, s_init, m_nodes,
                              priority=None, t_max=None,
                              max_iters: int | None = None) -> DesResult:
    """Seed-equivalent Packet DES with per-event O(N) metric writes."""
    H, N = pw.n_types, pw.n_jobs
    dtype = precision.canonical_dtype(pw.submit.dtype)
    k = jnp.asarray(k, dtype)
    s_init = jnp.asarray(s_init, dtype)
    m_nodes = jnp.asarray(m_nodes, jnp.int32)
    s_j = jnp.full((H,), s_init, dtype)
    p_j = jnp.ones((H,), dtype) if priority is None else jnp.asarray(priority, dtype)
    tmax_j = (jnp.full((H,), 3600.0, dtype) if t_max is None
              else jnp.asarray(t_max, dtype))
    if max_iters is None:
        max_iters = 4 * N + 64

    t_end_metric = pw.t_last_submit
    type_ids = jnp.arange(H)

    def sched_cond(st):
        nonempty = st.tail > st.head
        free_slot = jnp.any(jnp.isinf(st.grp_end))
        return (st.m_free > 0) & jnp.any(nonempty) & free_slot

    def sched_body(st: _RefState) -> _RefState:
        nonempty = st.tail > st.head
        sum_w = (pw.tj_prefw[type_ids, st.tail] -
                 pw.tj_prefw[type_ids, st.head])
        oldest = pw.tj_submit[type_ids, jnp.minimum(st.head, N - 1)]
        w = packet.queue_weights(sum_w, s_j, p_j, oldest, st.t, tmax_j, nonempty)
        j = jnp.argmax(w)
        work = sum_w[j]
        m_grp = packet.group_nodes(work, k, s_j[j], st.m_free)
        dur = packet.group_duration(work, s_j[j], m_grp)
        slot = jnp.argmax(jnp.isinf(st.grp_end))
        t_fin = st.t + dur

        in_grp = ((pw.jtype == j) & (pw.rank >= st.head[j]) &
                  (pw.rank < st.tail[j]))
        start_t = jnp.where(in_grp, st.t, st.start_t)
        head_w = pw.tj_prefw[j, st.head[j]]
        run_start = st.t + s_j[j] + (pw.cumw - head_w) / m_grp.astype(dtype)
        run_start_t = jnp.where(in_grp, run_start, st.run_start_t)

        busy = st.busy_ns + m_grp.astype(dtype) * _window_overlap(
            st.t, t_fin, t_end_metric)
        useful = st.useful_ns + m_grp.astype(dtype) * _window_overlap(
            st.t + s_j[j], t_fin, t_end_metric)

        return st._replace(
            head=st.head.at[j].set(st.tail[j]),
            m_free=st.m_free - m_grp,
            grp_end=st.grp_end.at[slot].set(t_fin),
            grp_m=st.grp_m.at[slot].set(m_grp),
            start_t=start_t, run_start_t=run_start_t,
            busy_ns=busy, useful_ns=useful,
            n_groups=st.n_groups + 1)

    def cond(st: _RefState):
        more = (st.next_sub < N) | jnp.any(~jnp.isinf(st.grp_end))
        return more & (st.iters < max_iters)

    def body(st: _RefState) -> _RefState:
        t_sub = jnp.where(st.next_sub < N,
                          pw.submit[jnp.minimum(st.next_sub, N - 1)], INF)
        slot = jnp.argmin(st.grp_end)
        t_fin = st.grp_end[slot]
        take_sub = t_sub <= t_fin
        t_new = jnp.where(take_sub, t_sub, t_fin)

        qlen = jnp.sum(st.tail - st.head).astype(st.t.dtype)
        qint = st.qlen_int + qlen * _window_overlap(st.t, t_new, t_end_metric)

        def on_submit(st):
            j = pw.jtype[jnp.minimum(st.next_sub, N - 1)]
            return st._replace(next_sub=st.next_sub + 1,
                               tail=st.tail.at[j].add(1))

        def on_finish(st):
            return st._replace(m_free=st.m_free + st.grp_m[slot],
                               grp_end=st.grp_end.at[slot].set(INF),
                               grp_m=st.grp_m.at[slot].set(0))

        st = st._replace(t=t_new, qlen_int=qint)
        st = jax.lax.cond(take_sub, on_submit, on_finish, st)
        st = jax.lax.while_loop(sched_cond, sched_body, st)
        return st._replace(iters=st.iters + 1)

    st0 = _RefState(
        t=jnp.zeros((), dtype), next_sub=jnp.zeros((), jnp.int32),
        head=jnp.zeros((H,), jnp.int32), tail=jnp.zeros((H,), jnp.int32),
        m_free=m_nodes, grp_end=jnp.full((RING,), INF, dtype),
        grp_m=jnp.zeros((RING,), jnp.int32),
        start_t=jnp.full((N,), INF, dtype), run_start_t=jnp.full((N,), INF, dtype),
        qlen_int=jnp.zeros((), dtype), busy_ns=jnp.zeros((), dtype),
        useful_ns=jnp.zeros((), dtype), n_groups=jnp.zeros((), jnp.int32),
        iters=jnp.zeros((), jnp.int32))

    st = jax.lax.while_loop(cond, body, st0)
    ok = (st.next_sub >= N) & jnp.all(jnp.isinf(st.grp_end)) & \
        jnp.all(st.head == st.tail) & jnp.all(jnp.isfinite(st.start_t))
    return DesResult(start_t=st.start_t, run_start_t=st.run_start_t,
                     qlen_int=st.qlen_int, busy_ns=st.busy_ns,
                     useful_ns=st.useful_ns, n_groups=st.n_groups,
                     makespan=st.t, ok=ok)


@partial(jax.jit, static_argnames=("max_iters", "ring"))
def _simulate_packet_jit(pw, k, s_init, m_nodes, max_iters=None, ring=None):
    return simulate_packet(pw, k, s_init, m_nodes, max_iters=max_iters,
                           ring=ring)


def simulate_packet_host(wl: Workload, k: float, s_prop: float,
                         dtype=jnp.float32) -> DesResult:
    """Convenience host entry point: workload + scale ratio + init proportion.

    Passing ``dtype=jnp.float64`` is the float64 opt-in: the whole
    pack-simulate pipeline runs inside a `precision.dtype_scope`, so the
    session's global x64 state is untouched.
    """
    with precision.dtype_scope(dtype):
        pw = pack_workload(wl, dtype)
        s = wl.init_time_for_proportion(s_prop)
        return jax.tree.map(np.asarray, simulate_packet(
            pw, k, s, wl.params.nodes))
