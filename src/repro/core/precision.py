"""Opt-in float64 precision plumbing for the simulation core.

The DES carries every time-integral accumulator (busy/useful node-seconds,
queue-length integral) in the workload dtype — float32 by default. Long
horizons or >>5000-job workloads deserve float64, but JAX truncates
``float64`` requests to float32 whenever ``jax_enable_x64`` is off, which
would turn a precision request into a silent no-op. This module makes the
choice explicit and scoped:

  * ``dtype_scope(dtype)`` — context manager that enables x64 only while a
    float64 simulation actually runs (wraps ``jax.experimental.enable_x64``),
    restoring the previous state on exit. Float32 sessions never flip:
    entering the scope with float32 is a no-op.
  * ``canonical_dtype(dtype)`` — validates a requested simulation dtype
    against the *current* x64 state and raises a clear error instead of
    letting JAX truncate silently.

High-level drivers (``run_packet_grid``, ``run_baselines``,
``simulate_packet_host``, ``benchmarks/bench_dtype``) enter ``dtype_scope``
themselves, so ``dtype=jnp.float64`` on their signatures IS the opt-in.
Low-level entry points (``pack_workload``, ``simulate_packet``, the baseline
simulators) only *validate* — callers composing them manually wrap the whole
pack-simulate-measure pipeline in one ``dtype_scope`` so every jit trace and
array creation sees a consistent x64 state.

jit caches stay correct across scopes for free: the x64 flag is part of
JAX's trace context, so a module-level jitted function compiled under
float64 never collides with its float32 cache entry.

Measured float32-vs-float64 deviations over the paper grid live in
``benchmarks/results/BENCH_dtype.json`` (see ``benchmarks/bench_dtype.py``).
"""
from __future__ import annotations

import contextlib

import jax
import numpy as np

SUPPORTED_DTYPES = (np.dtype(np.float32), np.dtype(np.float64))


def x64_enabled() -> bool:
    """Whether float64 is currently available (``jax_enable_x64`` on)."""
    return bool(jax.config.jax_enable_x64)


def canonical_dtype(dtype) -> np.dtype:
    """Normalize and validate a simulation dtype against the x64 state.

    Raises ValueError for non-float dtypes and for float64 requested while
    x64 is disabled — the situation where JAX would otherwise silently
    truncate every array to float32.
    """
    d = np.dtype(dtype)
    if d not in SUPPORTED_DTYPES:
        raise ValueError(
            f"simulation dtype must be float32 or float64, got {d}")
    if d == np.dtype(np.float64) and not x64_enabled():
        raise ValueError(
            "float64 simulation requested while jax_enable_x64 is off; JAX "
            "would silently truncate to float32. Wrap the call in "
            "repro.core.precision.dtype_scope(jnp.float64) (or use a "
            "high-level driver such as run_packet_grid(dtype=jnp.float64), "
            "which scopes it for you).")
    return d


@contextlib.contextmanager
def dtype_scope(dtype):
    """Scoped opt-in: enable x64 iff `dtype` is float64, restore on exit.

    Yields the validated numpy dtype. Nesting is safe; float32 scopes never
    touch the flag, so surrounding float32 sessions cannot silently flip.
    """
    d = np.dtype(dtype)
    if d not in SUPPORTED_DTYPES:
        raise ValueError(
            f"simulation dtype must be float32 or float64, got {d}")
    if d == np.dtype(np.float64) and not x64_enabled():
        from jax.experimental import enable_x64
        with enable_x64():
            yield d
    else:
        yield d
