"""Baseline schedulers: FCFS and conservative EASY backfill (rigid jobs).

The paper itself sweeps only the Packet algorithm; its predecessor work
([1], [4]) compares grouping against the backfill scheduling that production
JMS use. We implement both baselines on the *rigid* view of the workload
(each job runs alone on its requested n_i nodes for s + e_i seconds, paying
its own initialization), with the same fixed-shape `lax.while_loop` DES
skeleton as `repro.core.des` so results are directly comparable.

Per-event cost mirrors the group-log DES: the skeleton's queue-length
integral uses the scalar identity `waiting = next_sub - n_started` (no [N]
mask sum per event), FCFS walks a head pointer (jobs start strictly in
submit order, so the head is a monotone scalar — O(1) per started job
instead of an O(N) argmax), and the running-job ring is sized
`resolve_ring(M, N)` instead of a fixed 512. Backfill still scans the
waiting mask once per pass: its candidate set is inherently order-breaking.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import precision
from repro.core.des import (DesResult, PackedWorkload, _window_overlap,
                            INF, resolve_ring)


class _BaseState(NamedTuple):
    t: jnp.ndarray
    next_sub: jnp.ndarray
    head_ptr: jnp.ndarray     # first never-started job index (monotone)
    started: jnp.ndarray      # [N] bool (submitted jobs that began running)
    m_free: jnp.ndarray
    grp_end: jnp.ndarray      # [ring]
    grp_m: jnp.ndarray        # [ring]
    start_t: jnp.ndarray      # [N]
    qlen_int: jnp.ndarray
    busy_ns: jnp.ndarray
    useful_ns: jnp.ndarray
    n_started: jnp.ndarray
    iters: jnp.ndarray


def _start_job(st: _BaseState, i, s_init, runtime, nodes, t_end_metric):
    """Start rigid job i now; returns updated state (assumes it fits)."""
    dtype = st.t.dtype
    dur = s_init + runtime[i]
    t_fin = st.t + dur
    slot = jnp.argmax(jnp.isinf(st.grp_end))
    m = nodes[i]
    busy = st.busy_ns + m.astype(dtype) * _window_overlap(st.t, t_fin, t_end_metric)
    useful = st.useful_ns + m.astype(dtype) * _window_overlap(
        st.t + s_init, t_fin, t_end_metric)
    return st._replace(
        started=st.started.at[i].set(True),
        m_free=st.m_free - m,
        grp_end=st.grp_end.at[slot].set(t_fin),
        grp_m=st.grp_m.at[slot].set(m),
        start_t=st.start_t.at[i].set(st.t),
        busy_ns=busy, useful_ns=useful,
        n_started=st.n_started + 1)


def _event_skeleton(pw: PackedWorkload, s_init, m_nodes, sched_pass,
                    max_iters, ring):
    """Shared submit/finish event loop around a scheduling pass."""
    N = pw.n_jobs
    dtype = pw.submit.dtype
    t_end_metric = pw.t_last_submit

    def cond(st: _BaseState):
        more = (st.next_sub < N) | jnp.any(~jnp.isinf(st.grp_end))
        return more & (st.iters < max_iters)

    def body(st: _BaseState):
        t_sub = jnp.where(st.next_sub < N,
                          pw.submit[jnp.minimum(st.next_sub, N - 1)], INF)
        slot = jnp.argmin(st.grp_end)
        t_fin = st.grp_end[slot]
        take_sub = t_sub <= t_fin
        t_new = jnp.where(take_sub, t_sub, t_fin)

        # waiting jobs = submitted minus started, as a scalar counter
        n_wait = (st.next_sub - st.n_started).astype(dtype)
        qint = st.qlen_int + n_wait * _window_overlap(st.t, t_new, t_end_metric)
        st = st._replace(t=t_new, qlen_int=qint)

        st = jax.lax.cond(
            take_sub,
            lambda s: s._replace(next_sub=s.next_sub + 1),
            lambda s: s._replace(m_free=s.m_free + s.grp_m[slot],
                                 grp_end=s.grp_end.at[slot].set(INF),
                                 grp_m=s.grp_m.at[slot].set(0)),
            st)
        st = sched_pass(st)
        return st._replace(iters=st.iters + 1)

    st0 = _BaseState(
        t=jnp.zeros((), dtype), next_sub=jnp.zeros((), jnp.int32),
        head_ptr=jnp.zeros((), jnp.int32),
        started=jnp.zeros((N,), bool), m_free=jnp.asarray(m_nodes, jnp.int32),
        grp_end=jnp.full((ring,), INF, dtype),
        grp_m=jnp.zeros((ring,), jnp.int32),
        start_t=jnp.full((N,), INF, dtype),
        qlen_int=jnp.zeros((), dtype), busy_ns=jnp.zeros((), dtype),
        useful_ns=jnp.zeros((), dtype), n_started=jnp.zeros((), jnp.int32),
        iters=jnp.zeros((), jnp.int32))

    st = jax.lax.while_loop(cond, body, st0)
    drained = (st.next_sub >= N) & jnp.all(jnp.isinf(st.grp_end))
    ok = drained & jnp.all(st.started)
    zf = jnp.zeros((), dtype)
    zi = jnp.zeros((), jnp.int32)
    return DesResult(start_t=st.start_t,
                     run_start_t=st.start_t + s_init,
                     qlen_int=st.qlen_int, busy_ns=st.busy_ns,
                     useful_ns=st.useful_ns, n_groups=st.n_started,
                     makespan=st.t, ok=ok, budget_exhausted=~drained,
                     lost_work=zf, failures=zi, straggler_kills=zi,
                     requeues=zi, requeued_jobs=zi)


def simulate_fcfs(pw: PackedWorkload, s_init, m_nodes,
                  max_iters: int | None = None,
                  ring: int | None = None) -> DesResult:
    """Strict FCFS: the head of the queue blocks everything behind it.

    FCFS starts jobs exactly in submit order, so `head_ptr` IS the head of
    the queue — the scheduling pass is O(1) per started job.
    """
    N = pw.n_jobs
    s_init = jnp.asarray(s_init, precision.canonical_dtype(pw.submit.dtype))
    ring = resolve_ring(m_nodes, N, ring)
    if max_iters is None:
        max_iters = 4 * N + 64

    def sched_pass(st: _BaseState):
        def cond(st):
            i = jnp.minimum(st.head_ptr, N - 1)
            fits = (st.head_ptr < st.next_sub) & (pw.nodes[i] <= st.m_free)
            return fits & jnp.any(jnp.isinf(st.grp_end))

        def body(st):
            i = jnp.minimum(st.head_ptr, N - 1)
            st = _start_job(st, i, s_init, pw.runtime, pw.nodes,
                            pw.t_last_submit)
            return st._replace(head_ptr=st.head_ptr + 1)

        return jax.lax.while_loop(cond, body, st)

    return _event_skeleton(pw, s_init, m_nodes, sched_pass, max_iters, ring)


def simulate_backfill(pw: PackedWorkload, s_init, m_nodes,
                      backfill_depth: int = 64,
                      max_iters: int | None = None,
                      ring: int | None = None) -> DesResult:
    """Conservative EASY backfill.

    The head job gets a reservation at the *shadow time* (earliest instant
    enough nodes will be free); queued jobs within `backfill_depth` may jump
    ahead iff they fit now and either finish before the shadow time or use
    only the `extra` nodes not needed by the reservation. Shadow/extra are
    computed once per pass (conservative, as in production schedulers).
    """
    N = pw.n_jobs
    dtype = precision.canonical_dtype(pw.submit.dtype)
    s_init = jnp.asarray(s_init, dtype)
    ring = resolve_ring(m_nodes, N, ring)
    idx = jnp.arange(N)
    if max_iters is None:
        max_iters = 4 * N + 64

    def sched_pass(st: _BaseState):
        # 1) start jobs from the head while they fit
        def head_cond(st):
            waiting = (idx < st.next_sub) & ~st.started
            head = jnp.argmax(waiting)
            fits = jnp.any(waiting) & (pw.nodes[head] <= st.m_free)
            return fits & jnp.any(jnp.isinf(st.grp_end))

        def head_body(st):
            waiting = (idx < st.next_sub) & ~st.started
            head = jnp.argmax(waiting)
            return _start_job(st, head, s_init, pw.runtime, pw.nodes,
                              pw.t_last_submit)

        st = jax.lax.while_loop(head_cond, head_body, st)

        # 2) if a head remains blocked, compute its reservation
        waiting = (idx < st.next_sub) & ~st.started
        any_wait = jnp.any(waiting)
        head = jnp.argmax(waiting)
        n_head = jnp.where(any_wait, pw.nodes[head], 1)

        order = jnp.argsort(st.grp_end)                 # running jobs by end
        ends = st.grp_end[order]
        frees = jnp.cumsum(st.grp_m[order]) + st.m_free
        enough = frees >= n_head
        shadow_i = jnp.argmax(enough)
        shadow = jnp.where(jnp.any(enough), ends[shadow_i], INF)
        free_at_shadow = jnp.where(jnp.any(enough), frees[shadow_i],
                                   st.m_free)
        extra = jnp.maximum(free_at_shadow - n_head, 0)

        # 3) scan up to backfill_depth waiting jobs behind the head
        cand = jnp.nonzero(waiting & (idx != head), size=backfill_depth,
                           fill_value=N)[0]

        def bf_body(q, st):
            i = cand[q]
            valid = i < N
            fits_now = valid & (pw.nodes[jnp.minimum(i, N - 1)] <= st.m_free)
            i_c = jnp.minimum(i, N - 1)
            ends_before = st.t + s_init + pw.runtime[i_c] <= shadow
            within_extra = pw.nodes[i_c] <= extra
            slot_free = jnp.any(jnp.isinf(st.grp_end))
            do = fits_now & (ends_before | within_extra) & slot_free & any_wait
            return jax.lax.cond(
                do,
                lambda s: _start_job(s, i_c, s_init, pw.runtime, pw.nodes,
                                     pw.t_last_submit),
                lambda s: s, st)

        return jax.lax.fori_loop(0, backfill_depth, bf_body, st)

    return _event_skeleton(pw, s_init, m_nodes, sched_pass, max_iters, ring)
