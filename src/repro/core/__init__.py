"""The paper's primary contribution: group-based job scheduling (Packet
algorithm) with scale-ratio tuning, as a fixed-shape JAX discrete-event
simulation plus the pure policy functions reused by the ML-cluster layer."""
from repro.core import packet, precision
from repro.core.cohort import (CohortKey, WorkloadCohort, cohort_key,
                               group_workloads, stack_workloads)
from repro.core.des import (STEP_IMPLS, ChaosConfig, DesResult,
                            PackedWorkload, chaos_is_inert, chaos_uniforms,
                            event_budget, pack_workload, packet_scan_step,
                            resolve_max_requeues, resolve_ring,
                            simulate_packet, simulate_packet_host,
                            simulate_packet_reference, simulate_packet_scan,
                            simulate_packet_scan_lanes)
from repro.core.metrics import Metrics, efficiency_metrics
from repro.core.schedulers import simulate_backfill, simulate_fcfs
from repro.core.sweep import (CHAOS_AXIS_FIELDS, PAPER_INIT_PROPS,
                              PAPER_SCALE_RATIOS,
                              PlateauResult, chaos_axis_len, chaos_lane_grid,
                              cohort_lane_sharding, lane_padding,
                              lane_sharding, plateau_threshold, resolve_mode,
                              run_baselines, run_cohort_grid,
                              run_packet_grid, run_window_oracle, sweep_plan)

__all__ = [
    "packet", "precision", "CohortKey", "WorkloadCohort", "cohort_key",
    "group_workloads", "stack_workloads", "ChaosConfig", "DesResult",
    "PackedWorkload", "chaos_is_inert", "chaos_uniforms", "event_budget",
    "pack_workload", "packet_scan_step", "STEP_IMPLS",
    "resolve_max_requeues", "resolve_ring", "simulate_packet",
    "simulate_packet_host", "simulate_packet_reference",
    "simulate_packet_scan", "simulate_packet_scan_lanes", "Metrics",
    "efficiency_metrics", "simulate_backfill", "simulate_fcfs",
    "CHAOS_AXIS_FIELDS", "PAPER_INIT_PROPS", "PAPER_SCALE_RATIOS",
    "PlateauResult",
    "chaos_axis_len", "chaos_lane_grid", "cohort_lane_sharding",
    "lane_padding", "lane_sharding", "plateau_threshold", "resolve_mode",
    "run_baselines", "run_cohort_grid", "run_packet_grid",
    "run_window_oracle", "sweep_plan",
]
