"""Sweep driver: the paper's experiment grid as fused, shardable XLA programs.

The paper ran 1332 experiments (6 workflows x 37 scale ratios x 6 init
proportions), each "dozens of minutes" in Alea. Here one workload's whole
(k x S) grid can run as a SINGLE jitted program: the grid is flattened into
a lane axis of len(ks) * len(s_props) experiments (222 per workload for the
paper's grid) and `vmap`ped over both the scale ratio and the init time at
once, so the full study is 6 XLA dispatches total. Because experiments are
a pure data axis, the lane inputs are placed with a `NamedSharding` over all
available devices whenever the lane count divides evenly — the same program
runs one lane per device slice on a pod with no code change (see ROADMAP
"Open items" for the multi-host extension).

Lane batching is a throughput trade, not a free win: a vmapped while_loop
steps every lane until the slowest drains and turns per-lane scalar updates
into lane-axis gathers/scatters. With the O(1)-per-event group-log DES the
per-lane body is tiny, so on a single CPU device sequential dispatch of the
cached per-experiment program is ~10x faster per experiment than lockstep
lanes, while on multi-device backends the fused program wins by sharding.
`run_packet_grid(mode="auto")` picks accordingly; every mode is also
selectable explicitly.

Compiled entry points are module-level and take the PackedWorkload as an
argument (not a closure), so jit caches are shared across workloads of equal
shape: sweeping the paper's 6 same-shape workflows compiles once, not six
times, and repeated `run_packet_grid` calls never retrace. Caches are also
keyed on dtype (input avals + the x64 trace context), so the float64 opt-in
(`dtype=jnp.float64`, scoped via `repro.core.precision`) coexists with
float32 sweeps in one session without cross-talk.

Dtype guidance (study: benchmarks/results/BENCH_dtype.json): float32 grids
match float64 to ~7e-3 (waits) / ~2e-6 (utilizations) on homogeneous flows,
but on 5000-job heterogeneous flows 77-83% of cells schedule differently
(near-tie cascades) — run those in float64 when per-cell values matter.
"""
from __future__ import annotations

import itertools
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision
from repro.core.des import pack_workload, resolve_ring, simulate_packet
from repro.core.metrics import Metrics, efficiency_metrics
from repro.core.schedulers import simulate_backfill, simulate_fcfs
from repro.workload.lublin import Workload

# the paper's 37 scale-ratio values: 0.1..1 step .1, 1..10 step 1,
# 10..100 step 10, 100..1000 step 100
PAPER_SCALE_RATIOS: tuple[float, ...] = tuple(
    round(v, 1) for v in itertools.chain(
        (i / 10 for i in range(1, 10)),
        range(1, 10),
        range(10, 100, 10),
        range(100, 1001, 100)))
# 5% then 10%..50% step 10% (paper §6)
PAPER_INIT_PROPS: tuple[float, ...] = (0.05, 0.10, 0.20, 0.30, 0.40, 0.50)

assert len(PAPER_SCALE_RATIOS) == 37


def _one_experiment(pw, k, s, m_nodes, ring):
    res = simulate_packet(pw, k, s, m_nodes, ring=ring)
    return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)


@partial(jax.jit, static_argnames=("m_nodes", "ring"))
def _packet_one(pw, k, s, m_nodes, ring):
    """Single experiment (the per-dispatch path of mode='seq')."""
    return _one_experiment(pw, k, s, m_nodes, ring)


@partial(jax.jit, static_argnames=("m_nodes", "ring"))
def _packet_lanes(pw, k_lanes, s_lanes, m_nodes, ring):
    """Fused engine: one vmap over the flattened (k x S) lane axis."""
    return jax.vmap(_one_experiment, in_axes=(None, 0, 0, None, None))(
        pw, k_lanes, s_lanes, m_nodes, ring)


@partial(jax.jit, static_argnames=("m_nodes", "ring"))
def _packet_k_column(pw, ks_arr, s, m_nodes, ring):
    """One init-proportion column batched over the scale-ratio axis."""
    return jax.vmap(_one_experiment, in_axes=(None, 0, None, None, None))(
        pw, ks_arr, s, m_nodes, ring)


@partial(jax.jit, static_argnames=("m_nodes", "ring"))
def _packet_s_row(pw, k, s_vals, m_nodes, ring):
    """One scale-ratio row batched over the init-proportion axis."""
    return jax.vmap(_one_experiment, in_axes=(None, None, 0, None, None))(
        pw, k, s_vals, m_nodes, ring)


@partial(jax.jit, static_argnames=("m_nodes", "ring"))
def _baseline_lanes(pw, s_vals, m_nodes, ring):
    """Both rigid baselines batched over the init-proportion axis."""
    def fcfs_one(s):
        res = simulate_fcfs(pw, s, m_nodes, ring=ring)
        return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)

    def bf_one(s):
        res = simulate_backfill(pw, s, m_nodes, ring=ring)
        return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)

    return {"fcfs": jax.vmap(fcfs_one)(s_vals),
            "backfill": jax.vmap(bf_one)(s_vals)}


def resolve_mode(mode: str, n_lanes: int) -> str:
    """Resolve mode='auto' to the concrete dispatch layout.

    'fused' only pays when the lane axis actually shards across devices;
    unsharded lockstep lanes lose ~10x to sequential dispatch (see module
    docstring), so a single-device backend resolves to 'seq'. Exposed so
    benchmark provenance (e.g. paper_grid.json) can record the layout that
    actually ran.
    """
    if mode != "auto":
        return mode
    return "fused" if lane_sharding(n_lanes) is not None else "seq"


def lane_sharding(n_lanes: int):
    """NamedSharding splitting the experiment lane axis across all devices.

    Returns None on a single device or when the lane count does not divide
    the device count (XLA would need padding; callers then use the default
    replicated placement).
    """
    devices = jax.devices()
    if len(devices) <= 1 or n_lanes % len(devices) != 0:
        return None
    mesh = jax.sharding.Mesh(np.asarray(devices), ("lane",))
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("lane"))


def run_packet_grid(wl: Workload,
                    ks: Sequence[float] = PAPER_SCALE_RATIOS,
                    s_props: Sequence[float] = PAPER_INIT_PROPS,
                    dtype=jnp.float32,
                    vmap_s: bool = False,
                    vmap_k: bool = False,
                    mode: str = "auto") -> Metrics:
    """Metrics over the (scale ratio x init proportion) grid of one workload.

    Returns a Metrics pytree whose leaves have shape [len(ks), len(s_props)].

    Modes:
      * ``"fused"`` — ONE XLA program over all len(ks) * len(s_props)
        experiment lanes, lane axis device-sharded when possible. The
        scalable layout: on an n-device backend each device runs lanes/n
        experiments of the same program.
      * ``"seq"`` — one cached-jit dispatch per experiment. On a single
        CPU device this wins: the group-log event body is so cheap that a
        batched while_loop's lockstep iteration (all lanes step until the
        slowest drains, with gather/scatter over the lane axis) costs ~10x
        the per-lane work, while 222 sequential dispatches of a ~ms program
        are pure compute.
      * ``"auto"`` (default) — "fused" when `lane_sharding` can actually
        split the lane axis across devices (the sharding pays for the
        lockstep overhead), else "seq".
      * ``vmap_k=True`` / ``vmap_s=True`` — the narrower column/row
        batchings, kept for A/B comparison.

    All paths share module-level compile caches keyed on workload shape, so
    repeated calls (and the paper's 6 same-shape workflows) never retrace.
    jit caches are additionally keyed on dtype (via input avals and the x64
    trace context), so float32 and float64 sweeps coexist without retracing
    each other.

    `dtype=jnp.float64` is the precision opt-in: the whole sweep runs inside
    `precision.dtype_scope`, leaving the session's global x64 state alone.
    """
    if mode not in ("auto", "seq", "fused", "vmap_k", "vmap_s"):
        raise ValueError(f"unknown sweep mode {mode!r}")
    if (vmap_k or vmap_s) and mode != "auto":
        raise ValueError("pass either mode= or the legacy vmap_k/vmap_s "
                         "flags, not both")
    K, S = len(ks), len(s_props)
    if vmap_k:
        mode = "vmap_k"
    elif vmap_s:
        mode = "vmap_s"
    else:
        mode = resolve_mode(mode, K * S)

    with precision.dtype_scope(dtype):
        pw = pack_workload(wl, dtype)
        m_nodes = int(wl.params.nodes)
        ring = resolve_ring(m_nodes, pw.n_jobs)
        s_vals = jnp.asarray(
            [wl.init_time_for_proportion(p) for p in s_props], dtype)
        ks_arr = jnp.asarray(ks, dtype)

        if mode == "vmap_k":
            cols = [_packet_k_column(pw, ks_arr, s, m_nodes, ring)
                    for s in s_vals]
            stacked = jax.tree.map(lambda *x: jnp.stack(x, axis=1), *cols)
            return jax.tree.map(np.asarray, stacked)
        if mode == "vmap_s":
            rows = [_packet_s_row(pw, k, s_vals, m_nodes, ring)
                    for k in ks_arr]
            stacked = jax.tree.map(lambda *x: jnp.stack(x, axis=0), *rows)
            return jax.tree.map(np.asarray, stacked)
        if mode == "seq":
            cells = [[_packet_one(pw, k, s, m_nodes, ring) for s in s_vals]
                     for k in ks_arr]
            rows = [jax.tree.map(lambda *x: jnp.stack(x), *row)
                    for row in cells]
            stacked = jax.tree.map(lambda *x: jnp.stack(x), *rows)
            return jax.tree.map(np.asarray, stacked)

        # fused (k x S) lane engine
        k_lanes = jnp.repeat(ks_arr, S)
        s_lanes = jnp.tile(s_vals, K)
        sharding = lane_sharding(K * S)
        if sharding is not None:
            k_lanes = jax.device_put(k_lanes, sharding)
            s_lanes = jax.device_put(s_lanes, sharding)
        lanes = _packet_lanes(pw, k_lanes, s_lanes, m_nodes, ring)
        return jax.tree.map(
            lambda x: np.asarray(x).reshape((K, S) + x.shape[1:]), lanes)


def run_baselines(wl: Workload, s_props: Sequence[float] = PAPER_INIT_PROPS,
                  dtype=jnp.float32) -> dict[str, Metrics]:
    """FCFS and EASY-backfill metrics per init proportion (rigid jobs).

    Both baselines and all init proportions run as one batched program.
    `dtype=jnp.float64` opts into the scoped x64 mode, as in
    `run_packet_grid`.
    """
    with precision.dtype_scope(dtype):
        pw = pack_workload(wl, dtype)
        m_nodes = int(wl.params.nodes)
        ring = resolve_ring(m_nodes, pw.n_jobs)
        s_vals = jnp.asarray(
            [wl.init_time_for_proportion(p) for p in s_props], dtype)
        out = _baseline_lanes(pw, s_vals, m_nodes, ring)
        return {name: jax.tree.map(np.asarray, m) for name, m in out.items()}


def plateau_threshold(ks: np.ndarray, avg_wait: np.ndarray,
                      rel_tol: float = 0.05) -> float:
    """The paper's actionable output: the smallest scale ratio after which
    the average queue time stays within rel_tol of its large-k plateau."""
    ks = np.asarray(ks, np.float64)
    w = np.asarray(avg_wait, np.float64)
    plateau = np.median(w[-5:])
    ref = max(plateau, 1e-9)
    good = np.abs(w - plateau) <= rel_tol * max(ref, 1.0) + 1.0
    # find first index from which all subsequent are good
    for i in range(len(ks)):
        if good[i:].all():
            return float(ks[i])
    return float(ks[-1])
