"""Sweep driver: the paper's experiment grid as batched XLA programs.

The paper ran 1332 experiments (6 workflows x 37 scale ratios x 6 init
proportions), each "dozens of minutes" in Alea. Here one workload's whole
(k x S) grid is a single jitted program, optionally vmapped over the init-
proportion axis, so the full study runs in minutes on one host and shards
embarrassingly across pods (experiments are a pure data axis).
"""
from __future__ import annotations

import itertools
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.des import pack_workload, simulate_packet
from repro.core.metrics import Metrics, efficiency_metrics
from repro.core.schedulers import simulate_backfill, simulate_fcfs
from repro.workload.lublin import Workload

# the paper's 37 scale-ratio values: 0.1..1 step .1, 1..10 step 1,
# 10..100 step 10, 100..1000 step 100
PAPER_SCALE_RATIOS: tuple[float, ...] = tuple(
    round(v, 1) for v in itertools.chain(
        (i / 10 for i in range(1, 10)),
        range(1, 10),
        range(10, 100, 10),
        range(100, 1001, 100)))
# 5% then 10%..50% step 10% (paper §6)
PAPER_INIT_PROPS: tuple[float, ...] = (0.05, 0.10, 0.20, 0.30, 0.40, 0.50)

assert len(PAPER_SCALE_RATIOS) == 37


def run_packet_grid(wl: Workload,
                    ks: Sequence[float] = PAPER_SCALE_RATIOS,
                    s_props: Sequence[float] = PAPER_INIT_PROPS,
                    dtype=jnp.float32,
                    vmap_s: bool = False,
                    vmap_k: bool = False) -> Metrics:
    """Metrics over the (scale ratio x init proportion) grid of one workload.

    Returns a Metrics pytree whose leaves have shape [len(ks), len(s_props)].

    ``vmap_k`` batches the whole scale-ratio axis into ONE XLA program
    (the while_loop runs all lanes until the slowest drains) — ~1.9x per
    experiment on one CPU core by amortizing dispatch, and the layout that
    parallelizes across accelerator lanes/devices (the experiment axis is
    pure data parallelism).
    """
    pw = pack_workload(wl, dtype)
    m_nodes = wl.params.nodes
    s_vals = jnp.asarray([wl.init_time_for_proportion(p) for p in s_props],
                         dtype)
    ks_arr = jnp.asarray(ks, dtype)

    def one(k, s):
        res = simulate_packet(pw, k, s, m_nodes)
        return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)

    if vmap_k:
        col = jax.jit(jax.vmap(one, in_axes=(0, None)))
        cols = [col(ks_arr, s) for s in s_vals]
        return jax.tree.map(
            lambda *x: np.stack([np.asarray(v) for v in x], axis=1), *cols)
    if vmap_s:
        row = jax.jit(jax.vmap(one, in_axes=(None, 0)))
        rows = [row(k, s_vals) for k in ks_arr]
    else:
        one_j = jax.jit(one)
        rows = [jax.tree.map(lambda *x: jnp.stack(x),
                             *[one_j(k, s) for s in s_vals])
                for k in ks_arr]
    grid = jax.tree.map(lambda *x: np.stack([np.asarray(v) for v in x]), *rows)
    return grid


def run_baselines(wl: Workload, s_props: Sequence[float] = PAPER_INIT_PROPS,
                  dtype=jnp.float32) -> dict[str, Metrics]:
    """FCFS and EASY-backfill metrics per init proportion (rigid jobs)."""
    pw = pack_workload(wl, dtype)
    m_nodes = wl.params.nodes
    s_vals = jnp.asarray([wl.init_time_for_proportion(p) for p in s_props],
                         dtype)

    def fcfs_one(s):
        res = simulate_fcfs(pw, s, m_nodes)
        return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)

    def bf_one(s):
        res = simulate_backfill(pw, s, m_nodes)
        return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)

    out = {}
    for name, fn in (("fcfs", fcfs_one), ("backfill", bf_one)):
        f = jax.jit(fn)
        rows = [f(s) for s in s_vals]
        out[name] = jax.tree.map(
            lambda *x: np.stack([np.asarray(v) for v in x]), *rows)
    return out


def plateau_threshold(ks: np.ndarray, avg_wait: np.ndarray,
                      rel_tol: float = 0.05) -> float:
    """The paper's actionable output: the smallest scale ratio after which
    the average queue time stays within rel_tol of its large-k plateau."""
    ks = np.asarray(ks, np.float64)
    w = np.asarray(avg_wait, np.float64)
    plateau = np.median(w[-5:])
    ref = max(plateau, 1e-9)
    good = np.abs(w - plateau) <= rel_tol * max(ref, 1.0) + 1.0
    # find first index from which all subsequent are good
    for i in range(len(ks)):
        if good[i:].all():
            return float(ks[i])
    return float(ks[-1])
