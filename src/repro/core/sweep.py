"""Sweep driver: the paper's experiment grid as batched, shardable XLA programs.

The paper ran 1332 experiments (6 workflows x 37 scale ratios x 6 init
proportions), each "dozens of minutes" in Alea. Here one workload's whole
(k x S) grid is flattened into a lane axis of len(ks) * len(s_props)
experiments (222 per workload for the paper's grid) and driven through one
of three dispatch layouts over the event-budget scan engine
(`repro.core.des.simulate_packet_scan`); a fourth, *cohort*, layer batches
the workload axis on top so the WHOLE study runs as a couple of programs
(`run_cohort_grid`, one per group of same-static workloads):

  * ``"seq"``     — one cached-jit dispatch per experiment (the while-loop
    engine `simulate_packet`). Zero batching overhead; the baseline every
    other mode is measured against. Under `run_cohort_grid` this delegates
    to per-workload sequential dispatch (the pre-cohort driver layout).
  * ``"chunked"`` — lanes sorted by *predicted event count* (monotone
    decreasing in k * s: large scale ratios starve groups of nodes, so the
    queue drains in few big groups) and processed as a few fixed-size
    vmapped dispatches. Lanes of similar event count retire together, so
    the scan's segmented early exit stops each chunk near its own step
    count instead of the grid-wide worst case. This is the fastest layout
    on a single CPU device for paper-sized grids (see
    benchmarks/results/BENCH_des.json). Under `run_cohort_grid` every
    member's sorted chunks are interleaved through one sync-free dispatch
    sequence over device row slices of the stacked operand (workload-FUSED
    [W, width] chunk dispatches were measured and rejected — cache
    pressure; see `_run_cohort_chunks`).
  * ``"fused"``   — ONE program over all lanes. The scalable layout: the
    lane axis is padded up to the next device-count multiple with sentinel
    lanes (copies of the last real lane, sliced off after the gather) and
    placed with a `NamedSharding` over all local devices, so the 222-lane
    paper grid shards on 2/4/8-device backends even though 222 is not a
    power-of-two multiple. Under `run_cohort_grid` the program is [W, L]:
    the lane axis keeps the padded sharding (PartitionSpec(None, "lane")),
    the stacked workload axis is replicated, and one program covers
    W x lanes experiments (666 for a 3-flow paper cohort).

The workload axis exists because `simulate_packet_scan` takes the
`PackedWorkload` as an *operand*: `repro.core.cohort.stack_workloads`
stacks same-static workloads along a leading axis and the cohort kernel
vmaps over (pw, k, s) with ``in_axes=(0, 0, 0, None, None)`` — nested over
the per-lane vmap — so no workload table is ever replicated per lane.

Why the scan engine: a vmapped `while_loop` (the PR-1 fused engine) carries
the [lanes, N] group log through every lockstep iteration and scatters into
it per event, which lost ~16x to sequential dispatch on one CPU device.
`simulate_packet_scan` instead emits log records as scan outputs, carries
only O(H + ring) state, and runs a branchless masked step over a precomputed
event budget (~3N, with segmented early exit) — batched lanes now cost about
the same per experiment as sequential dispatch, and chunking makes them
cheaper (BENCH_des.json "engine_ab" section tracks the ratio across PRs).

`run_packet_grid(mode="auto")` resolves the layout from lane count and
device count (`resolve_mode`); `sweep_plan` returns the same decision plus
its inputs as a dict so benchmark provenance (e.g. paper_grid.json) records
what actually ran. Compiled entry points are module-level and take the
PackedWorkload as an argument (not a closure), so jit caches are shared
across workloads of equal shape and keyed on dtype (input avals + the x64
trace context): the float64 opt-in (`dtype=jnp.float64`, scoped via
`repro.core.precision`) coexists with float32 sweeps in one session.

Dtype guidance (study: benchmarks/results/BENCH_dtype.json): float32 grids
match float64 to ~7e-3 (waits) / ~2e-6 (utilizations) on homogeneous flows,
but on 5000-job heterogeneous flows 77-83% of cells schedule differently
(near-tie cascades) — `benchmarks/paper_sweep.py` therefore defaults
heterogeneous flows to float64 and records the per-workload decision.
"""
from __future__ import annotations

import dataclasses
import itertools
import warnings
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision
from repro.core.des import (STEP_IMPLS, ChaosConfig, PackedWorkload,
                            _check_step_impl, chaos_is_inert, event_budget,
                            pack_workload, resolve_max_requeues,
                            resolve_ring, simulate_packet,
                            simulate_packet_scan, simulate_packet_scan_lanes)
from repro.core.metrics import Metrics, efficiency_metrics
from repro.core.schedulers import simulate_backfill, simulate_fcfs
from repro.workload.lublin import Workload

# the paper's 37 scale-ratio values: 0.1..1 step .1, 1..10 step 1,
# 10..100 step 10, 100..1000 step 100
PAPER_SCALE_RATIOS: tuple[float, ...] = tuple(
    round(v, 1) for v in itertools.chain(
        (i / 10 for i in range(1, 10)),
        range(1, 10),
        range(10, 100, 10),
        range(100, 1001, 100)))
# 5% then 10%..50% step 10% (paper §6)
PAPER_INIT_PROPS: tuple[float, ...] = (0.05, 0.10, 0.20, 0.30, 0.40, 0.50)

assert len(PAPER_SCALE_RATIOS) == 37

SWEEP_MODES = ("auto", "seq", "chunked", "fused", "vmap_k", "vmap_s")
CHUNK_LANES = 64          # chunked-mode dispatch width (measured sweet spot)
CHUNKED_MIN_LANES = 32    # below this, per-dispatch batching can't amortize
# Measured same-schedule float32 deviation ceiling for avg_wait over the
# full paper grid (benchmarks/results/BENCH_dtype.json
# `suggested_float32_rtol`, 10x the worst rounding-only deviation). Used as
# the default absolute-slack scale in `plateau_threshold`, so the plateau
# call is exactly as tolerant as float32 arithmetic is imprecise.
FLOAT32_AVG_WAIT_RTOL = 0.031


def _one_experiment(pw, k, s, m_nodes, ring, chaos=None):
    res = simulate_packet(pw, k, s, m_nodes, ring=ring, chaos=chaos)
    return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)


def _one_experiment_scan(pw, k, s, m_nodes, ring, chaos=None):
    res = simulate_packet_scan(pw, k, s, m_nodes, ring=ring, chaos=chaos)
    return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)


@partial(jax.jit, static_argnames=("m_nodes", "ring", "step_impl"))
def _packet_one(pw, k, s, m_nodes, ring, chaos=None, step_impl="xla"):
    """Single experiment (the per-dispatch path of mode='seq').

    Without chaos this is the while-loop engine, bitwise-identical to every
    pre-chaos release. Chaos runs dispatch the scan engine instead: the
    sweep contract is that seq/chunked/fused agree *bitwise* on a seeded
    fault sweep, and only a shared engine can promise that — LLVM
    contracts mul+add into FMA at codegen, below HLO-level
    `optimization_barrier`s, so the two engines' differently-shaped loop
    bodies can legally round a metric accumulate differently in either
    dtype (observed: 1-2 ulp in qlen_int). Cross-engine chaos agreement
    is still enforced, engine-level, by tests/test_chaos.py: schedules
    and counters exact, float accumulates allclose (tight in float64).

    ``step_impl="pallas"`` always routes through the scan engine (the
    kernel is a scan-step implementation), chaos or not — so a pallas
    "seq" sweep A/Bs engine-level against the batched layouts, while the
    XLA default keeps the historical while-engine fast path.
    """
    if step_impl == "pallas":
        res = simulate_packet_scan(pw, k, s, m_nodes, ring=ring,
                                   chaos=chaos, step_impl="pallas")
        return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)
    if chaos is None:
        return _one_experiment(pw, k, s, m_nodes, ring)
    return _one_experiment_scan(pw, k, s, m_nodes, ring, chaos)


@partial(jax.jit, static_argnames=("m_nodes", "ring", "step_impl"))
def _packet_lanes(pw, k_lanes, s_lanes, m_nodes, ring, chaos=None,
                  step_impl="xla"):
    """Batched lanes through the event-budget scan engine (chunked/fused).

    `chaos` is either None (the pre-chaos trace) or a ChaosConfig whose
    leaves are [L]-aligned with the lane axis (ChaosConfig's static aux —
    seed, max_requeues — keys the jit cache via the treedef).

    ``step_impl="pallas"`` runs the same lanes through the fused
    event-step kernel (`des.simulate_packet_scan_lanes`) instead of the
    vmapped XLA step — one kernel invocation advances the whole dispatch
    one event, with bitwise-identical schedules and counters."""
    if step_impl == "pallas":
        res = simulate_packet_scan_lanes(pw, k_lanes, s_lanes, m_nodes,
                                         ring=ring, chaos=chaos,
                                         step_impl="pallas")
        return jax.vmap(
            lambda r: efficiency_metrics(pw.submit, r, m_nodes,
                                         pw.t_last_submit))(res)
    if chaos is None:
        return jax.vmap(_one_experiment_scan,
                        in_axes=(None, 0, 0, None, None))(
            pw, k_lanes, s_lanes, m_nodes, ring)
    return jax.vmap(_one_experiment_scan,
                    in_axes=(None, 0, 0, None, None, 0))(
        pw, k_lanes, s_lanes, m_nodes, ring, chaos)


@partial(jax.jit, static_argnames=("m_nodes", "ring"))
def _packet_k_column(pw, ks_arr, s, m_nodes, ring):
    """One init-proportion column batched over the scale-ratio axis."""
    return jax.vmap(_one_experiment_scan, in_axes=(None, 0, None, None, None))(
        pw, ks_arr, s, m_nodes, ring)


@partial(jax.jit, static_argnames=("m_nodes", "ring"))
def _packet_s_row(pw, k, s_vals, m_nodes, ring):
    """One scale-ratio row batched over the init-proportion axis."""
    return jax.vmap(_one_experiment_scan, in_axes=(None, None, 0, None, None))(
        pw, k, s_vals, m_nodes, ring)


@partial(jax.jit, static_argnames=("m_nodes", "ring"))
def _baseline_lanes(pw, s_vals, m_nodes, ring):
    """Both rigid baselines batched over the init-proportion axis."""
    def fcfs_one(s):
        res = simulate_fcfs(pw, s, m_nodes, ring=ring)
        return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)

    def bf_one(s):
        res = simulate_backfill(pw, s, m_nodes, ring=ring)
        return efficiency_metrics(pw.submit, res, m_nodes, pw.t_last_submit)

    return {"fcfs": jax.vmap(fcfs_one)(s_vals),
            "backfill": jax.vmap(bf_one)(s_vals)}


#: the ChaosConfig fields that may carry a chaos lane axis
CHAOS_AXIS_FIELDS = ("mtbf_chip_hours", "ckpt_period", "straggler_prob",
                     "straggler_factor", "straggler_deadline")


def chaos_axis_len(chaos: ChaosConfig | None) -> int:
    """Length C of the chaos lane axis: 1 for a scalar ChaosConfig, else the
    shared leading dim of its array-valued fault parameters.

    Scalar/array mixes are legal (scalars broadcast over the axis), but
    every array-valued parameter must share ONE length and be 1-D; both
    violations raise here, naming the offending fields, instead of
    surfacing as a broadcast shape error deep inside `chaos_lane_grid`."""
    if chaos is None:
        return 1
    sizes: dict[str, int] = {}
    for name in CHAOS_AXIS_FIELDS:
        x = getattr(chaos, name)
        nd = np.ndim(x)
        if nd > 1:
            raise ValueError(
                f"ChaosConfig.{name} must be a scalar or a 1-D chaos axis, "
                f"got shape {np.shape(x)}")
        if nd:
            sizes[name] = int(np.shape(x)[0])
    arrays = {n: s for n, s in sizes.items() if s != 1}
    uniq = sorted(set(arrays.values()))
    if len(uniq) > 1:
        detail = ", ".join(f"{n}[{s}]" for n, s in sorted(arrays.items()))
        raise ValueError(
            f"ChaosConfig fault parameters have mismatched chaos-axis "
            f"lengths: {detail}; array-valued parameters must share one "
            f"leading length (scalars broadcast)")
    return uniq[0] if uniq else 1


def chaos_lane_grid(chaos: ChaosConfig, n_grid: int, dtype) -> tuple:
    """Broadcast a ChaosConfig over the flat (k, s) lane axis.

    Returns ``(chaos_lanes, C)``: every fault parameter becomes a
    [n_grid * C] array (grid-major, chaos-minor — cell (i_k, i_s) owns the
    C consecutive lanes starting at (i_k * S + i_s) * C) and `lane` is
    overwritten with the flat experiment index. The lane id is assigned in
    GRID order, before any chunk sorting or fused padding, so the per-lane
    uniform stream is identical across every dispatch layout.
    """
    C = chaos_axis_len(chaos)

    def tile(x):
        arr = jnp.broadcast_to(jnp.asarray(x, dtype), (C,))
        return jnp.tile(arr, n_grid)

    lanes = dataclasses.replace(
        chaos,
        mtbf_chip_hours=tile(chaos.mtbf_chip_hours),
        ckpt_period=tile(chaos.ckpt_period),
        straggler_prob=tile(chaos.straggler_prob),
        straggler_factor=tile(chaos.straggler_factor),
        straggler_deadline=tile(chaos.straggler_deadline),
        lane=jnp.arange(n_grid * C, dtype=jnp.int32))
    return lanes, C


def _chaos_cell(chaos_lanes: ChaosConfig, i: int) -> ChaosConfig:
    """Scalar ChaosConfig for one flat lane (the mode='seq' dispatch)."""
    return jax.tree.map(lambda x: x[i], chaos_lanes)


_BUDGET_CELLS_SHOWN = 8    # exhausted cells named per message


def _format_budget_cells(bad: np.ndarray, ks=None, s_props=None,
                         axis_names=None) -> str:
    """Name the exhausted grid cells: indices along the metric axes
    ((i_k, i_s[, i_chaos]) for a reshaped grid, a flat lane index
    otherwise) plus the actual k / s_prop values when the caller's axes
    are known. `axis_names` overrides the default axis labels (the
    window oracle's second axis is the chaos cell, not an init
    proportion). Truncated after `_BUDGET_CELLS_SHOWN` entries."""
    if bad.ndim == 0:
        return "the single experiment"
    idx = np.argwhere(bad)
    if axis_names is not None:
        names = tuple(axis_names)[:bad.ndim]
    else:
        names = (("i_k", "i_s", "i_chaos")[:bad.ndim] if bad.ndim <= 3
                 else tuple(f"i{d}" for d in range(bad.ndim)))
    shown = []
    for cell in idx[:_BUDGET_CELLS_SHOWN]:
        cell = tuple(int(v) for v in cell)
        parts = ([f"lane={cell[0]}"] if bad.ndim == 1 else
                 [f"{n}={v}" for n, v in zip(names, cell)])
        if bad.ndim >= 2:
            if ks is not None and cell[0] < len(ks):
                parts.append(f"k={float(ks[cell[0]]):g}")
            if s_props is not None and cell[1] < len(s_props):
                parts.append(f"s_prop={float(s_props[cell[1]]):g}")
        shown.append("(" + ", ".join(parts) + ")")
    more = len(idx) - len(shown)
    return "; ".join(shown) + (f"; ... {more} more" if more > 0 else "")


def _enforce_budget(metrics, policy: str, label: str,
                    ks=None, s_props=None, axis_names=None):
    """raise / warn / ignore when any lane hit its event budget.

    A truncated lane means its schedule (and every metric) stops early —
    silently mixing those cells into a grid is how the pre-PR-6 driver hid
    starved runs, so the default is to raise. The message names the
    exhausted cells (grid indices and, when the caller passes its axes,
    the (k, s_prop) values — the chaos index identifies the fault cell via
    the sweep plan's `chaos` block), so a truncated 1332-cell run is
    diagnosable without re-running it.
    """
    if policy not in ("raise", "warn", "ignore"):
        raise ValueError(f"on_budget_exhausted must be 'raise', 'warn' or "
                         f"'ignore', got {policy!r}")
    if policy == "ignore":
        return
    bad = np.asarray(metrics.budget_exhausted)
    n_bad = int(bad.sum())
    if n_bad:
        msg = (f"{label}: {n_bad} lane(s) exhausted the event budget at "
               f"[{_format_budget_cells(bad, ks, s_props, axis_names)}] — "
               f"schedules "
               f"are truncated; raise max_requeues/budget or pass "
               f"on_budget_exhausted='ignore' to keep them")
        if policy == "raise":
            raise RuntimeError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)


def predicted_lane_events(k_lanes, s_lanes) -> np.ndarray:
    """Relative event-count predictor used to sort lanes into chunks.

    The scan engine's step count is N + 2G where G is the number of groups
    formed. G is monotone *decreasing* in both the scale ratio k and the
    init time s: large k means few nodes per group (m = ceil(W / (k s))),
    long group durations and a queue that drains in few big groups, while
    small k * s forms a near-singleton group per job (G -> N). The product
    k * s is therefore a monotone proxy; lanes are sorted by it so chunk
    neighbours retire at similar step counts. Only the ORDER matters —
    budgets stay at the safe `event_budget` bound and early exit does the
    rest — so the proxy needs no calibration.
    """
    score = np.asarray(k_lanes, np.float64) * np.asarray(s_lanes, np.float64)
    return -score        # descending events == ascending k * s


def lane_order(k_lanes, s_lanes) -> np.ndarray:
    """Stable lane permutation: predicted-longest lanes first."""
    return np.argsort(-predicted_lane_events(k_lanes, s_lanes), kind="stable")


def lane_padding(n_lanes: int, n_devices: int | None = None) -> int:
    """Sentinel lanes needed to round n_lanes up to a device multiple."""
    if n_devices is None:
        n_devices = jax.device_count()
    return (-n_lanes) % max(1, n_devices)


def lane_sharding(n_lanes: int, pad: bool = False):
    """NamedSharding splitting the experiment lane axis across all devices.

    Returns None on a single device or (by default) when the lane count
    does not divide the device count — callers following the historical
    ``if sharding is not None: device_put(...)`` pattern keep the
    replicated fallback. ``pad=True`` declares the caller pads the lane
    axis with `lane_padding` sentinel lanes before placement (as
    `run_packet_grid(mode="fused")` does), so any lane count shards — the
    paper's 222-lane grid included — on 2/4/8-device backends.
    """
    devices = jax.devices()
    if len(devices) <= 1:
        return None
    if not pad and n_lanes % len(devices) != 0:
        return None
    mesh = jax.sharding.Mesh(np.asarray(devices), ("lane",))
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("lane"))


def resolve_mode(mode: str, n_lanes: int, n_workloads: int = 1,
                 step_impl: str = "xla") -> str:
    """Resolve mode='auto' to the concrete dispatch layout; validate others.

    Measured heuristics (benchmarks/results/BENCH_des.json, single CPU
    device vs sharded backends), applied to the TOTAL experiment count
    ``n_lanes * n_workloads`` (`n_lanes` stays the per-workload lane count;
    ``n_workloads > 1`` is the cohort path of `run_cohort_grid`):

      * more than one device -> "fused": the padded lane axis shards, and
        per-device lane counts shrink with the device count.
      * one device, >= CHUNKED_MIN_LANES total experiments -> "chunked":
        sorted chunks through the scan engine beat sequential dispatch on
        paper-sized grids and stay within ~1.2x on small ones.
      * one device, small study -> "seq": nothing to amortize.

    Any explicit mode must be one of SWEEP_MODES; unknown strings raise
    instead of silently falling through to a default layout.

    `step_impl` (validated here so every driver rejects typos up front) is
    ORTHOGONAL to the layout: seq/chunked/fused describe how lanes are
    grouped into dispatches, the step implementation ("xla" | "pallas")
    describes what executes one event inside each dispatch. The legacy
    vmap_k/vmap_s layouts predate the engine knob and stay XLA-only.
    """
    _check_step_impl(step_impl)
    if mode not in SWEEP_MODES:
        raise ValueError(
            f"unknown sweep mode {mode!r}; available: {SWEEP_MODES}")
    if step_impl == "pallas" and mode in ("vmap_k", "vmap_s"):
        raise ValueError(
            f"mode {mode!r} is a legacy XLA-only layout; the pallas step "
            f"runs under 'seq', 'chunked' or 'fused'")
    if mode != "auto":
        return mode
    total = n_lanes * max(1, int(n_workloads))
    if jax.device_count() > 1 and total >= jax.device_count():
        return "fused"
    return "chunked" if total >= CHUNKED_MIN_LANES else "seq"


def sweep_plan(mode: str, n_lanes: int, n_workloads: int = 1,
               chaos: ChaosConfig | None = None,
               step_impl: str = "xla") -> dict:
    """The resolve_mode decision plus its inputs, for benchmark provenance.

    `benchmarks/paper_sweep.py` persists this next to the metrics so a
    paper_grid.json records not just WHAT ran but WHY that layout was
    picked (lane count, workload/cohort layout, device count, padding,
    chunk width). ``n_workloads > 1`` describes a cohort study: the plan
    then reports the stacked [W, lanes] layout `run_cohort_grid` executes.
    A `chaos` config multiplies the lane axis by its fault-parameter length
    C and records the fault grid (seed, requeue bound, parameter values)
    so a chaos sweep's provenance pins the exact draws. `step_impl`
    records which event-step engine runs inside each dispatch
    ("xla" | "pallas"); `step_interpret` flags a pallas run discharged
    through interpret mode (CPU backend) — a parity run, not a perf run,
    which is why bench_des skips its regression ratio gate.
    """
    if chaos_is_inert(chaos):
        chaos = None        # mirror the run_* drivers' normalization
    C = chaos_axis_len(chaos)
    n_lanes = int(n_lanes) * C
    resolved = resolve_mode(mode, n_lanes, n_workloads, step_impl)
    n_workloads = max(1, int(n_workloads))
    plan = {
        "requested_mode": mode,
        "mode": resolved,
        "step_impl": step_impl,
        "step_interpret": bool(step_impl == "pallas"
                               and jax.default_backend() == "cpu"),
        "n_lanes": n_lanes,
        "n_workloads": n_workloads,
        "total_experiments": n_lanes * n_workloads,
        "n_devices": int(jax.device_count()),
        "lane_pad": int(lane_padding(n_lanes)) if resolved == "fused" else 0,
        "chunk_lanes": CHUNK_LANES if resolved == "chunked" else None,
        "chunked_min_lanes": CHUNKED_MIN_LANES,
    }
    if chaos is not None:
        plan["chaos"] = {
            "axis_len": C,
            # requeue-credit semantics marker: absent in pre-PR-7 plans
            # (aggregate pool), "per-member" since the member-span walk
            "requeue_credit": "per-member",
            "seed": int(chaos.seed),
            "max_requeues": (None if chaos.max_requeues is None
                             else int(chaos.max_requeues)),
            "mtbf_chip_hours": np.asarray(chaos.mtbf_chip_hours,
                                          np.float64).tolist(),
            "ckpt_period": np.asarray(chaos.ckpt_period,
                                      np.float64).tolist(),
            "straggler_prob": np.asarray(chaos.straggler_prob,
                                         np.float64).tolist(),
            "straggler_factor": np.asarray(chaos.straggler_factor,
                                           np.float64).tolist(),
            "straggler_deadline": np.asarray(chaos.straggler_deadline,
                                             np.float64).tolist(),
        }
    return plan


def _run_lane_chunks(pw, k_lanes, s_lanes, m_nodes, ring, chunk: int,
                     chaos=None, step_impl="xla"):
    """Sorted equal-width chunks through the scan engine, then unsort.

    The requested `chunk` width only sets the number of dispatches
    (ceil(L / chunk)); the actual width is balanced to ceil(L / n_chunks)
    so a grid slightly over a chunk boundary doesn't pay a nearly-empty
    padded dispatch (222 lanes at width 64 -> 4 dispatches of 56, not
    3 x 64 + 30). Every chunk is padded to exactly that width (repeating
    its last lane) so all dispatches share one compiled program; the
    inverse permutation restores grid order before reshaping.

    `chaos` (when given) carries [L]-aligned fault-parameter leaves and is
    gathered by the SAME permutation as k/s — each lane keeps its grid-order
    lane id, so the per-lane uniform stream is sort-invariant.
    """
    L = int(k_lanes.shape[0])
    n_chunks = max(1, -(-L // max(1, chunk)))
    width = -(-L // n_chunks)
    order = lane_order(np.asarray(k_lanes), np.asarray(s_lanes))
    chunks = []
    for c in range(0, L, width):
        idx = order[c:c + width]
        pad = width - len(idx)
        if pad:
            idx = np.concatenate([idx, np.repeat(idx[-1:], pad)])
        chaos_c = (None if chaos is None
                   else jax.tree.map(lambda x: jnp.asarray(x)[idx], chaos))
        out = _packet_lanes(pw, k_lanes[idx], s_lanes[idx], m_nodes, ring,
                            chaos_c, step_impl=step_impl)
        chunks.append(jax.tree.map(lambda x: np.asarray(x)[:width - pad]
                                   if pad else np.asarray(x), out))
    gathered = jax.tree.map(lambda *x: np.concatenate(x, axis=0), *chunks)
    inv = np.empty_like(order)
    inv[order] = np.arange(L)
    return jax.tree.map(lambda x: x[inv], gathered)


def _run_lanes_fused(pw, k_lanes, s_lanes, m_nodes, ring, chaos=None,
                     step_impl="xla"):
    """All lanes in one dispatch, lane axis padded + sharded when possible."""
    L = int(k_lanes.shape[0])
    pad = lane_padding(L)
    if pad:
        k_lanes = jnp.concatenate([k_lanes, jnp.repeat(k_lanes[-1:], pad)])
        s_lanes = jnp.concatenate([s_lanes, jnp.repeat(s_lanes[-1:], pad)])
        if chaos is not None:
            # sentinel lanes replay the last real lane (same lane id ->
            # same stream); their rows are sliced off below
            chaos = jax.tree.map(
                lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad)]),
                chaos)
    sharding = lane_sharding(L + pad, pad=True)
    if sharding is not None:
        k_lanes = jax.device_put(k_lanes, sharding)
        s_lanes = jax.device_put(s_lanes, sharding)
        if chaos is not None:
            chaos = jax.device_put(chaos, sharding)
    out = _packet_lanes(pw, k_lanes, s_lanes, m_nodes, ring, chaos,
                        step_impl=step_impl)
    return jax.tree.map(lambda x: np.asarray(x)[:L], out)


# --------------------------------------------------------------------------
# Cohort layer: the workload axis (repro.core.cohort).
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("m_nodes", "ring", "step_impl"))
def _packet_cohort_lanes(spw, k_lanes, s_lanes, m_nodes, ring, chaos=None,
                         step_impl="xla"):
    """[W]-stacked workloads x [W, L] lanes: one program, W * L experiments.

    The outer vmap batches the PackedWorkload operand itself
    (in_axes=(0, 0, 0, None, None)); the inner vmap is the existing lane
    axis. Static aux (n_types, n_jobs) is shared by construction
    (`repro.core.cohort.stack_workloads` validates), so the jit cache keys
    on one shape for the whole cohort.

    `chaos` leaves are [L] and SHARED across the workload axis (common
    random numbers: every member sees the same per-lane fault stream, so
    cross-workload comparisons at a grid cell difference out the draws).

    ``step_impl="pallas"`` unrolls the (small, static) workload axis into
    one fused-kernel lane dispatch per member inside the same program —
    the kernel batches lanes, not workload tables, so each member keeps
    its own prefix tables as kernel operands.
    """
    if step_impl == "pallas":
        rows = []
        for w in range(int(k_lanes.shape[0])):
            pw_w = jax.tree.map(lambda x, w=w: x[w], spw)
            res = simulate_packet_scan_lanes(
                pw_w, k_lanes[w], s_lanes[w], m_nodes, ring=ring,
                chaos=chaos, step_impl="pallas")
            rows.append(jax.vmap(
                lambda r, p=pw_w: efficiency_metrics(
                    p.submit, r, m_nodes, p.t_last_submit))(res))
        return jax.tree.map(lambda *x: jnp.stack(x), *rows)
    if chaos is None:
        lanes = jax.vmap(_one_experiment_scan,
                         in_axes=(None, 0, 0, None, None))
        return jax.vmap(lanes, in_axes=(0, 0, 0, None, None))(
            spw, k_lanes, s_lanes, m_nodes, ring)
    lanes = jax.vmap(_one_experiment_scan,
                     in_axes=(None, 0, 0, None, None, 0))
    return jax.vmap(lanes, in_axes=(0, 0, 0, None, None, None))(
        spw, k_lanes, s_lanes, m_nodes, ring, chaos)


# NOTE: there is deliberately no while-engine cohort kernel. Vmapping
# `simulate_packet` over the workload axis (one (k, s) cell at a time,
# in_axes=(0, None, 0, None, None)) is bitwise-correct but measured ~4x
# SLOWER than per-workload sequential dispatch on one CPU device even at
# W = 3: the event loop's gather/scatter body vectorizes as badly over
# workloads as it did over lanes (the PR-1 fused-engine regression), and
# lockstep iteration pays the slowest member's event count in every cell.
# Small cohort studies therefore resolve to "seq" = per-workload delegation.


def cohort_lane_sharding(n_lanes: int, pad: bool = False):
    """NamedSharding for a [W, lanes] cohort batch: lane axis split over all
    local devices, workload axis replicated.

    Same contract as `lane_sharding` (None on one device; ``pad=True``
    declares the caller padded the lane axis with `lane_padding` sentinel
    lanes), but with a leading unsharded workload dimension — every device
    computes all W workloads over its slice of lanes, so cohort and
    single-workload fused dispatches balance identically.
    """
    devices = jax.devices()
    if len(devices) <= 1:
        return None
    if not pad and n_lanes % len(devices) != 0:
        return None
    mesh = jax.sharding.Mesh(np.asarray(devices), ("lane",))
    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(None, "lane"))


def _run_cohort_chunks(spw, k_l2, s_l2, m_nodes, ring, chunk: int,
                       chaos=None, step_impl="xla"):
    """Sorted chunks of every member's lanes, interleaved without syncs.

    The measured single-device cohort layout. Workload-fusing each chunk
    into a [W, width] block (`_packet_cohort_lanes` on narrow slices) was
    tried first and LOSES on CPU for paper-sized jobs counts: every scan
    step then walks W workloads' per-type tables (W x ~N floats), which
    falls out of cache — 1.4x slower than per-workload dispatch at
    N = 2500 on a 2-core CPU, the same locality cliff that made PR 3
    chunk the lane axis. Instead each member's lanes run through the
    single-workload chunk kernel (`_packet_lanes`, device-side row slices
    of the stacked operand, so the jit cache is shared with
    `run_packet_grid`), and the whole W x n_chunks dispatch sequence is
    issued WITHOUT host syncs: outputs stay on device until the caller's
    final conversion, so chunk c+1 (and workload w+1) enqueue while c
    still computes, where the sequential driver blocks per chunk.

    Lane order is computed once from the first member's (k, s) row and
    shared: the k grid is identical across members and init times differ
    only by a positive per-workload scalar (s_w = S/(1-S) * mean(e_w)), so
    the k * s event-count proxy sorts every row identically.
    """
    W, L = int(k_l2.shape[0]), int(k_l2.shape[1])
    n_chunks = max(1, -(-L // max(1, chunk)))
    width = -(-L // n_chunks)
    order = lane_order(np.asarray(k_l2[0]), np.asarray(s_l2[0]))
    slices = []
    for c in range(0, L, width):
        idx = order[c:c + width]
        pad = width - len(idx)
        if pad:
            idx = np.concatenate([idx, np.repeat(idx[-1:], pad)])
        slices.append((idx, pad))
    rows = []
    for w in range(W):
        pw_w = jax.tree.map(lambda x: x[w], spw)
        chunks = [jax.tree.map(
            lambda x: x[:width - pad] if pad else x,
            _packet_lanes(pw_w, k_l2[w, idx], s_l2[w, idx], m_nodes, ring,
                          None if chaos is None else jax.tree.map(
                              lambda x: jnp.asarray(x)[idx], chaos),
                          step_impl=step_impl))
            for idx, pad in slices]
        rows.append(jax.tree.map(lambda *x: jnp.concatenate(x), *chunks))
    gathered = jax.tree.map(lambda *x: jnp.stack(x), *rows)
    inv = jnp.asarray(np.argsort(order, kind="stable"))
    return jax.tree.map(lambda x: x[:, inv], gathered)


def _run_cohort_fused(spw, k_l2, s_l2, m_nodes, ring, chaos=None,
                      step_impl="xla"):
    """All W x L lanes in one dispatch; lane axis padded + sharded."""
    L = int(k_l2.shape[1])
    pad = lane_padding(L)
    if pad:
        k_l2 = jnp.concatenate(
            [k_l2, jnp.repeat(k_l2[:, -1:], pad, axis=1)], axis=1)
        s_l2 = jnp.concatenate(
            [s_l2, jnp.repeat(s_l2[:, -1:], pad, axis=1)], axis=1)
        if chaos is not None:
            chaos = jax.tree.map(
                lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad)]),
                chaos)
    sharding = cohort_lane_sharding(L + pad, pad=True)
    if sharding is not None:
        k_l2 = jax.device_put(k_l2, sharding)
        s_l2 = jax.device_put(s_l2, sharding)
        if chaos is not None:
            # chaos leaves are [L]: shard with the 1-D lane sharding that
            # matches the inner (lane) axis of the [W, L] operands
            chaos = jax.device_put(chaos, lane_sharding(L + pad, pad=True))
    out = _packet_cohort_lanes(spw, k_l2, s_l2, m_nodes, ring, chaos,
                               step_impl=step_impl)
    return jax.tree.map(lambda x: np.asarray(x)[:, :L], out)


def run_cohort_grid(cohort, ks: Sequence[float] = PAPER_SCALE_RATIOS,
                    s_props: Sequence[float] = PAPER_INIT_PROPS,
                    mode: str = "auto",
                    chunk_lanes: int | None = None,
                    chaos: ChaosConfig | None = None,
                    on_budget_exhausted: str = "raise",
                    step_impl: str = "xla") -> dict:
    """Per-workload [K, S] Metrics for every member of a `WorkloadCohort`,
    computed as ONE batched study over the stacked workload axis.

    Returns ``{name: Metrics}`` with leaves of shape [len(ks), len(s_props)]
    (``[K, S, C]`` when `chaos` carries a C-long fault-parameter axis) —
    each entry identical (lane for lane) to
    ``run_packet_grid(wl, ks, s_props, dtype=cohort.dtype)``, because the
    cohort kernel batches the same scan engine over an extra workload axis
    and per-lane results are independent of dispatch grouping (the cohort
    equivalence suite pins this bitwise in both dtypes). The chaos lane
    stream is shared across members (lane ids are assigned per grid cell,
    not per workload), so cohort and per-workload runs agree exactly and
    cross-workload comparisons use common random numbers.

    Modes are the sweep layouts applied to the [W, L] study: ``"chunked"``
    dispatches sorted [W, width] blocks, ``"fused"`` runs one padded +
    sharded program, ``"seq"`` delegates to per-workload sequential
    dispatch (the pre-cohort driver layout — the measured-fastest choice
    for studies too small to amortize batching; see the no-while-kernel
    note above), and ``"auto"`` resolves from the TOTAL experiment count
    W * L (`resolve_mode`). The legacy vmap_k/vmap_s layouts have no
    cohort form. Init proportions are converted per member (s depends on
    each workload's mean runtime), so the [W, L] init-time operand
    genuinely varies across the workload axis.
    """
    if chaos_is_inert(chaos):
        chaos = None        # zero-rate config: run the exact pre-chaos trace
    K, S = len(ks), len(s_props)
    W = cohort.n_workloads
    resolved = resolve_mode(mode, K * S, W, step_impl)
    if resolved in ("vmap_k", "vmap_s"):
        raise ValueError(
            f"mode {resolved!r} has no cohort layout; use run_packet_grid "
            f"per workload for the legacy column/row batchings")
    if resolved == "seq":
        return {name: run_packet_grid(wl, ks, s_props, dtype=cohort.dtype,
                                      mode="seq", chaos=chaos,
                                      on_budget_exhausted=on_budget_exhausted,
                                      step_impl=step_impl)
                for name, wl in zip(cohort.names, cohort.workloads)}

    dtype = cohort.dtype
    with precision.dtype_scope(dtype):
        spw = cohort.pack()
        m_nodes, ring = cohort.m_nodes, cohort.ring
        ks_arr = jnp.asarray(ks, dtype)
        s_mat = jnp.stack([jnp.asarray(
            [wl.init_time_for_proportion(p) for p in s_props], dtype)
            for wl in cohort.workloads])                    # [W, S]
        k_l2 = jnp.broadcast_to(jnp.repeat(ks_arr, S), (W, K * S))
        s_l2 = jnp.tile(s_mat, (1, K))
        chaos_l, C = (None, 1) if chaos is None else chaos_lane_grid(
            chaos, K * S, dtype)
        if C > 1:
            k_l2 = jnp.repeat(k_l2, C, axis=1)
            s_l2 = jnp.repeat(s_l2, C, axis=1)
        if resolved == "chunked":
            lanes = _run_cohort_chunks(
                spw, k_l2, s_l2, m_nodes, ring,
                max(1, int(chunk_lanes or CHUNK_LANES)), chaos_l,
                step_impl)
        else:                   # fused
            lanes = _run_cohort_fused(spw, k_l2, s_l2, m_nodes, ring,
                                      chaos_l, step_impl)
        shape = (W, K, S) if C == 1 else (W, K, S, C)
        grids = jax.tree.map(
            lambda x: np.asarray(x).reshape(shape + x.shape[2:]), lanes)
        out = {name: jax.tree.map(lambda x, w=w: x[w], grids)
               for w, name in enumerate(cohort.names)}
        for name, m in out.items():
            _enforce_budget(m, on_budget_exhausted,
                            f"run_cohort_grid[{name}]", ks, s_props)
        return out


def run_packet_grid(wl: Workload,
                    ks: Sequence[float] = PAPER_SCALE_RATIOS,
                    s_props: Sequence[float] = PAPER_INIT_PROPS,
                    dtype=jnp.float32,
                    vmap_s: bool = False,
                    vmap_k: bool = False,
                    mode: str = "auto",
                    chunk_lanes: int | None = None,
                    chaos: ChaosConfig | None = None,
                    on_budget_exhausted: str = "raise",
                    step_impl: str = "xla") -> Metrics:
    """Metrics over the (scale ratio x init proportion) grid of one workload.

    Returns a Metrics pytree whose leaves have shape [len(ks), len(s_props)],
    or ``[len(ks), len(s_props), C]`` when `chaos` carries a C-long
    fault-parameter axis (`chaos_axis_len`) — the chaos axis is a third
    lane dimension, swept at full batched throughput. Lane ids are assigned
    in grid order before any dispatch-layout reshuffling, so seq, chunked
    and fused produce bit-identical chaos draws. `on_budget_exhausted`
    ("raise" | "warn" | "ignore") governs lanes whose schedules were
    truncated by the event budget (`Metrics.budget_exhausted`).

    Modes (see the module docstring for the layouts): ``"seq"``,
    ``"chunked"``, ``"fused"``, ``"auto"`` (device/lane-count heuristic via
    `resolve_mode`), plus the legacy ``vmap_k=True`` / ``vmap_s=True``
    column/row batchings kept for A/B comparison (passing both is an
    error — previously vmap_k silently won).

    All paths share module-level compile caches keyed on workload shape, so
    repeated calls (and the paper's 6 same-shape workflows) never retrace.
    jit caches are additionally keyed on dtype (via input avals and the x64
    trace context), so float32 and float64 sweeps coexist without retracing
    each other.

    `dtype=jnp.float64` is the precision opt-in: the whole sweep runs inside
    `precision.dtype_scope`, leaving the session's global x64 state alone.
    `chunk_lanes` overrides the chunked-mode dispatch width (default
    CHUNK_LANES).
    """
    if vmap_k and vmap_s:
        raise ValueError("vmap_k=True and vmap_s=True are mutually "
                         "exclusive batching layouts; pass at most one "
                         "(or use mode='fused' for the full lane axis)")
    if (vmap_k or vmap_s) and mode != "auto":
        raise ValueError("pass either mode= or the legacy vmap_k/vmap_s "
                         "flags, not both")
    if chaos is not None and (vmap_k or vmap_s):
        raise ValueError("chaos sweeps have no vmap_k/vmap_s layout; use "
                         "mode='seq'/'chunked'/'fused'")
    _check_step_impl(step_impl)
    if step_impl == "pallas" and (vmap_k or vmap_s):
        raise ValueError("the legacy vmap_k/vmap_s layouts are XLA-only; "
                         "use mode='seq'/'chunked'/'fused' with "
                         "step_impl='pallas'")
    if chaos_is_inert(chaos):
        chaos = None        # zero-rate config: run the exact pre-chaos trace
    K, S = len(ks), len(s_props)
    if vmap_k:
        mode = "vmap_k"
    elif vmap_s:
        mode = "vmap_s"
    else:
        mode = resolve_mode(mode, K * S * chaos_axis_len(chaos),
                            step_impl=step_impl)

    with precision.dtype_scope(dtype):
        pw = pack_workload(wl, dtype)
        m_nodes = int(wl.params.nodes)
        ring = resolve_ring(m_nodes, pw.n_jobs)
        s_vals = jnp.asarray(
            [wl.init_time_for_proportion(p) for p in s_props], dtype)
        ks_arr = jnp.asarray(ks, dtype)

        if mode == "vmap_k":
            cols = [_packet_k_column(pw, ks_arr, s, m_nodes, ring)
                    for s in s_vals]
            stacked = jax.tree.map(lambda *x: jnp.stack(x, axis=1), *cols)
            return jax.tree.map(np.asarray, stacked)
        if mode == "vmap_s":
            rows = [_packet_s_row(pw, k, s_vals, m_nodes, ring)
                    for k in ks_arr]
            stacked = jax.tree.map(lambda *x: jnp.stack(x, axis=0), *rows)
            return jax.tree.map(np.asarray, stacked)

        chaos_l, C = (None, 1) if chaos is None else chaos_lane_grid(
            chaos, K * S, dtype)
        shape = (K, S) if C == 1 else (K, S, C)
        if mode == "seq":
            if chaos is None:
                cells = [_packet_one(pw, k, s, m_nodes, ring,
                                     step_impl=step_impl)
                         for k in ks_arr for s in s_vals]
            else:
                # the scan engine, one flat lane at a time — same engine
                # and lane ids as the batched layouts, so chaos draws and
                # float rounding match the chunked/fused modes exactly
                cells = [_packet_one(pw, ks_arr[i // (S * C)],
                                     s_vals[(i // C) % S], m_nodes, ring,
                                     _chaos_cell(chaos_l, i),
                                     step_impl=step_impl)
                         for i in range(K * S * C)]
            stacked = jax.tree.map(lambda *x: jnp.stack(x), *cells)
            out = jax.tree.map(
                lambda x: np.asarray(x).reshape(shape + x.shape[1:]),
                stacked)
            _enforce_budget(out, on_budget_exhausted, "run_packet_grid",
                            ks, s_props)
            return out

        # batched lane layouts over the scan engine
        k_lanes = jnp.repeat(ks_arr, S * C)
        s_lanes = jnp.repeat(jnp.tile(s_vals, K), C)
        if mode == "chunked":
            lanes = _run_lane_chunks(pw, k_lanes, s_lanes, m_nodes, ring,
                                     max(1, int(chunk_lanes or CHUNK_LANES)),
                                     chaos_l, step_impl)
        else:                       # fused
            lanes = _run_lanes_fused(pw, k_lanes, s_lanes, m_nodes, ring,
                                     chaos_l, step_impl)
        out = jax.tree.map(
            lambda x: np.asarray(x).reshape(shape + x.shape[1:]), lanes)
        _enforce_budget(out, on_budget_exhausted, "run_packet_grid",
                        ks, s_props)
        return out


def run_window_oracle(pw: PackedWorkload,
                      ks: Sequence[float],
                      s_init: float,
                      m_nodes: int,
                      ring: int | None = None,
                      mode: str = "auto",
                      chunk_lanes: int | None = None,
                      chaos: ChaosConfig | None = None,
                      on_budget_exhausted: str = "raise",
                      step_impl: str = "xla") -> Metrics:
    """One control tick of the streaming service: all candidate scale
    ratios on a pre-packed workload window, as one batched lane program.

    This is `run_packet_grid` re-cut for the monitor → decide → actuate
    loop of `repro.service`: the caller owns packing (windows arrive
    already packed, via `pack_workload` on a `slice_window` output) and
    passes ONE init time `s_init` in seconds (typically from the monitor's
    windowed runtime signal, not a whole s_props axis), so the returned
    Metrics leaves are [len(ks)] — the tick's tuning curve. Because the
    windowing layer holds `window_jobs` fixed, every tick shares the
    packed shapes and the module-level jit caches (`_packet_lanes` /
    `_packet_one`): the lane program traces on the first tick and only
    dispatches afterwards.

    `chaos` makes the tick fault-aware: a `ChaosConfig` whose fault
    parameters carry a C-long chaos lane axis (`chaos_axis_len`) expands
    the tick to one fused [K * C] lane program and the returned leaves to
    ``[len(ks), C]`` — per candidate k, the wait / lost_work /
    useful_util / requeued_jobs cells across every fault regime, from ONE
    dispatch. Lane ids follow `chaos_lane_grid` grid order (k-major,
    chaos-minor), exactly the ids `run_packet_grid(ks, s_props=[s],
    chaos=...)` assigns, so the oracle's [K, C] block is bitwise the
    grid driver's ``[:, 0, :]`` chaos column (tests/test_service.py pins
    this in both dtypes). An inert config (zero failure and straggler
    rates) is normalized to None and runs the exact fault-free program;
    a scalar active config keeps [K] leaves (C == 1).

    Dtype follows the packed window (pack under `precision.dtype_scope`
    for float64); the sweep re-enters that scope here so a float64 service
    loop never leaks global x64 state. Modes as in `run_packet_grid`
    minus the legacy vmap layouts ("auto" resolves over the K * C lanes
    of this single tick).
    """
    dtype = np.dtype(pw.submit.dtype)
    K = len(ks)
    if K < 1:
        raise ValueError("run_window_oracle needs at least one candidate k")
    if chaos_is_inert(chaos):
        chaos = None        # zero-rate config: run the exact pre-chaos trace
    C = chaos_axis_len(chaos)
    resolved = resolve_mode(mode, K * C, step_impl=step_impl)
    if resolved in ("vmap_k", "vmap_s"):
        raise ValueError(
            f"mode={resolved!r} is a grid layout; the window oracle has a "
            "single lane axis — use 'auto', 'seq', 'chunked' or 'fused'")
    with precision.dtype_scope(dtype):
        m_nodes = int(m_nodes)
        ring = resolve_ring(m_nodes, pw.n_jobs) if ring is None else int(ring)
        chaos_l = (None if chaos is None
                   else chaos_lane_grid(chaos, K, dtype)[0])
        k_lanes = jnp.repeat(jnp.asarray(ks, dtype), C)
        s_lanes = jnp.full((K * C,), s_init, dtype)
        if resolved == "seq":
            cells = [_packet_one(pw, k_lanes[i], s_lanes[i], m_nodes, ring,
                                 None if chaos_l is None
                                 else _chaos_cell(chaos_l, i),
                                 step_impl=step_impl)
                     for i in range(K * C)]
            lanes = jax.tree.map(lambda *x: jnp.stack(x), *cells)
        elif resolved == "chunked":
            lanes = _run_lane_chunks(pw, k_lanes, s_lanes, m_nodes, ring,
                                     max(1, int(chunk_lanes or CHUNK_LANES)),
                                     chaos_l, step_impl)
        else:                       # fused
            lanes = _run_lanes_fused(pw, k_lanes, s_lanes, m_nodes, ring,
                                     chaos_l, step_impl)
        shape = (K,) if C == 1 else (K, C)
        out = jax.tree.map(
            lambda x: np.asarray(x).reshape(shape + x.shape[1:]), lanes)
        _enforce_budget(out, on_budget_exhausted, "run_window_oracle", ks,
                        axis_names=("i_k", "i_chaos"))
        return out


def run_baselines(wl: Workload, s_props: Sequence[float] = PAPER_INIT_PROPS,
                  dtype=jnp.float32) -> dict[str, Metrics]:
    """FCFS and EASY-backfill metrics per init proportion (rigid jobs).

    Both baselines and all init proportions run as one batched program.
    `dtype=jnp.float64` opts into the scoped x64 mode, as in
    `run_packet_grid`.
    """
    with precision.dtype_scope(dtype):
        pw = pack_workload(wl, dtype)
        m_nodes = int(wl.params.nodes)
        ring = resolve_ring(m_nodes, pw.n_jobs)
        s_vals = jnp.asarray(
            [wl.init_time_for_proportion(p) for p in s_props], dtype)
        out = _baseline_lanes(pw, s_vals, m_nodes, ring)
        return {name: jax.tree.map(np.asarray, m) for name, m in out.items()}


class PlateauResult(NamedTuple):
    """`plateau_threshold` output: the tuned scale ratio AND the plateau
    level it converged to, so callers can sanity-check flip-prone cells
    (a float32 near-tie cascade moves `plateau`, not just `threshold`)."""
    threshold: float    # smallest k after which avg_wait stays near plateau
    plateau: float      # the large-k plateau value (median of the tail)


def plateau_threshold(ks, avg_wait, rel_tol: float = 0.05,
                      abs_tol: float | None = None,
                      plateau_tail: int = 5) -> PlateauResult:
    """The paper's actionable output: the smallest scale ratio after which
    the average queue time stays within tolerance of its large-k plateau.

    `ks` need not arrive sorted — both arrays are sorted together by k
    (the plateau is a large-k property, so order matters); mismatched or
    empty inputs raise. The tolerance band is
    ``rel_tol * max(plateau, 1) + abs_tol`` where `abs_tol` defaults to
    ``FLOAT32_AVG_WAIT_RTOL * max(plateau, 1)`` — the measured float32
    rounding envelope from the BENCH_dtype study — instead of the previous
    hard-coded 1.0 s, so the slack scales with the metric rather than
    assuming second-scale waits.
    """
    ks = np.atleast_1d(np.asarray(ks, np.float64))
    w = np.atleast_1d(np.asarray(avg_wait, np.float64))
    if ks.ndim != 1 or ks.shape != w.shape:
        raise ValueError(f"ks and avg_wait must be equal-length 1-D arrays, "
                         f"got shapes {ks.shape} and {w.shape}")
    if ks.size == 0:
        raise ValueError("plateau_threshold needs at least one scale ratio")
    order = np.argsort(ks, kind="stable")
    ks, w = ks[order], w[order]
    tail = max(1, min(int(plateau_tail), len(w)))
    plateau = float(np.median(w[-tail:]))
    ref = max(plateau, 1e-9)
    if abs_tol is None:
        abs_tol = FLOAT32_AVG_WAIT_RTOL * max(ref, 1.0)
    good = np.abs(w - plateau) <= rel_tol * max(ref, 1.0) + abs_tol
    # find first index from which all subsequent are good
    for i in range(len(ks)):
        if good[i:].all():
            return PlateauResult(float(ks[i]), plateau)
    return PlateauResult(float(ks[-1]), plateau)
