"""Workload cohorts: the workload axis of the batched sweep.

The paper's study is 6 workflows x 37 scale ratios x 6 init proportions =
1332 experiments. PR 3 batched the (k x S) grid of ONE workload into a
222-lane program; this module batches the *workload* axis on top, so the
whole study runs as a handful of fused XLA programs instead of 6 sequential
per-workflow sweeps.

Two workloads can share one program iff their compile-time statics match:
cluster size M (a scalar operand whose value is shared by every lane of a
dispatch), job count N and type count H (array shapes), the simulation
dtype (jit cache key + x64 trace context), and the running-group ring size
(loop-carried shape, derived ``min(M, N)``). `cohort_key` captures exactly
that tuple; `group_workloads` partitions a named workload dict by it. The
paper's 6 flows form exactly two cohorts under the default precision policy
of benchmarks/paper_sweep.py:

  * 3 heterogeneous flows — M=500, N=5000, float64 (near-tie cascades make
    float32 schedules chaotic; see BENCH_dtype.json),
  * 3 homogeneous flows  — M=100, N=5000, float32.

`stack_workloads` packs each member (`repro.core.des.pack_workload`) and
stacks the `PackedWorkload` pytrees along a new leading axis; the result is
a valid PackedWorkload whose array leaves carry shape [W, ...] and whose
static aux (n_types, n_jobs) is the shared value. `simulate_packet_scan`
takes the packed workload as an operand, so
``jax.vmap(..., in_axes=(0, 0, 0, None, None))`` over (pw, k, s) — nested
over the existing lane vmap — yields one program covering W x lanes
experiments without replicating any workload table per lane
(`repro.core.sweep._packet_cohort_lanes` / `run_cohort_grid`).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision
from repro.core.des import (ChaosConfig, PackedWorkload, chaos_is_inert,
                            pack_workload, resolve_max_requeues,
                            resolve_ring)
from repro.workload.lublin import Workload, workload_statics


class CohortKey(NamedTuple):
    """Compile-time statics shared by every member of a cohort."""
    m_nodes: int     # cluster size M (scalar operand, same for all lanes)
    n_jobs: int      # N: array shapes + event budget
    n_types: int     # H: per-type table shapes
    dtype: str       # simulation precision (jit cache key / x64 context)
    ring: int        # running-group buffer size (loop-carried shape)
    # requeue-round bound R (0 without chaos): sizes the group log (N + R)
    # and the event budget, so it is a compile-time static like N. Appended
    # last with a default so pre-chaos positional construction still works.
    # The per-member requeue credit (des.py "requeue") adds only O(H + ring)
    # span/residual state — no [N] member arrays and no new capacity — so
    # R and the ring size remain the only chaos-dependent statics.
    max_requeues: int = 0


def cohort_key(wl: Workload, dtype=np.float32,
               chaos: ChaosConfig | None = None) -> CohortKey:
    """The statics tuple deciding which stacked program a workload joins.

    A `chaos` config contributes its resolved requeue bound
    (`resolve_max_requeues`): two workloads can share a chaos sweep's
    program only if their log/budget shapes — which grow with R — match.
    Inert configs (all-zero rates) normalize to no-chaos, R = 0, matching
    the run drivers' normalization.
    """
    if chaos_is_inert(chaos):
        chaos = None
    m_nodes, n_jobs, n_types = workload_statics(wl)
    return CohortKey(m_nodes, n_jobs, n_types, np.dtype(dtype).name,
                     resolve_ring(m_nodes, n_jobs),
                     resolve_max_requeues(chaos, n_jobs))


@dataclasses.dataclass(frozen=True)
class WorkloadCohort:
    """Named workloads sharing one CohortKey, ready to run as one program."""
    names: tuple[str, ...]
    workloads: tuple[Workload, ...]
    key: CohortKey

    @property
    def n_workloads(self) -> int:
        return len(self.names)

    @property
    def m_nodes(self) -> int:
        return self.key.m_nodes

    @property
    def n_jobs(self) -> int:
        return self.key.n_jobs

    @property
    def ring(self) -> int:
        return self.key.ring

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self.key.dtype)

    @property
    def label(self) -> str:
        """Stable provenance label, e.g. ``M100-N5000-float32``."""
        return f"M{self.key.m_nodes}-N{self.key.n_jobs}-{self.key.dtype}"

    def pack(self) -> PackedWorkload:
        """Members packed and stacked along a leading [W] workload axis.

        Cached on first use: members and dtype are immutable, so repeated
        studies over one cohort (different grids, modes, or the chunked
        path's per-member row slices) skip the host repack and re-upload
        the old per-workload driver paid on every `run_packet_grid` call.
        """
        cached = self.__dict__.get("_packed")
        if cached is None:
            cached = stack_workloads(self.workloads, self.dtype)
            object.__setattr__(self, "_packed", cached)
        return cached


def stack_workloads(workloads: Sequence[Workload],
                    dtype=np.float32) -> PackedWorkload:
    """Pack same-static workloads and stack them along a leading axis.

    The result is a PackedWorkload whose array leaves have shape [W, ...]
    (including the scalar `t_last_submit`, which becomes [W]) and whose
    static aux is the shared (n_types, n_jobs) — i.e. a batched operand for
    ``jax.vmap(simulate_packet_scan, in_axes=(0, ...))``. Mismatched statics
    raise immediately with the offending field named, instead of surfacing
    as an opaque pytree/shape error inside jit.

    float64 stacking enters the scoped x64 opt-in itself (nesting is safe),
    so standalone callers need no extra `precision.dtype_scope`.
    """
    if not workloads:
        raise ValueError("stack_workloads needs at least one workload")
    stats = [workload_statics(wl) for wl in workloads]
    for i, field in enumerate(("m_nodes", "n_jobs", "n_types")):
        vals = sorted({s[i] for s in stats})
        if len(vals) > 1:
            raise ValueError(
                f"cannot stack workloads with mismatched {field}: {vals}; "
                f"split them into compatible cohorts with group_workloads()")
    with precision.dtype_scope(dtype):
        pws = [pack_workload(wl, dtype) for wl in workloads]
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *pws)


def group_workloads(flows: Mapping[str, Workload],
                    dtypes=np.float32,
                    chaos: ChaosConfig | None = None) -> list[WorkloadCohort]:
    """Partition named workloads into batch-compatible cohorts.

    ``dtypes`` is either one dtype for every workload or a mapping
    ``name -> dtype`` (e.g. the per-workload precision policy of
    benchmarks/paper_sweep.py, which runs heterogeneous flows in float64).
    ``chaos`` (when the study is a fault sweep) folds the requeue bound into
    each key, since it changes the compiled log/budget shapes. Cohorts come
    back in first-member insertion order, and members keep their insertion
    order within each cohort, so provenance and result files are stable
    across runs.
    """
    if isinstance(dtypes, Mapping):
        missing = [n for n in flows if n not in dtypes]
        if missing:
            raise ValueError(f"no dtype given for workloads {missing}")
        dtype_of = lambda name: np.dtype(dtypes[name])
    else:
        dtype_of = lambda name: np.dtype(dtypes)

    members: dict[CohortKey, list[tuple[str, Workload]]] = {}
    for name, wl in flows.items():
        members.setdefault(cohort_key(wl, dtype_of(name), chaos), []).append(
            (name, wl))
    return [WorkloadCohort(names=tuple(n for n, _ in mem),
                           workloads=tuple(w for _, w in mem), key=key)
            for key, mem in members.items()]
