"""Pure functions of the Packet group-scheduling policy (paper §5).

These are the policy formulas shared by the discrete-event simulator
(`repro.core.des`), the Pallas kernel (`repro.kernels.packet_select`) and the
ML-cluster integration (`repro.cluster`):

  * queue weight      W(T_j) = C_j * P_j * (1 + T_cur_j / T_max_j),
                      C_j = (sum of queued work) / s_j
  * group node count  m_threshold = ceil(sum_work / (k * s_j)),
                      m_group = min(m_threshold, m_free)
  * group duration    d = s_j + sum_work / m_group

Paper's worked example (Fig. 3): s = 1 min, total work 4 node-minutes:
k = 0.5 -> 8 nodes, k = 1 -> 4 nodes, k = 2 -> 2 nodes, k = 4 -> 1 node.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -jnp.inf


def queue_weights(sum_work, s_j, priority, oldest_submit, now, t_max,
                  nonempty):
    """Vector of Packet queue weights over the h job types (paper Step 2).

    Args:
      sum_work:      [H] total queued single-node work per type (sum e_i).
      s_j:           [H] initialization time per type.
      priority:      [H] job-type priority P_j.
      oldest_submit: [H] submit time of the first (oldest) queued job.
      now:           scalar, current simulation time.
      t_max:         [H] wait-normalization constant T_j^max.
      nonempty:      [H] bool, queue has jobs.

    Returns [H] weights, -inf for empty queues.
    """
    c_j = sum_work / jnp.maximum(s_j, 1e-9)
    t_cur = jnp.maximum(now - oldest_submit, 0.0)
    w = c_j * priority * (1.0 + t_cur / jnp.maximum(t_max, 1e-9))
    return jnp.where(nonempty, w, NEG_INF)


def m_threshold(sum_work, k, s_j):
    """Nodes so the group's execution time is ~= k x its init time (Step 4)."""
    m = jnp.ceil(sum_work / (jnp.maximum(k, 1e-9) * jnp.maximum(s_j, 1e-9)))
    return jnp.maximum(m, 1.0).astype(jnp.int32)


def group_nodes(sum_work, k, s_j, m_free):
    """m_group = min(m_threshold, m_free); 0 if no free nodes."""
    m = jnp.minimum(m_threshold(sum_work, k, s_j), m_free)
    return jnp.maximum(m, 0)


def group_duration(sum_work, s_j, m_group):
    """Initialization once, then all jobs back-to-back with linear speed-up."""
    return s_j + sum_work / jnp.maximum(m_group, 1).astype(sum_work.dtype)
