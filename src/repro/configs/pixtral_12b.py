"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128
(mistral-nemo). The vision frontend is a STUB: input_specs() provides
n_prefix=1024 precomputed patch embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    rope_theta=1_000_000.0, embeds_input=True, n_prefix=1024,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
)
