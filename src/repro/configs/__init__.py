"""Assigned-architecture configs + input-shape cells.

``get_config(arch_id)`` returns the exact full-size ModelConfig from the
assignment table; ``SHAPES`` are the four input-shape cells. ``cells()``
enumerates the runnable (arch x shape) grid — ``long_500k`` only runs for
sub-quadratic archs (ssm / hybrid), per the assignment (skips recorded in
DESIGN.md / EXPERIMENTS.md).

``input_specs(cfg, shape)`` builds jax.ShapeDtypeStruct stand-ins for every
model input of that cell — weak-type-correct, no device allocation — for the
multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, reduced

ARCHS: tuple[str, ...] = (
    "qwen2-moe-a2.7b", "arctic-480b", "yi-6b", "phi3-medium-14b",
    "granite-3-2b", "starcoder2-7b", "xlstm-1.3b", "pixtral-12b",
    "recurrentgemma-2b", "seamless-m4t-large-v2",
)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}

# sub-quadratic archs that run the 500k-context decode cell
LONG_CONTEXT_ARCHS = ("xlstm-1.3b", "recurrentgemma-2b")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def cells(archs=ARCHS, shapes=None) -> list[tuple[str, str]]:
    """The assigned (arch x shape) grid — 40 cells."""
    out = []
    for a in archs:
        for s in (shapes or SHAPES):
            if s == "long_500k" and a not in LONG_CONTEXT_ARCHS:
                continue   # pure full-attention arch: assignment-directed skip
            out.append((a, s))
    return out


def skipped_cells(archs=ARCHS) -> list[tuple[str, str, str]]:
    return [(a, "long_500k",
             "quadratic full attention at 524288 ctx; assignment directs skip")
            for a in archs if a not in LONG_CONTEXT_ARCHS]


def input_specs(cfg: ModelConfig, shape: Shape, dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell."""
    B, S = shape.batch, shape.seq
    f = jnp.dtype(cfg.compute_dtype)
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": tok((B, S))}
        if cfg.family == "encdec":
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
        elif cfg.embeds_input and cfg.n_prefix:
            specs["embeds"] = jax.ShapeDtypeStruct((B, cfg.n_prefix,
                                                    cfg.d_model), f)
        if shape.kind == "train":
            specs["labels"] = tok((B, S))
        return specs
    # decode: one new token against a cache of length S (built separately)
    return {"tokens": tok((B, 1))}


def smoke_config(arch: str, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return reduced(get_config(arch), **overrides)
