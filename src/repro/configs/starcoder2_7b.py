"""starcoder2-7b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]
32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
36 heads do not divide TP=16 -> dp_batch attention."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    rope_theta=100_000.0, mlp_type="gelu", norm_type="layernorm",
    param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
)
