"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal. [arXiv:2308.11596; hf]
24L (24 enc + 24 dec) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206
(padded to 256208). The speech frontend is a STUB: input_specs() provides
precomputed frame embeddings for the encoder."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    n_enc_layers=24, n_dec_layers=24,
    rope_theta=0.0, mlp_type="gelu", norm_type="layernorm",
    embeds_input=True,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
)
