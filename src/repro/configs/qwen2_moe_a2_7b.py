"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf] 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936. Shared-expert branch = 4 x 1408 = 5632 (HF
shared_expert_intermediate_size).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    n_experts=60, experts_per_token=4, expert_d_ff=1408,
    shared_expert_d_ff=5632,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
)
