"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2. [arXiv:2402.19427; hf]
26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, lru width 2560,
window 2048, pattern (rec, rec, attn) cycled (26 = 8*3 + 2).
Sub-quadratic (windowed) -> runs the long_500k cell."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"), local_window=2048, d_rnn=2560,
    conv_width=4, rope_theta=10_000.0, mlp_type="gelu",
    param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
)
