"""arctic-480b [moe] — 128 experts top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000. Dense-MoE hybrid: every layer sums a dense FFN
(d_ff=4864) residual branch with the 128-expert top-2 MoE.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    n_experts=128, experts_per_token=2, expert_d_ff=4864,
    dense_residual=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
)
