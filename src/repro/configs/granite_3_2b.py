"""granite-3-2b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]
40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155 (padded to 49168)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=49155,
    rope_theta=10_000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
)
