"""phi3-medium-14b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]
40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
40 heads / kv=10 do not divide TP=16 -> policy resolves batch-parallel
(dp_batch) attention on the production mesh."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab_size=100352,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
)
