"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]
48L d_model=2048 4H vocab=50304, d_ff=0 (projection factor inside blocks).
Pattern: xLSTM[7:1] — 7 mLSTM : 1 sLSTM, repeated 6x. Attention-free ->
runs the long_500k cell with O(1) state."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm_pattern=("m", "m", "m", "m", "m", "m", "m", "s"),
    mlstm_chunk=64, rope_theta=0.0,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat="full",
)
