"""Sweep-layout unit tests: mode resolution/validation, lane scheduling
helpers, padded device sharding, and the hardened `plateau_threshold`.

The padded-sharding test runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the in-process
backend is pinned to one CPU device by conftest), proving the paper's
222-style non-divisible lane count actually shards on a multi-device
backend and returns the same metrics as sequential dispatch.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (PlateauResult, lane_padding, plateau_threshold,
                        resolve_mode, run_packet_grid, sweep_plan)
from repro.core.sweep import (CHUNKED_MIN_LANES, SWEEP_MODES, lane_order,
                              predicted_lane_events)


class TestResolveMode:
    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="unknown sweep mode"):
            resolve_mode("warp", 222)
        with pytest.raises(ValueError, match="available"):
            resolve_mode("Fused", 222)   # case-sensitive: no silent fallback

    def test_explicit_modes_pass_through(self):
        for mode in SWEEP_MODES:
            if mode != "auto":
                assert resolve_mode(mode, 222) == mode

    def test_auto_single_device(self):
        # conftest pins tests to one CPU device: big grids chunk, small seq
        assert resolve_mode("auto", CHUNKED_MIN_LANES) == "chunked"
        assert resolve_mode("auto", 222) == "chunked"
        assert resolve_mode("auto", CHUNKED_MIN_LANES - 1) == "seq"
        assert resolve_mode("auto", 1) == "seq"

    def test_sweep_plan_provenance(self):
        plan = sweep_plan("auto", 222)
        assert plan["requested_mode"] == "auto"
        assert plan["mode"] == resolve_mode("auto", 222)
        assert plan["n_lanes"] == 222
        assert plan["n_workloads"] == 1
        assert plan["total_experiments"] == 222
        assert plan["n_devices"] >= 1
        if plan["mode"] == "chunked":
            assert plan["chunk_lanes"] >= 1

    def test_auto_counts_total_cohort_experiments(self):
        """A cohort crosses the chunked threshold on W * lanes, not lanes:
        a small grid over enough stacked workloads still batches."""
        lanes = CHUNKED_MIN_LANES // 2
        assert resolve_mode("auto", lanes, n_workloads=1) == "seq"
        assert resolve_mode("auto", lanes, n_workloads=2) == "chunked"

    def test_sweep_plan_cohort_layout(self):
        plan = sweep_plan("auto", 222, n_workloads=3)
        assert plan["n_lanes"] == 222
        assert plan["n_workloads"] == 3
        assert plan["total_experiments"] == 666

    def test_run_packet_grid_validates_mode(self, small_workload):
        with pytest.raises(ValueError, match="unknown sweep mode"):
            run_packet_grid(small_workload, ks=[1.0], s_props=[0.05],
                            mode="bogus")


class TestLegacyVmapFlags:
    def test_both_vmap_flags_rejected(self, small_workload):
        """Previously vmap_k silently won; now it is a hard error."""
        with pytest.raises(ValueError, match="mutually exclusive"):
            run_packet_grid(small_workload, ks=[1.0], s_props=[0.05],
                            vmap_k=True, vmap_s=True)

    def test_vmap_flag_plus_mode_rejected(self, small_workload):
        with pytest.raises(ValueError, match="not both"):
            run_packet_grid(small_workload, ks=[1.0], s_props=[0.05],
                            vmap_k=True, mode="seq")


class TestLaneScheduling:
    def test_predictor_monotone_in_k_and_s(self):
        """Predicted event count decreases in both k and s (large k * s
        starves groups of nodes -> few big groups)."""
        ks = np.array([0.1, 1.0, 10.0, 100.0])
        ev_k = predicted_lane_events(ks, np.full(4, 60.0))
        assert (np.diff(ev_k) < 0).all()
        s = np.array([10.0, 60.0, 600.0])
        ev_s = predicted_lane_events(np.full(3, 2.0), s)
        assert (np.diff(ev_s) < 0).all()

    def test_lane_order_is_a_permutation(self):
        k = np.array([100.0, 0.1, 2.0, 2.0])
        s = np.array([60.0, 60.0, 60.0, 10.0])
        order = lane_order(k, s)
        assert sorted(order.tolist()) == [0, 1, 2, 3]
        # longest-predicted lane (smallest k*s) first
        assert order[0] == 1
        assert order[-1] == 0

    def test_lane_padding(self):
        assert lane_padding(222, 1) == 0
        assert lane_padding(222, 2) == 0
        assert lane_padding(222, 4) == 2
        assert lane_padding(222, 8) == 2
        assert lane_padding(4, 4) == 0
        assert lane_padding(1, 4) == 3


class TestPlateauThreshold:
    def test_returns_threshold_and_plateau(self):
        ks = np.array([0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0])
        w = np.array([900.0, 500.0, 120.0, 100.0, 100.0, 100.0, 100.0, 100.0])
        res = plateau_threshold(ks, w)
        assert isinstance(res, PlateauResult)
        assert res.plateau == pytest.approx(100.0)
        # band = 0.05 * 100 + 0.031 * 100 = 8.1: the 120 cell is outside
        assert res.threshold == pytest.approx(4.0)

    def test_unsorted_input_is_sorted_not_garbage(self):
        ks = np.array([0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0])
        w = np.array([900.0, 500.0, 120.0, 100.0, 100.0, 100.0, 100.0, 100.0])
        perm = np.random.default_rng(0).permutation(len(ks))
        assert plateau_threshold(ks[perm], w[perm]) == plateau_threshold(ks, w)

    def test_short_input(self):
        res = plateau_threshold([2.0], [50.0])
        assert res == PlateauResult(2.0, 50.0)
        res = plateau_threshold([1.0, 4.0], [300.0, 100.0])
        assert res.plateau == pytest.approx(np.median([300.0, 100.0]))

    def test_bad_input_raises(self):
        with pytest.raises(ValueError, match="equal-length"):
            plateau_threshold([1.0, 2.0], [1.0, 2.0, 3.0])
        with pytest.raises(ValueError, match="at least one"):
            plateau_threshold([], [])

    def test_abs_tol_parameter(self):
        """The absolute slack is a parameter now (default: the measured
        float32 rounding envelope), not a hard-coded 1 second."""
        ks = np.array([1.0, 2.0, 4.0, 8.0, 16.0, 32.0])
        w = np.array([103.0, 100.0, 100.0, 100.0, 100.0, 100.0])
        tight = plateau_threshold(ks, w, rel_tol=0.0, abs_tol=1.0)
        loose = plateau_threshold(ks, w, rel_tol=0.0, abs_tol=10.0)
        assert tight.threshold == pytest.approx(2.0)
        assert loose.threshold == pytest.approx(1.0)
        # default slack scales with the plateau (0.031 * 100 = 3.1 s here)
        # instead of assuming second-scale waits
        default = plateau_threshold(ks, w, rel_tol=0.0)
        assert default.threshold == pytest.approx(1.0)


_SHARD_SCRIPT = r"""
import json
import numpy as np
from repro.core import group_workloads, lane_padding, run_cohort_grid, \
    run_packet_grid
from repro.core.sweep import cohort_lane_sharding, lane_sharding
from repro.workload.lublin import WorkloadParams, generate_workload

import jax
assert jax.device_count() == 4, jax.devices()

wl = generate_workload(WorkloadParams(
    n_jobs=80, nodes=32, load=0.9, homogeneous=True, seed=7))
ks, s_props = [0.5, 8.0, 100.0], [0.05, 0.5]      # 6 lanes: 6 % 4 != 0
assert lane_padding(len(ks) * len(s_props)) == 2
assert lane_sharding(8, pad=True) is not None     # padded count shards
assert lane_sharding(6) is None                   # default stays strict
assert cohort_lane_sharding(8, pad=True) is not None
assert cohort_lane_sharding(6) is None
seq = run_packet_grid(wl, ks=ks, s_props=s_props, mode="seq")
fused = run_packet_grid(wl, ks=ks, s_props=s_props, mode="fused")

# the cohort form of the same padded sharding: [W, lanes] with the lane
# axis split over the 4 devices, members bitwise-matching solo fused runs
flows = {"a": wl, "b": generate_workload(WorkloadParams(
    n_jobs=80, nodes=32, load=0.95, homogeneous=True, seed=8))}
cohort = group_workloads(flows, np.float32)[0]
grids = run_cohort_grid(cohort, ks=ks, s_props=s_props, mode="fused")
cohort_match = all(
    np.array_equal(np.asarray(getattr(grids[name], f)),
                   np.asarray(getattr(
                       run_packet_grid(w, ks=ks, s_props=s_props,
                                       mode="fused"), f)))
    for name, w in flows.items() for f in grids[name]._fields)

print(json.dumps({
    "seq_avg_wait": np.asarray(seq.avg_wait).tolist(),
    "fused_avg_wait": np.asarray(fused.avg_wait).tolist(),
    "fused_n_groups": np.asarray(fused.n_groups).tolist(),
    "seq_n_groups": np.asarray(seq.n_groups).tolist(),
    "fused_ok": bool(np.asarray(fused.ok).all()),
    "shape": list(np.asarray(fused.avg_wait).shape),
    "cohort_match": bool(cohort_match),
    "cohort_ok": bool(all(np.asarray(g.ok).all() for g in grids.values())),
}))
"""


def test_padded_sharding_multi_device_subprocess():
    """222-style non-divisible lane counts shard via sentinel padding: a
    forced 4-device CPU backend runs a 6-lane fused grid (pad 2) and must
    reproduce sequential dispatch exactly."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr}\nstdout:\n{proc.stdout}"
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["fused_ok"]
    assert out["shape"] == [3, 2]
    np.testing.assert_allclose(out["fused_avg_wait"], out["seq_avg_wait"],
                               rtol=1e-5, atol=1e-5)
    assert out["fused_n_groups"] == out["seq_n_groups"]
    assert out["cohort_ok"]
    assert out["cohort_match"]    # [W, lanes] sharded == solo fused, bitwise
