"""End-to-end integration: training driver (with checkpoint resume),
serving driver, simulation CLI, and a real dry-run subprocess (512
placeholder devices, production mesh) for one cell.

Whole module is `slow` (multi-minute drivers + subprocess dry-run):
deselected from tier-1 by the default ``-m "not slow"`` addopts; run with
``pytest -m ""`` for the full matrix.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

pytestmark = pytest.mark.slow


def test_train_driver_runs_and_learns(tmp_path):
    from repro.launch.train import main
    loss = main(["--arch", "granite-3-2b", "--reduced", "--steps", "30",
                 "--batch", "8", "--seq", "64", "--lr", "3e-3",
                 "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "10"])
    assert np.isfinite(loss)
    files = os.listdir(tmp_path / "ck")
    assert any(f.endswith(".npz") for f in files)


def test_train_driver_resumes(tmp_path):
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    main(["--arch", "granite-3-2b", "--reduced", "--steps", "10",
          "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
          "--ckpt-every", "5"])
    # resume continues from the checkpoint rather than starting over
    loss = main(["--arch", "granite-3-2b", "--reduced", "--steps", "15",
                 "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
                 "--ckpt-every", "5", "--resume"])
    assert np.isfinite(loss)


def test_serve_driver():
    from repro.launch.serve import main
    out = main(["--arch", "yi-6b", "--reduced", "--batch", "2",
                "--prompt-len", "8", "--max-new", "6"])
    assert out.shape == (2, 6)


def test_sim_driver(capsys):
    from repro.launch.sim import main
    main(["--workload", "homog0.85", "--jobs", "400",
          "--init-prop", "0.05"])
    out = capsys.readouterr().out
    assert "plateau threshold" in out


@pytest.mark.slow
def test_dryrun_subprocess_single_cell(tmp_path):
    """The real thing: 512 host devices, production mesh, one cell."""
    out = str(tmp_path / "dr.json")
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("JAX_PLATFORMS", None)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--cells", "granite-3-2b:decode_32k", "--multi-pod",
         "--out", out],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(out))[0]
    assert rec["ok"] and rec["devices"] == 512
    assert rec["flops"] > 0
    assert rec["collectives"]["link_bytes_per_device"] > 0
