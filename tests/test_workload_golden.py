"""Lublin generator determinism: fixed-seed golden digests.

The golden-metrics suite (`test_golden_metrics.py`) pins simulator *output*;
this module pins simulator *input*. If the generator ever drifts (an RNG
call added/reordered, a constant touched, a numpy behaviour change), these
digests break loudly here — workload drift can then never masquerade as a
simulator regression in the metric suites downstream.

Digests are sha256 over float64 arrays rounded to 1e-6 s (see
`Workload.golden_digest`), covering both the heterogeneous and the paper's
"modified" homogeneous generator mode. Regenerate after an *intentional*
generator change with:

    PYTHONPATH=src python tests/test_workload_golden.py
"""
import numpy as np
import pytest

from repro.workload.lublin import WorkloadParams, generate_workload

GOLDEN_PARAMS = {
    "hetero": WorkloadParams(n_jobs=400, nodes=500, load=0.9,
                             homogeneous=False, seed=1234),
    "homog": WorkloadParams(n_jobs=400, nodes=100, load=0.9,
                            homogeneous=True, seed=1234,
                            daily_amplitude=0.3),
}

GOLDEN_DIGESTS = {
    "hetero": {
        "submit": "cba4b5e8650b5e09e64a5546e5ccc5f6c6b0958a2262586975a30fef85c7fff7",
        "runtime": "dc027f78c59df7d15fdc17a4f4dd742ef6b0b5c8d59a8b7a7a5eaa4ab29617d6",
        "nodes": "bd4962863899c774a011cb39b231a7d6700673d19356b085e2ce673302cd0a76",
        "jtype": "fb90a98e6471b3141306f5597783f821430069277d2a6dcb36d851f132a28f97",
    },
    "homog": {
        "submit": "8051181e21d744fe675b2c877f2ff394da4bde3f4262e320896787695ac13a22",
        "runtime": "efa804805f30782fdbb805a0afc205f11c41dd9e5277a751d0753a0bf1c5e4a0",
        "nodes": "d606d18508ab6bc98b24b467680f403ced8dbdf7ce955d0aea24afcc1aa3591b",
        "jtype": "27340bdfae5e699183fada6fe08d48065937c0112fd14f289a3f96c6a1c711de",
    },
}


@pytest.mark.parametrize("mode", sorted(GOLDEN_PARAMS))
def test_fixed_seed_digests(mode):
    got = generate_workload(GOLDEN_PARAMS[mode]).golden_digest()
    assert got == GOLDEN_DIGESTS[mode], (
        f"{mode} generator output drifted from the golden digests; if the "
        "change is intentional, regenerate with "
        "`PYTHONPATH=src python tests/test_workload_golden.py` and update "
        "the golden metrics too "
        "(`PYTHONPATH=src python tests/test_golden_metrics.py`).")


def test_digest_is_content_sensitive():
    """The digest helper actually sees each array (no accidental aliasing)."""
    wl = generate_workload(GOLDEN_PARAMS["hetero"])
    d = wl.golden_digest()
    assert len(set(d.values())) == len(d)                 # all distinct
    bumped = wl.golden_digest()
    assert bumped == d                                    # pure/deterministic
    import dataclasses
    wl2 = dataclasses.replace(wl, submit=wl.submit + 1e-3)
    assert wl2.golden_digest()["submit"] != d["submit"]
    assert wl2.golden_digest()["runtime"] == d["runtime"]


def test_digest_insensitive_to_sub_rounding_noise():
    """Rounding at 1e-6 s absorbs sub-libm-rounding jitter."""
    import dataclasses
    wl = generate_workload(GOLDEN_PARAMS["homog"])
    wl2 = dataclasses.replace(wl, submit=wl.submit + 1e-9)
    assert wl2.golden_digest()["submit"] == wl.golden_digest()["submit"]


if __name__ == "__main__":
    for mode, params in GOLDEN_PARAMS.items():
        print(f'    "{mode}": {generate_workload(params).golden_digest()!r},')
