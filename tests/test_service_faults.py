"""Fault-aware streaming control: chaos oracle parity, regime estimation,
risk-aware decide, and the degradation harness.

Four layers, pinned bottom-up: (1) the chaos-axis window oracle is
bitwise the offline grid driver's chaos column in BOTH dtypes — one
tick's [K, C] curves are the same lanes `run_packet_grid` runs; (2) the
fault-regime estimator is a deterministic function of its observations
(EWMA math, weight concentration, NaN carry-forward) checked against
hand arithmetic; (3) `FaultAwareController` at λ=0 IS the fault-blind
hysteresis on the expected-wait curve, and at high λ leaves a near-tied
wait plateau toward the low-lost member; (4) `run_service` under every
`on_budget_exhausted` policy with forced-exhaustion / NaN-telemetry /
dropped-telemetry `TickFaults` — "raise" names the tick and window,
"warn" completes with a warning, "degrade" completes EVERY tick with
health records and holds the last-good k.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import pack_workload
from repro.core.des import ChaosConfig
from repro.core.sweep import run_packet_grid, run_window_oracle
from repro.service import (FaultAwareController, FaultRegimeEstimator,
                           HysteresisController, ServiceConfig, TickFaults,
                           default_controllers, run_service)
from repro.service.monitor import RollingMonitor, window_signals
from repro.workload.lublin import WorkloadParams, generate_workload
from repro.workload.windows import drift_workload, slice_window

KS = np.array([1.0, 2.0, 4.0, 8.0, 16.0])

#: a 3-cell chaos axis: harsh / moderate / calm failure regimes, with the
#: straggler factor exercising both deadline outcomes (kill at 4.0x)
CHAOS3 = ChaosConfig(mtbf_chip_hours=np.array([25.0, 100.0, 800.0]),
                     ckpt_period=300.0, straggler_prob=0.1,
                     straggler_factor=np.array([4.0, 1.5, 1.5]), seed=7)

#: Metrics fields the fault-aware decide and its provenance consume
ORACLE_FIELDS = ("avg_wait", "lost_work", "useful_util", "requeued_jobs",
                 "failures", "requeues", "straggler_kills", "ok")


def _window(n_jobs=250, hi=200, seed=4):
    wl = generate_workload(WorkloadParams(
        n_jobs=n_jobs, nodes=100, load=0.9, homogeneous=True, seed=seed))
    return slice_window(wl, 0, hi)


class TestChaosWindowOracle:
    @pytest.mark.parametrize("dtype", ["float32", "float64"])
    def test_matches_offline_chaos_column_bitwise(self, dtype):
        """One fault-aware tick == the offline chaos sweep on the same
        window: same engine, same grid-order lane ids, so every leaf of
        the oracle's [K, C] block equals run_packet_grid's [:, 0, :]
        chaos column bit for bit — in both dtypes."""
        dt = np.dtype(dtype)
        win = _window()
        ks, s_prop = (0.5, 2.0, 8.0, 40.0), 0.05
        grid = run_packet_grid(win, ks=ks, s_props=[s_prop], dtype=dt,
                               mode="chunked", chaos=CHAOS3)
        from repro.core import precision
        with precision.dtype_scope(dt):
            pw = pack_workload(win, dt)
        m = run_window_oracle(pw, ks, win.init_time_for_proportion(s_prop),
                              win.params.nodes, mode="chunked", chaos=CHAOS3)
        for f in ORACLE_FIELDS:
            a = np.asarray(getattr(m, f))
            b = np.asarray(getattr(grid, f))
            assert a.shape == (len(ks), 3), f
            assert np.array_equal(a, b[:, 0, :]), f

    def test_dispatch_layouts_agree_bitwise(self):
        """Grid-order lane ids make the chaos draws dispatch-invariant:
        seq, chunked and fused ticks agree exactly."""
        win = _window()
        pw = pack_workload(win)
        s = win.init_time_for_proportion(0.05)
        outs = [run_window_oracle(pw, (0.5, 2.0, 8.0, 40.0), s,
                                  win.params.nodes, mode=mode, chaos=CHAOS3)
                for mode in ("seq", "chunked", "fused")]
        for f in ORACLE_FIELDS:
            ref = np.asarray(getattr(outs[0], f))
            for other in outs[1:]:
                assert np.array_equal(ref, np.asarray(getattr(other, f))), f

    def test_inert_chaos_is_the_fault_free_program(self):
        """A zero-rate ChaosConfig normalizes to None: [K] leaves,
        bitwise the fault-free tick."""
        win = _window()
        pw = pack_workload(win)
        s = win.init_time_for_proportion(0.05)
        base = run_window_oracle(pw, KS, s, win.params.nodes, mode="chunked")
        inert = run_window_oracle(pw, KS, s, win.params.nodes, mode="chunked",
                                  chaos=ChaosConfig())
        for f in ("avg_wait", "useful_util", "n_groups", "ok"):
            a, b = np.asarray(getattr(base, f)), np.asarray(getattr(inert, f))
            assert a.shape == (len(KS),), f
            assert np.array_equal(a, b), f

    def test_scalar_active_chaos_keeps_1d_leaves(self):
        win = _window()
        pw = pack_workload(win)
        s = win.init_time_for_proportion(0.05)
        m = run_window_oracle(pw, (2.0, 8.0), s, win.params.nodes,
                              mode="chunked",
                              chaos=ChaosConfig(mtbf_chip_hours=50.0))
        assert np.asarray(m.avg_wait).shape == (2,)
        assert np.asarray(m.failures).sum() > 0


class TestRollingMonitorHardening:
    def _sig(self, lo=0, hi=150, seed=2):
        wl = generate_workload(WorkloadParams(
            n_jobs=300, nodes=100, load=0.9, homogeneous=True, seed=seed))
        return window_signals(slice_window(wl, lo, hi), 0.05)

    def test_nan_carries_last_finite_ewma(self):
        m = RollingMonitor(alpha=0.5)
        sig = self._sig()
        first = m.observe(sig)
        poisoned = sig._replace(offered_load=float("nan"),
                                init_time=float("inf"))
        second = m.observe(poisoned)
        assert second["ewm_offered_load"] == first["ewm_offered_load"]
        assert second["ewm_init_time"] == first["ewm_init_time"]
        assert second["delta_offered_load"] == 0.0
        assert set(second["carried"]) == {"offered_load", "init_time"}
        # finite components still smooth normally
        assert second["ewm_arrival_rate"] == pytest.approx(
            0.5 * sig.arrival_rate + 0.5 * first["ewm_arrival_rate"])
        clean = m.observe(sig)
        assert "carried" not in clean

    def test_nan_at_bootstrap_raises_named(self):
        m = RollingMonitor()
        with pytest.raises(ValueError, match="offered_load"):
            m.observe(self._sig()._replace(offered_load=float("nan")))

    def test_reset_and_has_state(self):
        m = RollingMonitor(alpha=0.5)
        assert not m.has_state
        sig = self._sig()
        m.observe(sig)
        assert m.has_state
        m.reset()
        assert not m.has_state
        # post-reset observation bootstraps fresh (no smoothing with the
        # pre-reset history)
        out = m.observe(self._sig(150, 300))
        assert out["delta_offered_load"] == 0.0


class TestFaultRegimeEstimator:
    def test_uniform_before_any_observation(self):
        est = FaultRegimeEstimator()
        w = est.weights({"failures": [10.0, 1.0, 0.1]})
        assert w.shape == (3,)
        np.testing.assert_allclose(w, [1 / 3] * 3)

    def test_concentrates_on_matching_cell(self):
        est = FaultRegimeEstimator(alpha=1.0, temperature=0.25)
        est.observe(failures=10.0, requeues=12.0, lost_work=5000.0)
        w = est.weights({"failures": np.array([10.0, 1.0, 0.0]),
                         "requeues": np.array([12.0, 2.0, 0.0]),
                         "lost_work": np.array([5000.0, 400.0, 0.0])})
        assert int(np.argmax(w)) == 0
        assert w[0] > 0.9                      # exact match, sharp temp
        assert w[1] > w[2]                     # ordered by distance
        assert w.sum() == pytest.approx(1.0)

    def test_ewma_math_and_regime_shift(self):
        est = FaultRegimeEstimator(alpha=0.5)
        est.observe(10.0, 0.0, 0.0)
        out = est.observe(20.0, 0.0, 0.0)
        assert out["ewm_failures"] == pytest.approx(15.0)
        # a regime shift moves the EWMA (and therefore the weights)
        # toward the new cell within a few half-lives
        cells = {"failures": np.array([0.5, 15.0, 40.0])}
        assert int(np.argmax(est.weights(cells))) == 1
        for _ in range(4):
            est.observe(40.0, 0.0, 0.0)
        assert int(np.argmax(est.weights(cells))) == 2

    def test_temperature_sets_concentration(self):
        cells = {"failures": np.array([10.0, 5.0, 0.0])}
        sharp = FaultRegimeEstimator(temperature=0.01)
        flat = FaultRegimeEstimator(temperature=100.0)
        for est in (sharp, flat):
            est.observe(10.0, 0.0, 0.0)
        assert sharp.weights(cells)[0] > 0.999
        np.testing.assert_allclose(flat.weights(cells), 1 / 3, atol=0.01)

    def test_nan_telemetry_carries_forward(self):
        est = FaultRegimeEstimator(alpha=0.5)
        est.observe(10.0, 2.0, 100.0)
        out = est.observe(float("nan"), float("inf"), 200.0)
        assert set(out["carried"]) == {"failures", "requeues"}
        assert out["ewm_failures"] == 10.0      # carried, not NaN-poisoned
        assert out["ewm_lost_work"] == pytest.approx(150.0)
        assert est.n_carried == 2
        w = est.weights({"failures": np.array([10.0, 0.0])})
        assert np.all(np.isfinite(w))

    def test_never_observed_signal_degrades_to_uniform(self):
        """A stream that was NaN from the start never observes anything:
        weights stay at the uniform prior rather than propagating NaN."""
        est = FaultRegimeEstimator()
        out = est.observe(float("nan"), float("nan"), float("nan"))
        assert len(out["carried"]) == 3 and "ewm_failures" not in out
        np.testing.assert_allclose(est.weights(
            {"failures": np.array([1.0, 2.0])}), [0.5, 0.5])

    def test_mismatched_cells_raise_named(self):
        est = FaultRegimeEstimator()
        est.observe(1.0, 1.0, 1.0)
        with pytest.raises(ValueError, match="failures"):
            est.weights({"failures": np.array([1.0, 2.0]),
                         "requeues": np.array([1.0, 2.0, 3.0])})
        with pytest.raises(ValueError, match="non-empty"):
            est.weights({})

    def test_reset(self):
        est = FaultRegimeEstimator()
        est.observe(float("nan"), 1.0, 1.0)
        assert est.has_state and est.n_carried == 1
        est.reset()
        assert not est.has_state and est.n_carried == 0
        np.testing.assert_allclose(
            est.weights({"failures": np.array([0.0, 9.0])}), [0.5, 0.5])

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultRegimeEstimator(alpha=0.0)
        with pytest.raises(ValueError):
            FaultRegimeEstimator(temperature=0.0)


class TestFaultAwareDecide:
    WAIT2 = np.array([[100.0, 110.0], [10.0, 11.0],
                      [10.2, 11.2], [10.4, 11.4]])
    LOST2 = np.array([[900.0, 1800.0], [40.0, 80.0],
                      [20.0, 40.0], [1.0, 2.0]])
    KS4 = np.array([1.0, 4.0, 8.0, 16.0])

    def test_lambda_zero_is_fault_blind_on_expected_wait(self):
        w = np.array([0.25, 0.75])
        fa = FaultAwareController(risk_lambda=0.0)
        fb = HysteresisController()
        curves = (self.WAIT2, self.WAIT2[:, ::-1], self.WAIT2 * 1.5)
        for c in curves:
            assert (fa.decide(self.KS4, c, lost=self.LOST2, weights=w).k
                    == fb.decide(self.KS4, c @ w).k)

    def test_high_lambda_leaves_plateau_toward_low_lost(self):
        """k=4 wins on wait alone (near-tied plateau with 8 and 16), but
        the λ·lost term makes k=16 the cost arg-best."""
        fb = HysteresisController()
        w = np.array([0.5, 0.5])
        assert fb.decide(self.KS4, self.WAIT2 @ w).k == 4.0
        fa = FaultAwareController(risk_lambda=1.0)
        d = fa.decide(self.KS4, self.WAIT2, lost=self.LOST2, weights=w)
        assert d.k == 16.0 and d.reason == "bootstrap"
        # ... and the hysteresis hold still applies on the cost curve
        d2 = fa.decide(self.KS4, self.WAIT2 * 1.001, lost=self.LOST2,
                       weights=w)
        assert not d2.moved and d2.reason == "hold"

    def test_weights_shift_the_expectation(self):
        """Concentrating weight on the harsh cell doubles the lost term."""
        fa = FaultAwareController(risk_lambda=0.2)
        calm = fa.decide(self.KS4, self.WAIT2, lost=self.LOST2,
                         weights=np.array([1.0, 0.0]))
        fa2 = FaultAwareController(risk_lambda=0.2)
        harsh = fa2.decide(self.KS4, self.WAIT2, lost=self.LOST2,
                           weights=np.array([0.0, 1.0]))
        assert harsh.best_wait > calm.best_wait   # cost at best, provenance

    def test_1d_and_default_inputs_accepted(self):
        fa = FaultAwareController()
        d = fa.decide(KS, [100.0, 50.0, 10.0, 9.0, 10.0])
        assert d.k == 8.0
        fa2 = FaultAwareController()
        # [K, C] wait with no weights: uniform cells
        d2 = fa2.decide(self.KS4, self.WAIT2)
        assert d2.k == 4.0

    def test_validation(self):
        fa = FaultAwareController()
        with pytest.raises(ValueError):
            fa.decide(self.KS4, self.WAIT2, weights=np.ones(3))
        with pytest.raises(ValueError):
            fa.decide(self.KS4, self.WAIT2[:, :, None])
        with pytest.raises(ValueError, match="non-finite"):
            fa.decide(self.KS4, self.WAIT2,
                      lost=self.LOST2 * np.nan,
                      weights=np.array([0.5, 0.5]))
        with pytest.raises(ValueError):
            FaultAwareController(risk_lambda=-0.5)


class TestServiceConfigValidation:
    """Bad configs must raise at construction, not deep inside tick N."""

    BAD = [
        dict(window_jobs=0),
        dict(stride_jobs=0),
        dict(s_prop=0.0),
        dict(dtype="float16"),
        dict(dtype="int32"),
        dict(mode="vmap_k"),
        dict(mode="warp"),
        dict(rel_tol=-0.01),
        dict(abs_tol=-1.0),
        dict(ewm_alpha=0.0),
        dict(ewm_alpha=1.5),
        dict(on_budget_exhausted="explode"),
        dict(risk_lambda=-1.0),
        dict(fault_alpha=0.0),
        dict(fault_temperature=0.0),
        dict(max_consecutive_degraded=0),
        dict(ks=()),
        dict(chaos=CHAOS3, chaos_env_cell=3),
        dict(chaos=CHAOS3, chaos_env_cell=-1),
        dict(chaos=ChaosConfig()),      # inert axis
    ]

    @pytest.mark.parametrize("kw", BAD,
                             ids=[str(sorted(b.items()))[:40] for b in BAD])
    def test_bad_field_raises(self, kw):
        with pytest.raises(ValueError):
            ServiceConfig(**kw)

    def test_good_configs_construct(self):
        ServiceConfig()
        cfg = ServiceConfig(chaos=CHAOS3, chaos_env_cell=2,
                            on_budget_exhausted="degrade")
        assert cfg.n_chaos_cells == 3

    def test_tick_faults_validation(self):
        tf = TickFaults(exhaust_budget=[2, 1], nan_telemetry={3})
        assert tf.exhaust_budget == frozenset({1, 2})
        with pytest.raises(ValueError):
            TickFaults(exhaust_budget=[-1])
        with pytest.raises(ValueError):
            TickFaults(drop_telemetry="012")


def _trace(n_jobs=800):
    return drift_workload(
        WorkloadParams(n_jobs=n_jobs, nodes=100, load=0.9, homogeneous=True,
                       seed=9, daily_amplitude=0.3),
        loads=[0.9] * 4)


_SERVICE_KW = dict(ks=(0.5, 2.0, 8.0, 40.0), window_jobs=200, mode="chunked")


class TestDegradeHarness:
    def test_raise_policy_names_tick_and_window(self):
        config = ServiceConfig(**_SERVICE_KW)
        with pytest.raises(RuntimeError, match=r"tick 1 .*\[200, 400\)"):
            run_service(_trace(), config,
                        tick_faults=TickFaults(exhaust_budget={1}))

    def test_warn_policy_completes_with_context(self):
        config = ServiceConfig(on_budget_exhausted="warn", **_SERVICE_KW)
        with pytest.warns(RuntimeWarning, match="tick 1"):
            out = run_service(_trace(), config,
                              tick_faults=TickFaults(exhaust_budget={1}))
        assert out["n_ticks"] == 4
        assert out["n_degraded_ticks"] == 0
        assert out["health"][1]["budget_warned"]

    def test_degrade_policy_completes_every_tick(self):
        config = ServiceConfig(on_budget_exhausted="degrade", **_SERVICE_KW)
        out = run_service(_trace(), config,
                          tick_faults=TickFaults(exhaust_budget={1}))
        assert out["n_ticks"] == 4
        assert out["n_degraded_ticks"] == 1
        assert [h["tick"] for h in out["health"]] == [0, 1, 2, 3]
        bad = out["ticks"][1]
        assert bad["degraded"] and "best_k" not in bad
        for name, c in bad["controllers"].items():
            # held exactly the k committed at tick 0 — the last-good k
            assert c["reason"] == "degraded-hold"
            assert (c["realized_k"]
                    == out["ticks"][0]["controllers"][name]["committed_k"])
        # degraded ticks are excluded from regret scoring
        for s in out["controllers"].values():
            assert s["n_ticks"] == 3
            assert len(s["k_trajectory"]) == 4    # but the k history is full
            assert s["mean_regret_wait"] >= -1e-12

    def test_degraded_bootstrap_uses_median_candidate(self):
        config = ServiceConfig(on_budget_exhausted="degrade", **_SERVICE_KW)
        out = run_service(_trace(), config,
                          tick_faults=TickFaults(exhaust_budget={0}))
        t0 = out["ticks"][0]
        for c in t0["controllers"].values():
            assert c["reason"] == "degraded-bootstrap"
            assert c["realized_k"] == 8.0       # median of (0.5, 2, 8, 40)

    def test_bounded_retry_raises_past_consecutive_limit(self):
        config = ServiceConfig(on_budget_exhausted="degrade",
                               max_consecutive_degraded=1, **_SERVICE_KW)
        with pytest.raises(RuntimeError, match="consecutive degraded"):
            run_service(_trace(), config,
                        tick_faults=TickFaults(exhaust_budget={1, 2}))
        # non-consecutive faults stay within the bound
        out = run_service(_trace(), config,
                          tick_faults=TickFaults(exhaust_budget={1, 3}))
        assert out["n_degraded_ticks"] == 2

    def test_degrade_without_faults_matches_default_numerics(self):
        """The degrade machinery must not perturb a healthy stream: same
        curves, same decisions, same regrets — only the health records
        are new."""
        base = run_service(_trace(), ServiceConfig(**_SERVICE_KW))
        deg = run_service(_trace(), ServiceConfig(
            on_budget_exhausted="degrade", **_SERVICE_KW))
        assert deg["n_degraded_ticks"] == 0
        for name in base["controllers"]:
            b, d = base["controllers"][name], deg["controllers"][name]
            assert b["k_trajectory"] == d["k_trajectory"]
            assert b["total_regret_wait"] == d["total_regret_wait"]
            assert b["switches"] == d["switches"]
        assert base["oracle"]["best_k"] == deg["oracle"]["best_k"]
        assert "health" not in base and "health" in deg

    def test_default_output_schema_unchanged(self):
        out = run_service(_trace(), ServiceConfig(**_SERVICE_KW))
        assert sorted(out) == ["config", "controllers", "n_ticks", "oracle",
                               "ticks"]
        assert "on_budget_exhausted" not in out["config"]
        assert "chaos" not in out["config"]


class TestFaultAwareService:
    @pytest.fixture(scope="class")
    def result(self):
        config = ServiceConfig(chaos=CHAOS3, chaos_env_cell=0,
                               risk_lambda=1.0, **_SERVICE_KW)
        return run_service(_trace(), config, default_controllers(config))

    def test_controller_set_and_invariants(self, result):
        assert set(result["controllers"]) == {"fault_aware", "hysteresis",
                                              "naive"}
        for name, s in result["controllers"].items():
            assert s["mean_regret_wait"] >= -1e-12, name
            assert s["mean_regret_useful"] >= -1e-12, name
            assert s["total_lost_work"] >= 0.0, name

    def test_weights_are_distributions(self, result):
        for t in result["ticks"]:
            for c in t["controllers"].values():
                w = np.asarray(c["weights"])
                assert w.shape == (3,)
                assert np.all(w >= 0) and w.sum() == pytest.approx(1.0)

    def test_estimator_locks_onto_environment_cell(self, result):
        """After a few closed-loop ticks the realized harsh-cell telemetry
        concentrates every controller's regime weights on env cell 0."""
        last = result["ticks"][-1]
        for name, c in last["controllers"].items():
            assert int(np.argmax(c["weights"])) == 0, name

    def test_fault_aware_no_worse_on_lost_work(self, result):
        fa = result["controllers"]["fault_aware"]
        fb = result["controllers"]["hysteresis"]
        assert fa["total_lost_work"] <= fb["total_lost_work"] + 1e-9
        assert (fa["total_regret_wait"]
                <= 1.1 * fb["total_regret_wait"] + 1e-6)

    def test_chaos_provenance_recorded(self, result):
        chaos = result["config"]["chaos"]
        assert chaos["n_cells"] == 3 and chaos["env_cell"] == 0
        assert chaos["mtbf_chip_hours"] == [25.0, 100.0, 800.0]
        t1 = result["ticks"][1]["controllers"]["fault_aware"]
        assert "ewm_failures" in t1["fault_ewm"]
        assert t1["realized_lost"] >= 0.0

    def test_nan_and_dropped_telemetry_survive_with_chaos(self):
        config = ServiceConfig(chaos=CHAOS3, on_budget_exhausted="degrade",
                               **_SERVICE_KW)
        out = run_service(
            _trace(), config,
            tick_faults=TickFaults(nan_telemetry={1}, drop_telemetry={2},
                                   exhaust_budget={3}))
        assert out["n_ticks"] == 4 and out["n_degraded_ticks"] == 1
        t1 = out["ticks"][1]["controllers"]["fault_aware"]
        assert t1["carried_telemetry"] == ["failures", "requeues",
                                           "lost_work"]
        assert out["health"][2]["dropped_telemetry"]
        assert "carried" in out["ticks"][2]["signals"]
        # every post-fault weight vector is still a finite distribution
        for t in out["ticks"]:
            for c in t["controllers"].values():
                if "weights" in c:
                    assert np.all(np.isfinite(c["weights"]))
