"""Golden-metrics regression suite, run under BOTH simulation dtypes.

A small paper-shaped grid (two Lublin workflows x 4 scale ratios x 3 init
proportions, Packet + both rigid baselines) is pinned against a checked-in
float64 reference (``tests/golden/golden_metrics.json``):

  * the float64 run (through the scoped `repro.core.precision` opt-in) must
    reproduce the golden values to ~ulp (rtol 1e-9) — any drift is a
    simulator change, not rounding;
  * the float32 run must stay within per-metric tolerances derived from the
    float32-vs-float64 tolerance study over the full paper grid
    (``benchmarks/results/BENCH_dtype.json``, `suggested_float32_rtol` =
    10x the worst rounding-only deviation), and must form *exactly* the
    same group counts — the golden grid is verified decision-flip-free at
    regeneration time, so a flipped near-tie shows up as a hard failure
    here rather than hiding inside a loose tolerance.

The suite also asserts the opt-in never leaks: after a float64 run the
global ``jax_enable_x64`` flag is untouched and float32 is still the
session default.

Regenerate after an *intentional* simulator/generator change with:

    PYTHONPATH=src python tests/test_golden_metrics.py

(and re-run ``python -m benchmarks.bench_dtype`` so the tolerances and the
docstring deviation figures stay in sync; `test_workload_golden.py` pins
the generator inputs themselves).
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.core import precision, run_baselines, run_packet_grid
from repro.workload.lublin import WorkloadParams, generate_workload

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "golden_metrics.json")
BENCH_DTYPE_PATH = os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks", "results", "BENCH_dtype.json")

# Paper-shaped but small: one heterogeneous flow (larger cluster, wide
# jobs) and one "modified generator" homogeneous flow, ks spanning the
# sweep's decades, init proportions spanning the paper's range.
GOLDEN_WORKLOADS = {
    "hetero": WorkloadParams(n_jobs=200, nodes=96, load=0.9,
                             homogeneous=False, seed=17),
    "homog": WorkloadParams(n_jobs=200, nodes=48, load=0.9,
                            homogeneous=True, seed=18,
                            daily_amplitude=0.3),
}
GOLDEN_KS = (0.5, 2.0, 20.0, 200.0)
GOLDEN_S_PROPS = (0.05, 0.3, 0.5)

# Shared with benchmarks/bench_dtype.py via repro.core.metrics: relative
# tolerance is applied against max(|golden|, floor), with the same floors
# the study measured deviations against.
from repro.core.metrics import (METRIC_REL_FLOORS as ABS_FLOORS,
                                SCALAR_METRIC_FIELDS as METRIC_FIELDS)
# Fallback float32 tolerances if BENCH_dtype.json is absent: the
# `suggested_float32_rtol` block the 2026-08 study measured (10x the worst
# same-schedule deviation of the golden-scale workloads over the full
# 37 x 6 grid).
FALLBACK_FLOAT32_RTOL = {
    "avg_wait": 3.1e-2, "med_wait": 1.4e-2, "avg_qlen": 3.1e-2,
    "full_util": 1.4e-5, "useful_util": 1.1e-5, "avg_run_wait": 3.4e-5,
}


def float32_rtol() -> dict:
    if os.path.exists(BENCH_DTYPE_PATH):
        with open(BENCH_DTYPE_PATH) as f:
            study = json.load(f)
        sug = study.get("suggested_float32_rtol", {})
        if set(METRIC_FIELDS) <= set(sug):
            return {f: float(sug[f]) for f in METRIC_FIELDS}
    return dict(FALLBACK_FLOAT32_RTOL)


def compute_grids(dtype) -> dict:
    """The golden grid under one dtype; mode='seq' pins the dispatch layout
    (engine-layout equivalence is covered by test_des_equivalence)."""
    out = {}
    for name, params in GOLDEN_WORKLOADS.items():
        wl = generate_workload(params)
        grid = run_packet_grid(wl, ks=GOLDEN_KS, s_props=GOLDEN_S_PROPS,
                               dtype=dtype, mode="seq")
        bl = run_baselines(wl, s_props=GOLDEN_S_PROPS, dtype=dtype)
        entry = {"packet": {f: np.asarray(getattr(grid, f)).tolist()
                            for f in METRIC_FIELDS}}
        entry["packet"]["n_groups"] = \
            np.asarray(grid.n_groups).astype(int).tolist()
        entry["packet"]["ok"] = bool(np.asarray(grid.ok).all())
        for alg, m in bl.items():
            entry[alg] = {f: np.asarray(getattr(m, f)).tolist()
                          for f in METRIC_FIELDS}
            entry[alg]["ok"] = bool(np.asarray(m.ok).all())
        out[name] = entry
    return out


@pytest.fixture(scope="module")
def golden():
    if not os.path.exists(GOLDEN_PATH):
        pytest.fail(f"golden metrics file missing: {GOLDEN_PATH} "
                    "(regenerate: PYTHONPATH=src python "
                    "tests/test_golden_metrics.py)")
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def _assert_close(got, want, field, rtol, label):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    denom = np.maximum(np.abs(want), ABS_FLOORS[field])
    rel = np.abs(got - want) / denom
    worst = float(rel.max()) if rel.size else 0.0
    assert worst <= rtol, (
        f"{label}/{field}: max rel deviation {worst:.3e} > allowed "
        f"{rtol:.3e} (worst cell {np.unravel_index(int(np.argmax(rel)), rel.shape)})")


class TestGoldenFloat64:
    """float64 == the golden reference to ~ulp (identical op order)."""

    def test_matches_golden(self, golden):
        got = compute_grids(np.float64)
        for name, entry in golden["grids"].items():
            for alg in ("packet", "fcfs", "backfill"):
                for f in METRIC_FIELDS:
                    _assert_close(got[name][alg][f], entry[alg][f], f,
                                  1e-9, f"f64/{name}/{alg}")
            assert got[name]["packet"]["n_groups"] == \
                entry["packet"]["n_groups"]
            assert got[name]["packet"]["ok"]

    def test_no_global_x64_leakage(self, golden):
        """The float64 run above must not have flipped the session config."""
        import jax.numpy as jnp
        assert not jax.config.jax_enable_x64
        assert jnp.asarray(1.0).dtype == jnp.float32


class TestGoldenScanEngine:
    """One pass of the golden grid through the event-budget scan engine
    (mode='chunked', the batched-lane layout): a dispatch-layout change
    must reproduce the float64 golden reference like mode='seq' does.
    Layout-vs-layout equivalence at width is covered by
    test_des_equivalence; this pins the engine against the checked-in
    reference so a scan-engine regression cannot hide behind a matching
    regression in the while engine."""

    def test_chunked_matches_golden(self, golden):
        got = {}
        for name, params in GOLDEN_WORKLOADS.items():
            wl = generate_workload(params)
            grid = run_packet_grid(wl, ks=GOLDEN_KS, s_props=GOLDEN_S_PROPS,
                                   dtype=np.float64, mode="chunked")
            got[name] = {f: np.asarray(getattr(grid, f)).tolist()
                         for f in METRIC_FIELDS}
            got[name]["n_groups"] = \
                np.asarray(grid.n_groups).astype(int).tolist()
            assert np.asarray(grid.ok).all()
        for name, entry in golden["grids"].items():
            for f in METRIC_FIELDS:
                _assert_close(got[name][f], entry["packet"][f], f,
                              1e-9, f"f64-chunked/{name}")
            assert got[name]["n_groups"] == entry["packet"]["n_groups"]


class TestGoldenFloat32:
    """float32 within study-derived tolerances AND schedule-identical."""

    def test_within_derived_tolerances(self, golden):
        rtols = float32_rtol()
        got = compute_grids(np.float32)
        for name, entry in golden["grids"].items():
            for alg in ("packet", "fcfs", "backfill"):
                for f in METRIC_FIELDS:
                    _assert_close(got[name][alg][f], entry[alg][f], f,
                                  rtols[f], f"f32/{name}/{alg}")
            # decision-flip-free grid: group counts must match exactly
            assert got[name]["packet"]["n_groups"] == \
                entry["packet"]["n_groups"], (
                    f"{name}: float32 formed different groups than the "
                    "float64 golden reference — a near-tie flipped; pick a "
                    "different golden seed or investigate the scheduler")
            assert got[name]["packet"]["ok"]

    def test_tolerances_are_meaningful(self):
        """Derived tolerances must stay regression-sensitive: well below
        the O(1) cell deviations that paper-scale decision flips produce
        (BENCH_dtype.json measures up to ~650% there), so a real scheduler
        regression cannot hide inside the float32 allowance."""
        for f, v in float32_rtol().items():
            assert 1e-7 <= v < 5e-2, (f, v)


def regenerate():
    with precision.dtype_scope(np.float64):
        pass  # touch the scope early so misconfiguration fails fast
    grids64 = compute_grids(np.float64)
    grids32 = compute_grids(np.float32)
    for name in grids64:
        assert grids64[name]["packet"]["n_groups"] == \
            grids32[name]["packet"]["n_groups"], (
                f"{name}: golden grid sits on a float32 decision boundary; "
                "choose different seeds/ks")
        assert grids64[name]["packet"]["ok"]
    payload = {
        "comment": "float64 reference metrics for the golden grid; "
                   "regenerate with PYTHONPATH=src python "
                   "tests/test_golden_metrics.py",
        "spec": {
            "workloads": {n: {k: getattr(p, k) for k in
                              ("n_jobs", "nodes", "load", "homogeneous",
                               "seed", "daily_amplitude")}
                          for n, p in GOLDEN_WORKLOADS.items()},
            "ks": list(GOLDEN_KS), "s_props": list(GOLDEN_S_PROPS),
            "mode": "seq", "reference_dtype": "float64",
        },
        "grids": grids64,
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {GOLDEN_PATH} (verified decision-flip-free vs float32)")


if __name__ == "__main__":
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    regenerate()
