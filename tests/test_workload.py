"""Tests for the Lublin–Feitelson workload generator.

Property-based tests live in ``test_workload_properties.py`` behind the
optional ``hypothesis`` dev dependency.
"""
import numpy as np
import pytest

from repro.workload.lublin import (WorkloadParams, generate_workload,
                                   paper_workloads)


class TestGenerator:
    def test_load_calibration_exact(self):
        for load in (0.85, 0.90, 0.95):
            wl = generate_workload(WorkloadParams(n_jobs=1000, load=load, seed=1))
            assert wl.calculated_load() == pytest.approx(load, rel=1e-6)

    def test_submit_sorted_and_spans_horizon(self):
        wl = generate_workload(WorkloadParams(n_jobs=2000, seed=2))
        assert np.all(np.diff(wl.submit) >= 0)
        assert wl.submit[0] == pytest.approx(0.0, abs=1.0)
        assert wl.submit[-1] == pytest.approx(wl.params.horizon, rel=1e-6)

    def test_nodes_within_bounds(self):
        wl = generate_workload(WorkloadParams(n_jobs=2000, nodes=500, seed=3))
        assert wl.nodes.min() >= 1
        assert wl.nodes.max() <= 500

    def test_serial_fraction_near_lublin(self):
        wl = generate_workload(WorkloadParams(n_jobs=5000, seed=4))
        frac = (wl.nodes == 1).mean()
        assert 0.15 < frac < 0.40  # Lublin: ~0.244

    def test_types_in_range(self):
        wl = generate_workload(WorkloadParams(n_jobs=1000, n_types=8, seed=5))
        assert set(np.unique(wl.jtype)) <= set(range(8))
        assert len(np.unique(wl.jtype)) >= 4  # all popular types present

    def test_homogeneous_has_lower_runtime_spread(self):
        het = generate_workload(WorkloadParams(n_jobs=3000, seed=6))
        hom = generate_workload(WorkloadParams(n_jobs=3000, homogeneous=True,
                                               seed=6))
        cv_het = het.runtime.std() / het.runtime.mean()
        cv_hom = hom.runtime.std() / hom.runtime.mean()
        assert cv_hom < cv_het

    def test_reproducible(self):
        a = generate_workload(WorkloadParams(n_jobs=100, seed=42))
        b = generate_workload(WorkloadParams(n_jobs=100, seed=42))
        np.testing.assert_array_equal(a.submit, b.submit)
        np.testing.assert_array_equal(a.runtime, b.runtime)

    def test_init_time_for_proportion(self):
        wl = generate_workload(WorkloadParams(n_jobs=500, seed=8))
        for sp in (0.05, 0.3, 0.5):
            s = wl.init_time_for_proportion(sp)
            n = wl.n_jobs
            achieved = n * s / (n * s + wl.runtime.sum())
            assert achieved == pytest.approx(sp, rel=1e-9)

    def test_paper_workloads_structure(self):
        flows = paper_workloads(seed=0)
        assert set(flows) == {f"{kind}{ld:.2f}" for kind in ("hetero", "homog")
                              for ld in (0.85, 0.90, 0.95)}
        assert flows["hetero0.85"].params.nodes == 500
        assert flows["homog0.90"].params.nodes == 100
