"""Property-based tests for the Packet DES and baseline schedulers.

Requires the optional ``hypothesis`` dev dependency (``pip install
hypothesis``); the whole module is skipped when it is absent so tier-1
collection never fails in a minimal environment.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (pack_workload, simulate_backfill, simulate_fcfs,  # noqa: E402
                        simulate_packet, simulate_packet_reference)

from conftest import make_workload as _mk_workload  # noqa: E402


@st.composite
def tiny_workloads(draw):
    n = draw(st.integers(3, 24))
    h = draw(st.integers(1, 4))
    m = draw(st.integers(2, 16))
    submit = sorted(draw(st.lists(
        st.floats(0, 1e4, allow_nan=False, allow_infinity=False),
        min_size=n, max_size=n)))
    runtime = draw(st.lists(st.floats(1, 1e3), min_size=n, max_size=n))
    nodes = draw(st.lists(st.integers(1, m), min_size=n, max_size=n))
    jtype = draw(st.lists(st.integers(0, h - 1), min_size=n, max_size=n))
    return _mk_workload(submit, runtime, nodes, jtype, h, m)


class TestProperties:
    @settings(max_examples=25, deadline=None)
    @given(tiny_workloads(), st.floats(0.1, 100.0), st.floats(0.1, 0.6))
    def test_packet_invariants(self, wl, k, s_prop):
        pw = pack_workload(wl, jnp.float32)
        s = max(wl.init_time_for_proportion(s_prop), 1e-3)
        res = simulate_packet(pw, k, s, wl.params.nodes)
        res = jax.tree.map(np.asarray, res)
        assert res.ok, "simulation must drain"
        # every job starts, never before its submit
        assert np.all(np.isfinite(res.start_t))
        assert np.all(res.start_t >= np.asarray(pw.submit) - 1e-3)
        # a job's own run begins >= group start + init
        assert np.all(res.run_start_t >= res.start_t + s - 1e-2)
        # useful node-seconds within window can never exceed busy ones
        assert res.useful_ns <= res.busy_ns + 1e-3
        # utilization bounds
        window = float(pw.t_last_submit)
        if window > 0:
            assert res.busy_ns <= wl.params.nodes * window * (1 + 1e-5)

    @settings(max_examples=25, deadline=None)
    @given(tiny_workloads(), st.floats(0.1, 100.0), st.floats(0.1, 0.6))
    def test_packet_matches_reference(self, wl, k, s_prop):
        """The group-log DES agrees with the seed O(N)-writes oracle on
        arbitrary tiny workloads (the random-case arm of the equivalence
        suite in test_des_equivalence.py)."""
        pw = pack_workload(wl, jnp.float32)
        s = max(wl.init_time_for_proportion(s_prop), 1e-3)
        a = jax.tree.map(np.asarray, simulate_packet(pw, k, s, wl.params.nodes))
        b = jax.tree.map(np.asarray,
                         simulate_packet_reference(pw, k, s, wl.params.nodes))
        for f in a._fields:
            np.testing.assert_allclose(getattr(a, f), getattr(b, f),
                                       rtol=1e-6, atol=1e-6, err_msg=f)

    @settings(max_examples=25, deadline=None)
    @given(tiny_workloads(), st.floats(0.0, 100.0))
    def test_baseline_invariants(self, wl, s):
        pw = pack_workload(wl, jnp.float32)
        for sim in (simulate_fcfs, simulate_backfill):
            res = jax.tree.map(np.asarray, sim(pw, s, wl.params.nodes))
            assert res.ok
            assert np.all(res.start_t >= np.asarray(pw.submit) - 1e-3)
            assert int(res.n_groups) == wl.n_jobs  # no grouping in baselines

    @settings(max_examples=15, deadline=None)
    @given(tiny_workloads(), st.floats(0.2, 50.0))
    def test_work_conservation(self, wl, k):
        """Useful node-seconds over an infinite window == total work,
        independent of the scheduler (nothing is lost or duplicated)."""
        # use a workload whose metric window covers the whole run by
        # appending a far-future sentinel job
        far = wl.submit.max() + 1e7
        wl2 = _mk_workload(
            np.concatenate([wl.submit, [far]]),
            np.concatenate([wl.runtime, [1.0]]),
            np.concatenate([wl.nodes, [1]]),
            np.concatenate([wl.jtype, [0]]),
            wl.params.n_types, wl.params.nodes)
        pw = pack_workload(wl2, jnp.float32)
        res = jax.tree.map(np.asarray, simulate_packet(pw, k, 5.0, wl2.params.nodes))
        assert res.ok
        # all but the sentinel's work is inside the window
        total_work = wl.work.sum()
        assert res.useful_ns == pytest.approx(total_work, rel=2e-2)
