"""Cohort equivalence suite: the workload axis of the batched sweep.

Pins the tentpole property of `repro.core.cohort` + `run_cohort_grid`:
stacking same-static workloads along a leading axis and running them as one
batched study returns, per workload, EXACTLY the metrics of the existing
per-workload `run_packet_grid` path — bitwise, in both dtypes — because the
scan engine's per-lane results are independent of whatever shares the
dispatch. Also covers the grouping/stacking layer itself: statics-keyed
cohort splitting, the clear mismatched-statics errors, and the vectorized
multi-seed batch generator landing in one cohort.
"""
import numpy as np
import pytest

from repro.core import (CohortKey, cohort_key, group_workloads,
                        run_cohort_grid, run_packet_grid, stack_workloads)
from repro.workload.lublin import (WorkloadParams, generate_workload,
                                   generate_workload_batch, group_by_statics,
                                   workload_statics)

KS = [0.5, 2.0, 8.0, 50.0, 300.0]
SP = [0.05, 0.5]


def _make_flows(loads, n_jobs=160, nodes=32, homogeneous=True, seed0=1,
                **kw):
    return {f"{'homog' if homogeneous else 'hetero'}{ld:.2f}":
            generate_workload(WorkloadParams(
                n_jobs=n_jobs, nodes=nodes, load=ld,
                homogeneous=homogeneous, seed=seed0 + i, **kw))
            for i, ld in enumerate(loads)}


@pytest.fixture(scope="module")
def homog_flows():
    return _make_flows((0.85, 0.95))


@pytest.fixture(scope="module")
def hetero_flows():
    return _make_flows((0.85, 0.90), n_jobs=140, nodes=64,
                       homogeneous=False, seed0=3)


def _assert_grids_equal(got, want, context=""):
    for f in want._fields:
        a, b = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        np.testing.assert_array_equal(a, b, err_msg=f"{context}: {f}")


class TestGrouping:
    def test_same_statics_one_cohort(self, homog_flows):
        cohorts = group_workloads(homog_flows, np.float32)
        assert len(cohorts) == 1
        assert cohorts[0].names == tuple(homog_flows)
        assert cohorts[0].key == CohortKey(32, 160, 8, "float32", 32)
        assert cohorts[0].label == "M32-N160-float32"
        for wl in homog_flows.values():
            assert cohort_key(wl, np.float32) == cohorts[0].key

    def test_mixed_statics_split_into_two_cohorts(self, homog_flows,
                                                  hetero_flows):
        mixed = {**homog_flows, **hetero_flows}
        cohorts = group_workloads(mixed, np.float32)
        assert len(cohorts) == 2
        # first-member insertion order preserved
        assert cohorts[0].names == tuple(homog_flows)
        assert cohorts[1].names == tuple(hetero_flows)

    def test_dtype_splits_cohorts(self, homog_flows):
        names = list(homog_flows)
        cohorts = group_workloads(homog_flows, {names[0]: np.float32,
                                                names[1]: np.float64})
        assert len(cohorts) == 2
        assert {c.key.dtype for c in cohorts} == {"float32", "float64"}

    def test_missing_dtype_mapping_raises(self, homog_flows):
        with pytest.raises(ValueError, match="no dtype given"):
            group_workloads(homog_flows, {list(homog_flows)[0]: np.float32})

    def test_paper_flow_shapes_form_two_cohorts(self):
        """The paper's 6-flow layout (hetero M=500 / homog M=100) under the
        paper_sweep dtype policy collapses to exactly two cohorts."""
        flows = {}
        flows.update(_make_flows((0.85, 0.90, 0.95), n_jobs=120, nodes=100,
                                 homogeneous=True))
        flows.update(_make_flows((0.85, 0.90, 0.95), n_jobs=120, nodes=500,
                                 homogeneous=False, seed0=11))
        dtypes = {name: (np.float32 if wl.params.homogeneous else np.float64)
                  for name, wl in flows.items()}
        cohorts = group_workloads(flows, dtypes)
        assert len(cohorts) == 2
        assert sorted(c.n_workloads for c in cohorts) == [3, 3]

    def test_group_by_statics_helper(self, homog_flows, hetero_flows):
        mixed = {**homog_flows, **hetero_flows}
        groups = group_by_statics(mixed)
        assert len(groups) == 2
        assert groups[(32, 160, 8)] == list(homog_flows)
        key = workload_statics(next(iter(hetero_flows.values())))
        assert groups[key] == list(hetero_flows)


class TestStacking:
    def test_stacked_leading_axis(self, homog_flows):
        spw = stack_workloads(list(homog_flows.values()))
        one = next(iter(homog_flows.values()))
        assert spw.n_jobs == one.n_jobs and spw.n_types == 8
        assert spw.submit.shape == (2, one.n_jobs)
        assert spw.tj_prefw.shape == (2, 8, one.n_jobs + 1)
        assert spw.t_last_submit.shape == (2,)

    def test_mismatched_n_jobs_raises(self, homog_flows):
        short = generate_workload(WorkloadParams(
            n_jobs=80, nodes=32, load=0.9, homogeneous=True, seed=9))
        with pytest.raises(ValueError, match="mismatched n_jobs"):
            stack_workloads([next(iter(homog_flows.values())), short])

    def test_mismatched_nodes_raises(self, homog_flows, hetero_flows):
        with pytest.raises(ValueError, match="mismatched m_nodes"):
            stack_workloads([next(iter(homog_flows.values())),
                             generate_workload(WorkloadParams(
                                 n_jobs=160, nodes=64, load=0.9,
                                 homogeneous=True, seed=4))])

    def test_empty_raises(self):
        with pytest.raises(ValueError, match="at least one"):
            stack_workloads([])

    def test_cohort_pack_is_cached(self, homog_flows):
        cohort = group_workloads(homog_flows, np.float32)[0]
        assert cohort.pack() is cohort.pack()


class TestCohortEquivalence:
    """Stacked-cohort results bitwise-match per-workload run_packet_grid."""

    @pytest.mark.parametrize("mode", ["chunked", "fused"])
    def test_float32_homogeneous(self, homog_flows, mode):
        cohort = group_workloads(homog_flows, np.float32)[0]
        grids = run_cohort_grid(cohort, KS, SP, mode=mode)
        assert set(grids) == set(homog_flows)
        for name, wl in homog_flows.items():
            want = run_packet_grid(wl, KS, SP, mode=mode)
            _assert_grids_equal(grids[name], want, f"{mode}/{name}")
            assert np.asarray(grids[name].ok).all()

    @pytest.mark.parametrize("mode", ["chunked", "fused"])
    def test_float64_heterogeneous(self, hetero_flows, mode):
        cohort = group_workloads(hetero_flows, np.float64)[0]
        grids = run_cohort_grid(cohort, KS, SP, mode=mode)
        for name, wl in hetero_flows.items():
            want = run_packet_grid(wl, KS, SP, dtype=np.float64, mode=mode)
            assert np.asarray(grids[name].avg_wait).dtype == np.float64
            _assert_grids_equal(grids[name], want, f"f64/{mode}/{name}")

    def test_seq_delegates_to_per_workload(self, homog_flows):
        cohort = group_workloads(homog_flows, np.float32)[0]
        grids = run_cohort_grid(cohort, KS[:2], SP, mode="seq")
        for name, wl in homog_flows.items():
            want = run_packet_grid(wl, KS[:2], SP, mode="seq")
            _assert_grids_equal(grids[name], want, f"seq/{name}")

    def test_results_keyed_to_right_workload(self, homog_flows):
        """Different loads produce different metrics; unstacking must not
        permute members."""
        cohort = group_workloads(homog_flows, np.float32)[0]
        grids = run_cohort_grid(cohort, KS, SP, mode="fused")
        a, b = (np.asarray(grids[n].avg_wait) for n in cohort.names)
        assert not np.array_equal(a, b)

    def test_legacy_vmap_modes_rejected(self, homog_flows):
        cohort = group_workloads(homog_flows, np.float32)[0]
        with pytest.raises(ValueError, match="no cohort layout"):
            run_cohort_grid(cohort, KS, SP, mode="vmap_k")

    def test_single_member_cohort(self, homog_flows):
        name, wl = next(iter(homog_flows.items()))
        cohort = group_workloads({name: wl}, np.float32)[0]
        grids = run_cohort_grid(cohort, KS, SP, mode="chunked")
        _assert_grids_equal(grids[name],
                            run_packet_grid(wl, KS, SP, mode="chunked"),
                            "W=1")


class TestWorkloadBatch:
    def test_replicas_share_statics_and_land_in_one_cohort(self):
        reps = generate_workload_batch(WorkloadParams(
            n_jobs=100, nodes=32, load=0.9, homogeneous=True, seed=5), 3)
        assert list(reps) == ["rep000", "rep001", "rep002"]
        assert len({workload_statics(wl) for wl in reps.values()}) == 1
        assert len(group_workloads(reps, np.float32)) == 1

    def test_replicas_differ_and_are_calibrated(self):
        reps = generate_workload_batch(WorkloadParams(
            n_jobs=100, nodes=32, load=0.9, homogeneous=True, seed=5), 3)
        digests = [wl.golden_digest()["submit"] for wl in reps.values()]
        assert len(set(digests)) == 3
        for wl in reps.values():
            assert wl.calculated_load() == pytest.approx(0.9)
            assert (np.diff(wl.submit) >= 0).all()

    def test_batch_is_deterministic(self):
        p = WorkloadParams(n_jobs=60, nodes=16, load=0.85, seed=7)
        a = generate_workload_batch(p, 2)
        b = generate_workload_batch(p, 2)
        for (na, wa), (nb, wb) in zip(a.items(), b.items()):
            assert na == nb and wa.golden_digest() == wb.golden_digest()

    def test_bad_replica_count_raises(self):
        with pytest.raises(ValueError, match="n_replicas"):
            generate_workload_batch(WorkloadParams(n_jobs=10), 0)

    def test_single_workload_generator_unchanged(self):
        """The shape-polymorphic helper refactor must not perturb the
        1-D generator stream (golden digests elsewhere pin the full
        pipeline; this pins the axis-aware arrival math directly)."""
        wl = generate_workload(WorkloadParams(n_jobs=50, nodes=16, seed=3))
        assert (np.diff(wl.submit) >= 0).all()
        assert wl.submit[0] >= 0.0 and wl.n_jobs == 50
