"""Chaos (failure/straggler/requeue) semantics of the DES engines.

Four contracts, in order of strictness:

1. Zero-chaos identity — chaos=None and an inert ChaosConfig (all-zero
   rates) produce bitwise-identical results in BOTH engines and both
   dtypes, and the sweep drivers normalize inert configs to the exact
   pre-chaos compiled programs.
2. Cross-engine parity — with chaos enabled the while and scan engines
   produce identical event sequences: schedules, group logs, and every
   integer/boolean counter agree exactly in both dtypes. Float metric
   accumulates may differ by ulps in either dtype — LLVM's FMA
   contraction is free to round the two engines' differently-shaped
   loop bodies differently — so those are checked allclose (tight in
   float64).
3. Dispatch-layout invariance — run_packet_grid/run_cohort_grid produce
   bitwise-identical Metrics for mode="seq"/"chunked"/"fused": every
   layout runs the same scan engine with grid-order lane ids, so chaos
   draws and rounding cannot depend on how lanes were batched.
4. Differential oracle — hand-computable 2-job failure and straggler
   cascades agree with the host-side ClusterSim (repro.cluster) when its
   rng is scripted to replay the DES lane's uniform stream.
"""
import math
import types
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CHAOS_AXIS_FIELDS, ChaosConfig, chaos_axis_len,
                        chaos_is_inert, chaos_lane_grid,
                        chaos_uniforms, cohort_key, efficiency_metrics,
                        group_workloads, pack_workload, precision,
                        resolve_max_requeues, resolve_ring, run_cohort_grid,
                        run_packet_grid, simulate_packet,
                        simulate_packet_scan, sweep_plan)
from repro.core.sweep import _enforce_budget
from repro.cluster import ClusterConfig, ClusterSim, JobType, MLJob
from repro.workload.lublin import WorkloadParams, generate_workload

from conftest import make_workload


def assert_fields_equal(a, b, fields=None, err=""):
    for f in fields or a._fields:
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), (err, f)


@pytest.fixture(scope="module")
def chaos_workload():
    return generate_workload(WorkloadParams(
        n_jobs=80, nodes=32, load=0.9, homogeneous=True, seed=5))


# cell 0 is straggler-only and cell 1 failure-only: failures take
# precedence over kills at group end, so a harsh MTBF would mask every
# straggler kill in its cell
CHAOS_GRID = ChaosConfig(mtbf_chip_hours=np.asarray([0.0, 0.2]),
                         ckpt_period=120.0,
                         straggler_prob=np.asarray([0.3, 0.0]),
                         straggler_factor=4.0, straggler_deadline=2.0,
                         seed=11)
KS = [0.5, 2.0, 20.0]
SP = [0.05, 0.2]


# ------------------------------------------------------- zero-chaos identity

class TestZeroChaosIdentity:
    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    @pytest.mark.parametrize("engine", [simulate_packet,
                                        simulate_packet_scan])
    def test_inert_config_bitwise(self, chaos_workload, dtype, engine):
        m = chaos_workload.params.nodes
        with precision.dtype_scope(dtype):
            pw = pack_workload(chaos_workload, dtype)
            ring = resolve_ring(m, pw.n_jobs)
            k = jnp.asarray(2.0, dtype)
            s = jnp.asarray(chaos_workload.init_time_for_proportion(0.2),
                            dtype)
            r0 = engine(pw, k, s, m, ring=ring)
            rz = engine(pw, k, s, m, ring=ring, chaos=ChaosConfig())
        assert_fields_equal(r0, rz, err=engine.__name__)

    def test_inert_detection(self):
        assert chaos_is_inert(None)
        assert chaos_is_inert(ChaosConfig())
        assert chaos_is_inert(ChaosConfig(ckpt_period=60.0, seed=9))
        assert not chaos_is_inert(ChaosConfig(mtbf_chip_hours=1.0))
        assert not chaos_is_inert(ChaosConfig(straggler_prob=0.5))
        assert not chaos_is_inert(
            ChaosConfig(mtbf_chip_hours=np.asarray([0.0, 0.1])))

    @pytest.mark.parametrize("mode", ["seq", "chunked"])
    def test_grid_normalizes_inert_config(self, chaos_workload, mode):
        kw = dict(mode=mode)
        if mode == "chunked":
            kw["chunk_lanes"] = 4
        g0 = run_packet_grid(chaos_workload, KS, SP, **kw)
        gz = run_packet_grid(chaos_workload, KS, SP, chaos=ChaosConfig(),
                             **kw)
        assert_fields_equal(g0, gz, err=mode)
        assert g0.avg_wait.shape == (len(KS), len(SP))

    def test_cohort_key_normalizes_inert_config(self, chaos_workload):
        assert cohort_key(chaos_workload, chaos=ChaosConfig()) == \
            cohort_key(chaos_workload)
        assert cohort_key(chaos_workload, chaos=ChaosConfig()).max_requeues \
            == 0


# ---------------------------------------------------- cross-engine parity

class TestEngineChaosParity:
    # schedules and integer/boolean outputs must agree exactly in every
    # dtype; float metric accumulates only up to FMA-contraction ulps
    # (see the module docstring)
    EXACT = ("start_t", "run_start_t", "n_groups", "makespan", "ok",
             "budget_exhausted", "failures", "straggler_kills", "requeues",
             "requeued_jobs")

    @pytest.mark.parametrize("lane", [0, 2, 7])
    @pytest.mark.parametrize("dtype,rtol", [(np.float32, 1e-5),
                                            (np.float64, 1e-12)])
    def test_engines_agree(self, chaos_workload, lane, dtype, rtol):
        m = chaos_workload.params.nodes
        ch = ChaosConfig(mtbf_chip_hours=0.02, ckpt_period=120.0,
                         straggler_prob=0.3, seed=11, lane=lane)
        with precision.dtype_scope(dtype):
            pw = pack_workload(chaos_workload, dtype)
            ring = resolve_ring(m, pw.n_jobs)
            k = jnp.asarray(0.5, dtype)
            s = jnp.asarray(chaos_workload.init_time_for_proportion(0.2),
                            dtype)
            rw = simulate_packet(pw, k, s, m, ring=ring, chaos=ch)
            rs = simulate_packet_scan(pw, k, s, m, ring=ring, chaos=ch)
        assert bool(rw.ok) and int(rw.failures) > 0
        assert_fields_equal(rw, rs, fields=self.EXACT, err=f"lane {lane}")
        for f in set(rw._fields) - set(self.EXACT):
            np.testing.assert_allclose(np.asarray(getattr(rw, f)),
                                       np.asarray(getattr(rs, f)),
                                       rtol=rtol, err_msg=f)


# ----------------------------------------------- dispatch-layout invariance

class TestSweepChaosParity:
    def test_seq_chunked_fused_bitwise(self, chaos_workload):
        g_seq = run_packet_grid(chaos_workload, KS, SP, mode="seq",
                                chaos=CHAOS_GRID)
        g_chk = run_packet_grid(chaos_workload, KS, SP, mode="chunked",
                                chunk_lanes=4, chaos=CHAOS_GRID)
        g_fus = run_packet_grid(chaos_workload, KS, SP, mode="fused",
                                chaos=CHAOS_GRID)
        C = chaos_axis_len(CHAOS_GRID)
        assert C == 2
        assert g_seq.avg_wait.shape == (len(KS), len(SP), C)
        assert_fields_equal(g_seq, g_chk, err="seq vs chunked")
        assert_fields_equal(g_seq, g_fus, err="seq vs fused")
        # each chaos cell fires exactly its own fault kind
        assert int(np.sum(g_seq.straggler_kills[..., 0])) > 0
        assert int(np.sum(g_seq.failures[..., 0])) == 0
        assert int(np.sum(g_seq.failures[..., 1])) > 0
        assert int(np.sum(g_seq.straggler_kills[..., 1])) == 0

    def test_chaos_axis_len_validates(self):
        with pytest.raises(ValueError):
            chaos_axis_len(ChaosConfig(
                mtbf_chip_hours=np.asarray([0.1, 0.2]),
                straggler_prob=np.asarray([0.1, 0.2, 0.3])))

    def test_cohort_matches_per_workload(self, chaos_workload):
        wl2 = generate_workload(WorkloadParams(
            n_jobs=80, nodes=32, load=0.9, homogeneous=True, seed=9))
        cohorts = group_workloads({"a": chaos_workload, "b": wl2},
                                  np.float32, chaos=CHAOS_GRID)
        assert len(cohorts) == 1
        assert cohorts[0].key.max_requeues == \
            resolve_max_requeues(CHAOS_GRID, 80)
        gc = run_cohort_grid(cohorts[0], KS, SP, mode="fused",
                             chaos=CHAOS_GRID)
        gc_chk = run_cohort_grid(cohorts[0], KS, SP, mode="chunked",
                                 chunk_lanes=4, chaos=CHAOS_GRID)
        ga = run_packet_grid(chaos_workload, KS, SP, mode="fused",
                             chaos=CHAOS_GRID)
        assert_fields_equal(gc["a"], ga, err="cohort vs per-workload")
        for name in ("a", "b"):
            assert_fields_equal(gc[name], gc_chk[name], err=name)

    def test_sweep_plan_records_chaos(self):
        plan = sweep_plan("auto", len(KS) * len(SP), chaos=CHAOS_GRID)
        assert plan["n_lanes"] == len(KS) * len(SP) * 2
        ch = plan["chaos"]
        assert ch["axis_len"] == 2 and ch["seed"] == 11
        assert ch["mtbf_chip_hours"] == pytest.approx([0.0, 0.2])
        assert ch["straggler_prob"] == pytest.approx([0.3, 0.0])
        # inert configs vanish from the plan like they do from the run
        assert "chaos" not in sweep_plan("auto", 6, chaos=ChaosConfig())


# ---------------------------------------------- chaos-axis error reporting

class TestChaosAxisValidation:
    def test_mismatched_lengths_name_fields(self):
        bad = ChaosConfig(mtbf_chip_hours=np.asarray([0.1, 0.2]),
                          straggler_prob=np.asarray([0.1, 0.2, 0.3]))
        with pytest.raises(ValueError) as ei:
            chaos_axis_len(bad)
        msg = str(ei.value)
        assert "mtbf_chip_hours[2]" in msg
        assert "straggler_prob[3]" in msg

    def test_2d_param_names_field(self):
        with pytest.raises(ValueError,
                           match=r"ckpt_period must be a scalar or a 1-D"):
            chaos_axis_len(ChaosConfig(mtbf_chip_hours=0.1,
                                       ckpt_period=np.ones((2, 2))))

    def test_scalar_array_mix_broadcasts(self):
        mix = ChaosConfig(mtbf_chip_hours=np.asarray([0.1, 0.2]),
                          ckpt_period=120.0,
                          straggler_prob=np.asarray([0.3]))
        assert chaos_axis_len(mix) == 2      # len-1 arrays broadcast too
        lanes, C = chaos_lane_grid(mix, 3, np.float32)
        assert C == 2
        for name in CHAOS_AXIS_FIELDS:
            assert np.shape(getattr(lanes, name)) == (6,), name
        np.testing.assert_allclose(np.asarray(lanes.mtbf_chip_hours),
                                   [0.1, 0.2] * 3, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(lanes.straggler_prob),
                                   [0.3] * 6, rtol=1e-6)
        assert np.array_equal(np.asarray(lanes.lane), np.arange(6))

    def test_lane_grid_propagates_named_error(self):
        bad = ChaosConfig(mtbf_chip_hours=np.asarray([0.1, 0.2]),
                          straggler_deadline=np.asarray([2.0, 2.0, 2.0]))
        with pytest.raises(ValueError, match=r"straggler_deadline\[3\]"):
            chaos_lane_grid(bad, 4, np.float32)


# -------------------------------------------------- ClusterSim differential

class ScriptedRng:
    """Replays a DES lane's uniform stream into ClusterSim's rng calls.

    ClusterSim draws `random()` once per group at formation (straggler)
    and `exponential(scale)` once per group at its finish (failure). With
    single-type sequential groups both orders equal formation order, so
    row g of `chaos_uniforms` maps onto ClusterSim's g-th draw of each
    kind; an exponential draw is the inverse-CDF of the failure uniform,
    exactly the scan engine's `t_fail` formula.
    """

    def __init__(self, u):
        self.u = np.asarray(u)
        self.n_random = 0
        self.n_exp = 0
        self.exp_scales = []

    def random(self):
        v = float(self.u[self.n_random, 0])
        self.n_random += 1
        return v

    def exponential(self, scale):
        self.exp_scales.append(float(scale))
        v = -math.log(max(float(self.u[self.n_exp, 1]), 5e-324)) * scale
        self.n_exp += 1
        return v


def _hand_des(chaos, submit, runtime, k, s=100.0, m=4):
    """Run both engines on a single-type hand workload (nodes=1 jobs, so
    work == runtime) and return (while_result, scan_result, uniforms)."""
    n = len(submit)
    with precision.dtype_scope(np.float64):
        wl = make_workload(submit, runtime, [1] * n, [0] * n, 1, m)
        pw = pack_workload(wl, np.float64)
        ring = resolve_ring(m, n)
        cap = n + resolve_max_requeues(chaos, n)
        u = np.asarray(chaos_uniforms(chaos, np.float64, cap))
        rw = simulate_packet(pw, jnp.float64(k), jnp.float64(s), m,
                             ring=ring, chaos=chaos)
        rs = simulate_packet_scan(pw, jnp.float64(k), jnp.float64(s), m,
                                  ring=ring, chaos=chaos)
    return rw, rs, u


def _hand_cluster(cfg, submit, runtime, u, s=100.0):
    sim = ClusterSim([JobType("t0", init_time=s, tp_degree=1)], cfg)
    sim.rng = ScriptedRng(u)
    for i, (t, w) in enumerate(zip(submit, runtime)):
        sim.submit(MLJob(jid=i, jtype=0, submit=float(t), work=float(w)))
    return sim, sim.run()


def _two_job_des(chaos, k, s=100.0, m=4):
    with precision.dtype_scope(np.float64):
        wl = make_workload([0.0, 0.0], [6000.0, 6000.0], [1, 1], [0, 0],
                           1, m)
        pw = pack_workload(wl, np.float64)
        ring = resolve_ring(m, 2)
        cap = 2 + resolve_max_requeues(chaos, 2)
        u = np.asarray(chaos_uniforms(chaos, np.float64, cap))
        rw = simulate_packet(pw, jnp.float64(k), jnp.float64(s), m,
                             ring=ring, chaos=chaos)
        rs = simulate_packet_scan(pw, jnp.float64(k), jnp.float64(s), m,
                                  ring=ring, chaos=chaos)
    return rw, rs, u


def _two_job_cluster(cfg, u, s=100.0):
    sim = ClusterSim([JobType("t0", init_time=s, tp_degree=1)], cfg)
    sim.rng = ScriptedRng(u)
    sim.submit(MLJob(jid=0, jtype=0, submit=0.0, work=6000.0))
    sim.submit(MLJob(jid=1, jtype=0, submit=0.0, work=6000.0))
    return sim, sim.run()


class TestClusterSimDifferential:
    def test_failure_requeue_case(self):
        """Group 1 (job A alone, dur 1600) fails mid-run; its checkpointed
        remainder pools with job B into group 2, which survives.

        Hand model (s=100, M=4, k=2, mtbf=1 chip-hour, ckpt=300; seed 82
        picked so t_fail1 in (500, 1500) and group 2 survives):
          t_fail   = t0 - ln(u2) * mtbf*3600/m = -ln(u[0,1]) * 900
          run_done = t_fail - 100;  ckpt_done = 300*floor(run_done/300)
          lost     = (run_done - ckpt_done) * 4
          group 2  = B + A-remainder = 12000 - 4*ckpt_done chip-s at
                     t=1600, dur2 = 100 + work2/4.
        """
        chaos = ChaosConfig(mtbf_chip_hours=1.0, ckpt_period=300.0,
                            seed=82, lane=0)
        rw, rs, u = _two_job_des(chaos, k=2.0)
        t_fail = -math.log(max(u[0, 1], 5e-324)) * 900.0
        assert 500.0 < t_fail < 1500.0
        ckpt_done = 300.0 * math.floor((t_fail - 100.0) / 300.0)
        lost = (t_fail - 100.0 - ckpt_done) * 4
        dur2 = 100.0 + (12000.0 - 4 * ckpt_done) / 4.0

        for eng, r in (("while", rw), ("scan", rs)):
            assert bool(r.ok), eng
            assert int(r.n_groups) == 2, eng
            assert int(r.failures) == 1 and int(r.requeues) == 1, eng
            assert int(r.straggler_kills) == 0, eng
            assert float(r.lost_work) == pytest.approx(lost, rel=1e-12), eng
            assert float(r.makespan) == pytest.approx(1600.0 + dur2), eng
            np.testing.assert_allclose(np.asarray(r.start_t),
                                       [0.0, 1600.0], err_msg=eng)

        cfg = ClusterConfig(n_chips=4, scale_ratio=2.0, ckpt_period=300.0,
                            mtbf_chip_hours=1.0)
        sim, cm = _two_job_cluster(cfg, u)
        assert cm["groups"] == 2 and cm["failures"] == 1
        assert cm["requeues"] == 1 and cm["straggler_kills"] == 0
        assert cm["unfinished"] == 0
        assert cm["lost_chip_seconds"] == pytest.approx(lost, rel=1e-12)
        assert cm["makespan"] == pytest.approx(1600.0 + dur2)
        # the scripted draw really used the slice failure rate m/MTBF
        assert sim.rng.exp_scales == [900.0, 900.0]
        # requeued-then-completed job A reports its LAST completion time
        assert sim.jobs[0].finish == pytest.approx(1600.0 + dur2)
        assert sim.jobs[1].finish == pytest.approx(1600.0 + dur2)

    def test_straggler_cascade_case(self):
        """Every group stretches 4x against a 2x deadline: a kill cascade
        whose arithmetic is dyadic, hence exact in float64.

        Hand model (s=100, M=4, k=0.25, prob=1, factor=4, deadline=2):
        each round runs to its deadline 2*(100 + work/4), credits
        (deadline - 100) chip-seconds/chip / factor, and requeues the
        rest; work shrinks 12000 -> 8900 -> ... until round 7 fits its
        deadline. Ends at exactly t=12700 after 6 kills. `max_requeues=8`
        keeps the DES injection gate open for all rounds (ClusterSim is
        uncapped).
        """
        chaos = ChaosConfig(straggler_prob=1.0, straggler_factor=4.0,
                            straggler_deadline=2.0, seed=0, lane=0,
                            max_requeues=8)
        rw, rs, u = _two_job_des(chaos, k=0.25)
        for eng, r in (("while", rw), ("scan", rs)):
            assert bool(r.ok), eng
            assert int(r.n_groups) == 7, eng
            assert int(r.straggler_kills) == 6, eng
            assert int(r.requeues) == 6 and int(r.failures) == 0, eng
            assert float(r.lost_work) == 0.0, eng
            assert float(r.makespan) == 12700.0, eng

        cfg = ClusterConfig(n_chips=4, scale_ratio=0.25, straggler_prob=1.0,
                            straggler_factor=4.0, straggler_deadline=2.0)
        sim, cm = _two_job_cluster(cfg, u)
        assert cm["groups"] == 7 and cm["straggler_kills"] == 6
        assert cm["requeues"] == 6 and cm["failures"] == 0
        assert cm["unfinished"] == 0
        assert cm["makespan"] == 12700.0

    def test_partial_credit_splits_inside_member(self):
        """Group 2 = {B(4000), C(6000)} fails with checkpoint credit 6000
        chip-s: B completes inside the credit, C requeues alone with a
        2000 chip-s residual. The remnant must be ONE member (oldest
        submit 2.0) — the pre-fix aggregate pool re-queued the whole
        member count (2) because it never knew where the credit landed.

        Hand model (s=100, M=4, k=0.25, mtbf=1 chip-hour, ckpt=300;
        seed 6 picked so groups 1 and 3 survive while group 2 fails at
        t_fail in [1600, 1900) => ckpt_done 1500, credit 4*1500=6000):
          A: submit 0, work 6000 -> group 1 [0, 1600), all 4 chips
          B, C: submit 1, 2 -> queue; group 2 at t=1600, work 10000,
             dur 2600, fails; credit 6000 = B's 4000 + 2000 into C
          group 3 at t=4200: {C}, work 4000, dur 1100 -> makespan 5300.
        """
        chaos = ChaosConfig(mtbf_chip_hours=1.0, ckpt_period=300.0,
                            seed=6, lane=0)
        submit = [0.0, 1.0, 2.0]
        runtime = [6000.0, 4000.0, 6000.0]
        rw, rs, u = _hand_des(chaos, submit, runtime, k=0.25)
        t_fails = [-math.log(max(u[g, 1], 5e-324)) * 900.0 for g in range(3)]
        assert t_fails[0] > 1600.0 and t_fails[2] > 1100.0
        assert 1600.0 <= t_fails[1] < 1900.0     # => ckpt_done == 1500
        lost = (t_fails[1] - 100.0 - 1500.0) * 4

        for eng, r in (("while", rw), ("scan", rs)):
            assert bool(r.ok), eng
            assert int(r.n_groups) == 3, eng
            assert int(r.failures) == 1 and int(r.requeues) == 1, eng
            # the fix under test: one member requeued, not the pool's 2
            assert int(r.requeued_jobs) == 1, eng
            assert float(r.lost_work) == pytest.approx(lost, rel=1e-12), eng
            assert float(r.makespan) == 5300.0, eng
            np.testing.assert_allclose(np.asarray(r.start_t),
                                       [0.0, 1600.0, 1600.0], err_msg=eng)

        cfg = ClusterConfig(n_chips=4, scale_ratio=0.25, ckpt_period=300.0,
                            mtbf_chip_hours=1.0)
        sim, cm = _hand_cluster(cfg, submit, runtime, u)
        assert cm["groups"] == 3 and cm["failures"] == 1
        assert cm["requeues"] == 1 and cm["requeued_jobs"] == 1
        assert cm["unfinished"] == 0
        assert cm["lost_chip_seconds"] == pytest.approx(lost, rel=1e-12)
        assert cm["makespan"] == 5300.0
        # B finished by the requeue credit at group 2's end; C ran again
        assert sim.jobs[1].finish == 4200.0
        assert sim.jobs[2].finish == 5300.0

    def test_residual_carry_across_requeues(self):
        """One job killed four times: each walk must start from the
        pool's carried residual, or the remnant work (and every later
        duration) is wrong — dropping res0 gives a remnant of 4450
        instead of 1350 in round 2 alone.

        Hand model (s=100, M=4, k=0.25, prob=1, factor=4, deadline=2),
        all dyadic: deadline-kill credits 3100, 1550, 775, 387.5
        accumulate on the single member; remainders 2900 -> 1350 -> 575
        -> 187.5; round 5 fits its deadline (287.5 <= 293.75). Ends at
        3200 + 1650 + 875 + 487.5 + 287.5 = 6500 exactly.
        """
        chaos = ChaosConfig(straggler_prob=1.0, straggler_factor=4.0,
                            straggler_deadline=2.0, seed=0, lane=0,
                            max_requeues=8)
        submit, runtime = [0.0], [6000.0]
        rw, rs, u = _hand_des(chaos, submit, runtime, k=0.25)
        for eng, r in (("while", rw), ("scan", rs)):
            assert bool(r.ok), eng
            assert int(r.n_groups) == 5, eng
            assert int(r.straggler_kills) == 4 and int(r.requeues) == 4, eng
            assert int(r.requeued_jobs) == 4, eng
            assert float(r.lost_work) == 0.0, eng
            assert float(r.makespan) == 6500.0, eng

        cfg = ClusterConfig(n_chips=4, scale_ratio=0.25, straggler_prob=1.0,
                            straggler_factor=4.0, straggler_deadline=2.0)
        sim, cm = _hand_cluster(cfg, submit, runtime, u)
        assert cm["groups"] == 5 and cm["straggler_kills"] == 4
        assert cm["requeues"] == 4 and cm["requeued_jobs"] == 4
        assert cm["unfinished"] == 0 and cm["makespan"] == 6500.0


# ----------------------------------------------------- budget exhaustion

class TestBudgetExhaustion:
    def _truncated_metrics(self, wl):
        m = wl.params.nodes
        pw = pack_workload(wl, np.float32)
        ring = resolve_ring(m, pw.n_jobs)
        k = jnp.float32(2.0)
        s = jnp.float32(wl.init_time_for_proportion(0.2))
        res = simulate_packet_scan(pw, k, s, m, ring=ring, budget=8, seg=8)
        return efficiency_metrics(pw.submit, res, m, pw.t_last_submit)

    def test_tiny_budget_flags_scan(self, chaos_workload):
        met = self._truncated_metrics(chaos_workload)
        assert bool(met.budget_exhausted) and not bool(met.ok)

    def test_tiny_iteration_cap_flags_while(self, chaos_workload):
        m = chaos_workload.params.nodes
        pw = pack_workload(chaos_workload, np.float32)
        ring = resolve_ring(m, pw.n_jobs)
        s = jnp.float32(chaos_workload.init_time_for_proportion(0.2))
        res = simulate_packet(pw, jnp.float32(2.0), s, m, ring=ring,
                              max_iters=3)
        assert bool(res.budget_exhausted) and not bool(res.ok)

    def test_enforce_budget_policies(self, chaos_workload):
        met = self._truncated_metrics(chaos_workload)
        with pytest.raises(RuntimeError, match="event budget"):
            _enforce_budget(met, "raise", "test")
        with pytest.warns(RuntimeWarning, match="event budget"):
            _enforce_budget(met, "warn", "test")
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            _enforce_budget(met, "ignore", "test")
        with pytest.raises(ValueError):
            _enforce_budget(met, "explode", "test")

    def test_enforce_budget_names_grid_cells(self):
        bad = np.zeros((3, 2), bool)
        bad[0, 1] = bad[2, 0] = True
        met = types.SimpleNamespace(budget_exhausted=bad)
        with pytest.raises(RuntimeError) as ei:
            _enforce_budget(met, "raise", "grid", ks=KS, s_props=SP)
        msg = str(ei.value)
        assert "2 lane(s)" in msg
        assert "(i_k=0, i_s=1, k=0.5, s_prop=0.2)" in msg
        assert "(i_k=2, i_s=0, k=20, s_prop=0.05)" in msg

    def test_enforce_budget_names_chaos_cells(self):
        bad = np.zeros((2, 2, 3), bool)
        bad[1, 0, 2] = True
        met = types.SimpleNamespace(budget_exhausted=bad)
        with pytest.raises(RuntimeError, match=r"i_k=1, i_s=0, i_chaos=2"):
            _enforce_budget(met, "raise", "grid")

    def test_enforce_budget_truncates_flat_lanes(self):
        met = types.SimpleNamespace(budget_exhausted=np.ones(12, bool))
        with pytest.raises(RuntimeError) as ei:
            _enforce_budget(met, "raise", "flat")
        msg = str(ei.value)
        assert "lane=0" in msg and "lane=7" in msg
        assert "lane=8" not in msg and "... 4 more" in msg

    def test_enforce_budget_scalar_experiment(self, chaos_workload):
        met = self._truncated_metrics(chaos_workload)
        with pytest.raises(RuntimeError, match="the single experiment"):
            _enforce_budget(met, "raise", "one-shot")

    def test_grid_budget_clean_under_chaos(self, chaos_workload):
        """The sized budget (3N + 2R + slack) drains every chaos lane: the
        default on_budget_exhausted='raise' passes untripped."""
        g = run_packet_grid(chaos_workload, KS, SP, mode="fused",
                            chaos=CHAOS_GRID, on_budget_exhausted="raise")
        assert not np.asarray(g.budget_exhausted).any()
        assert np.asarray(g.ok).all()
