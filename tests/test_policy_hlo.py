"""Sharding-policy resolver + HLO collective-parser tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.hlo_stats import collective_stats
from repro.sharding.policy import resolve

MESH1 = {"data": 16, "model": 16}
MESH2 = {"pod": 2, "data": 16, "model": 16}


def test_tp_heads_when_divisible():
    pol = resolve(get_config("qwen2-moe-a2.7b"), MESH1, 256, "train",
                  seq=4096, strategy="tp")
    assert pol.attn_mode == "tp_heads" and pol.kv_repeat == 1
    assert pol.expert_pad == 64                  # 60 -> 64 for EP=16


def test_kv_replication_exactness_condition():
    pol = resolve(get_config("yi-6b"), MESH1, 256, "train", strategy="tp")
    assert pol.attn_mode == "tp_heads" and pol.kv_repeat == 4   # kv 4 -> 16
    pol = resolve(get_config("granite-3-2b"), MESH1, 256, "train",
                  strategy="tp")
    assert pol.kv_repeat == 2                                   # kv 8 -> 16


def test_dp_batch_for_odd_heads():
    for arch in ("phi3-medium-14b", "starcoder2-7b", "arctic-480b"):
        pol = resolve(get_config(arch), MESH1, 256, "train", strategy="tp")
        assert pol.attn_mode == "dp_batch", arch
        assert pol.rules["heads"] is None
        assert "model" in pol.rules["attn_batch"]


def test_multipod_odd_heads_fall_back():
    # batch 256 cannot span 512 chips: dp_batch unavailable -> none
    pol = resolve(get_config("phi3-medium-14b"), MESH2, 256, "train",
                  strategy="tp")
    assert pol.attn_mode == "none"


def test_decode_seq_kv_fallback():
    pol = resolve(get_config("starcoder2-7b"), MESH1, 128, "decode",
                  seq=32768)
    assert pol.decode_attn == "seq_kv"
    assert pol.rules["cache_seq"] == "model"


def test_serve_never_fsdp():
    for arch in ARCHS:
        for step in ("prefill", "decode"):
            pol = resolve(get_config(arch), MESH1, 32, step, seq=32768)
            assert pol.rules["embed_fsdp"] is None, (arch, step)
            assert pol.strategy == "serve"


def test_auto_strategy_napkin_math():
    # small dense model: DP wins (param mass tiny vs activation collectives)
    pol = resolve(get_config("granite-3-2b"), MESH1, 256, "train", seq=4096)
    assert pol.strategy in ("dp_zero1", "dp_zero3")
    # huge MoE: must use TP+EP (params cannot replicate or gather)
    pol = resolve(get_config("arctic-480b"), MESH1, 256, "train", seq=4096)
    assert pol.strategy == "tp"
    # any strategy note records the napkin estimates
    assert any("napkin" in n for n in pol.notes)


def test_batch_1_not_sharded():
    pol = resolve(get_config("xlstm-1.3b"), MESH1, 1, "decode", seq=524288)
    assert pol.batch_axes is None


def test_policy_rules_have_no_duplicate_axes():
    """Every (arch, shape-kind) policy must yield specs usable on the mesh:
    no mesh axis appears twice in one spec."""
    from repro.models.layers import unbox
    from repro.models.registry import get_family
    for arch in ARCHS:
        cfg = get_config(arch)
        for step, batch in (("train", 256), ("decode", 128)):
            pol = resolve(cfg, MESH1, batch, step, seq=4096)
            fam = get_family(cfg)
            boxed = jax.eval_shape(
                lambda k: fam.init_params(cfg, pol, k),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            _, axes = unbox(boxed)
            for ax in jax.tree.leaves(
                    axes, is_leaf=lambda x: isinstance(x, tuple)):
                spec = pol.spec(ax)
                flat = []
                for e in spec:
                    if isinstance(e, tuple):
                        flat.extend(e)
                    elif e is not None:
                        flat.append(e)
                assert len(flat) == len(set(flat)), (arch, step, ax, spec)


# ------------------------------------------------------------- HLO parser

def test_collective_parser_scales_by_trip_count():
    hlo = """
HloModule test
%add (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(%a, %b)
}
%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups=[4,4]<=[16], to_apply=%add
  ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar)
}
%cond (p: (s32[], f32[8,128])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %w = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body
  %ag = f32[32,128]{1,0} all-gather(%shard), replica_groups=[2,8]<=[16], dimensions={0}
  ROOT %o = f32[8,128] get-tuple-element(%w), index=1
}
"""
    st = collective_stats(hlo)
    assert st.n_whiles == 1
    # all-reduce: 8*128*4 = 4096 B x 12 trips
    assert st.op_count["all-reduce"] == 12.0
    assert st.op_bytes["all-reduce"] == 4096.0 * 12
    # all-gather result 32*128*4=16384, operand = /8
    assert st.op_bytes["all-gather"] == 16384 / 8
    # link: AR 2*(3/4)*4096*12 + AG (7/8)*16384
    assert st.link_bytes_per_device == pytest.approx(
        2 * 0.75 * 4096 * 12 + 7 / 8 * 16384)


def test_parser_on_real_compiled_module():
    mesh = jax.make_mesh((1,), ("data",))
    f = jax.jit(lambda x: x @ x.T)
    c = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    st = collective_stats(c.as_text())   # no collectives on 1 device
    assert st.total_bytes() == 0


# ------------------------------------------------------------- roofline

def test_analytic_param_count_matches_real_models():
    """The napkin-math param model must track the real builders within 2%
    (it is what strategy selection and MODEL_FLOPS are computed from)."""
    from repro.models import analysis
    from repro.models.layers import unbox
    from repro.models.registry import get_family
    for arch in ARCHS:
        cfg = get_config(arch)
        pol = resolve(cfg, MESH1, 256, "train", strategy="tp")
        fam = get_family(cfg)
        boxed = jax.eval_shape(lambda k: fam.init_params(cfg, pol, k),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        shapes, _ = unbox(boxed)
        real = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        pred = analysis.param_count(cfg, pol.expert_pad)
        err = abs(real - pred) / real
        assert err < 0.02, (arch, real, pred, err)
