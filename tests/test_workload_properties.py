"""Property-based tests for the workload generator (optional hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.workload.lublin import WorkloadParams, generate_workload  # noqa: E402


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([0.85, 0.9, 0.95]),
       st.booleans())
def test_property_any_seed_valid(seed, load, homog):
    wl = generate_workload(WorkloadParams(
        n_jobs=200, load=load, homogeneous=homog, seed=seed,
        nodes=100 if homog else 500))
    assert np.all(wl.runtime > 0)
    assert np.all(np.isfinite(wl.work))
    assert wl.calculated_load() == pytest.approx(load, rel=1e-6)
