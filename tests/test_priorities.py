"""Paper Step-2 weight knobs exercised end-to-end in the DES: job-type
priorities P_j and the aging normalizer T_max (starvation control)."""
import jax.numpy as jnp
import numpy as np

from repro.core.des import pack_workload, simulate_packet
from repro.core.metrics import efficiency_metrics
from repro.workload.lublin import WorkloadParams, generate_workload


def _wl(seed=11):
    return generate_workload(WorkloadParams(
        n_jobs=400, nodes=32, load=0.95, homogeneous=True, seed=seed))


def _per_type_wait(wl, res):
    wait = np.asarray(res.start_t) - wl.submit
    return np.array([wait[wl.jtype == j].mean()
                     for j in range(wl.params.n_types)])


def test_priority_lowers_wait_for_favored_type():
    wl = _wl()
    pw = pack_workload(wl)
    s = wl.init_time_for_proportion(0.30)
    H = wl.params.n_types
    base = simulate_packet(pw, 2.0, s, wl.params.nodes)
    pri = jnp.ones((H,)).at[3].set(50.0)
    fav = simulate_packet(pw, 2.0, s, wl.params.nodes, priority=pri)
    assert bool(base.ok) and bool(fav.ok)
    w_base = _per_type_wait(wl, base)
    w_fav = _per_type_wait(wl, fav)
    # favored type improves substantially (not zero-sum: regrouping can
    # help other types too, so only the favored direction is asserted)
    assert w_fav[3] <= w_base[3] / 2.0
    # and becomes (near-)best-served relative to its baseline rank
    assert (w_fav[3] <= np.sort(w_fav)[1] + 1e-6) or \
        (w_fav[3] <= w_base.min())


def test_tmax_aging_bounds_starvation():
    """Small T_max ages queues faster: the worst per-type wait shrinks."""
    wl = _wl(seed=13)
    pw = pack_workload(wl)
    s = wl.init_time_for_proportion(0.30)
    H = wl.params.n_types
    slow = simulate_packet(pw, 2.0, s, wl.params.nodes,
                           t_max=jnp.full((H,), 1e9))
    fast = simulate_packet(pw, 2.0, s, wl.params.nodes,
                           t_max=jnp.full((H,), 60.0))
    assert bool(slow.ok) and bool(fast.ok)
    w_slow = _per_type_wait(wl, slow)
    w_fast = _per_type_wait(wl, fast)
    assert w_fast.max() <= w_slow.max() * 1.1
    # aging trades tail for mean only mildly
    m_slow = efficiency_metrics(pw.submit, slow, wl.params.nodes,
                                pw.t_last_submit)
    m_fast = efficiency_metrics(pw.submit, fast, wl.params.nodes,
                                pw.t_last_submit)
    assert float(m_fast.useful_util) > 0.2
    assert float(m_slow.useful_util) > 0.2
