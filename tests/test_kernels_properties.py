"""Property-based kernel sweeps (optional hypothesis dev dependency)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.kernels.flash_attention.ops import flash_attention  # noqa: E402
from repro.kernels.flash_attention.ref import attention_ref  # noqa: E402
from repro.kernels.rglru_scan.kernel import lru_chunked  # noqa: E402
from repro.kernels.rglru_scan.ref import lru_ref  # noqa: E402


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(8, 96), skv_extra=st.integers(0, 64),
       h=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2]),
       hd=st.sampled_from([16, 32]))
def test_flash_attention_property(sq, skv_extra, h, g, hd):
    """Property: any (Sq, Skv>=Sq, H=KV*g, hd) agrees with the oracle."""
    skv = sq + skv_extra
    ks = jax.random.split(jax.random.PRNGKey(sq * 131 + skv), 3)
    q = jax.random.normal(ks[0], (1, sq, h * g, hd))
    k = jax.random.normal(ks[1], (1, skv, h, hd))
    v = jax.random.normal(ks[2], (1, skv, h, hd))
    out = flash_attention(q, k, v, bq=32, bkv=32)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(4, 80), d=st.integers(1, 200),
       chunk=st.sampled_from([8, 16, 32]))
def test_lru_property(s, d, chunk):
    """Property: chunked == associative-scan for arbitrary S, D, chunk."""
    ks = jax.random.split(jax.random.PRNGKey(s * 977 + d), 2)
    log_a = -jnp.abs(jax.random.normal(ks[0], (1, s, d))) * 0.2
    b = jax.random.normal(ks[1], (1, s, d))
    h, _ = lru_chunked(log_a, b, chunk=chunk, bd=128, interpret=True)
    href, _ = lru_ref(log_a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(href),
                               rtol=2e-4, atol=2e-5)
