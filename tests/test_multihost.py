"""Multi-host bootstrap helpers (single-process semantics on CPU)."""
import jax
import pytest

from repro.launch import multihost
from repro.launch.mesh import make_host_mesh


def test_host_data_shard_single_process():
    assert multihost.host_data_shard() == (0, 1)


def test_mesh_span_check():
    mesh = make_host_mesh()
    multihost.assert_mesh_spans_processes(mesh)   # 1 device = full span


def test_mesh_span_mismatch_detected():
    class Fake:
        class devices:
            size = 7

    with pytest.raises(RuntimeError):
        multihost.assert_mesh_spans_processes(Fake())
