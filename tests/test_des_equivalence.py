"""Equivalence suite: group-log DES == seed implementation, scan engine ==
while engine, batched sweep layouts == per-experiment calls.

The group-log rewrite (`simulate_packet`) changes how per-job start times
are produced (O(1) log appends + a vectorized post-pass) but must not change
a single metric. `simulate_packet_reference` is the seed implementation kept
verbatim as the oracle; these tests pin every DesResult field against it on
hand-constructed cases and on reduced Lublin workloads across the (k, s)
grid. The event-budget scan engine (`simulate_packet_scan`, the batched-lane
path of mode="chunked"/"fused") is pinned against both, and the sweep
dispatch layouts (seq / chunked / fused / vmap_k / vmap_s) against each
other in both dtypes.
"""
import jax
import numpy as np
import pytest

from repro.core import (efficiency_metrics, event_budget, pack_workload,
                        precision, resolve_ring, run_packet_grid,
                        simulate_packet, simulate_packet_reference,
                        simulate_packet_scan)
from repro.workload.lublin import WorkloadParams, generate_workload

from conftest import make_workload


def assert_des_equal(a, b, rtol=1e-6, atol=1e-6):
    a = jax.tree.map(np.asarray, a)
    b = jax.tree.map(np.asarray, b)
    for f in a._fields:
        np.testing.assert_allclose(getattr(a, f), getattr(b, f),
                                   rtol=rtol, atol=atol, err_msg=f)


HAND_CASES = [
    # (submit, runtime, nodes, jtype, n_types, M, k, s)
    ([0.0], [100.0], [1], [0], 2, 10, 1.0, 50.0),
    # sequential groups of one type on one node
    ([0.0, 1.0, 2.0], [100.0, 40.0, 60.0], [1, 1, 1], [0, 0, 0], 1, 1,
     1000.0, 10.0),
    # paper Fig 3 geometry
    ([0.0, 0.0], [120.0, 120.0], [1, 1], [0, 0], 1, 100, 0.5, 60.0),
    # two types compete for nodes
    ([0.0, 0.0, 5.0, 6.0], [50.0, 80.0, 30.0, 20.0], [1, 1, 1, 1],
     [0, 1, 0, 1], 2, 4, 2.0, 15.0),
    # starvation of free nodes (m_free clamp)
    ([0.0], [100.0], [1], [0], 1, 2, 0.1, 10.0),
    # many tiny jobs of one popular type + a rare type
    ([float(i) for i in range(12)], [10.0] * 12, [1] * 12,
     [0, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0], 2, 6, 4.0, 8.0),
]


class TestGroupLogEquivalence:
    @pytest.mark.parametrize("case", HAND_CASES)
    def test_hand_constructed(self, case):
        submit, runtime, nodes, jtype, h, m, k, s = case
        wl = make_workload(submit, runtime, nodes, jtype, h, m)
        pw = pack_workload(wl)
        assert_des_equal(simulate_packet(pw, k, s, m),
                         simulate_packet_reference(pw, k, s, m))

    @pytest.mark.parametrize("k", [0.3, 2.0, 20.0, 500.0])
    @pytest.mark.parametrize("s_prop", [0.05, 0.3, 0.5])
    def test_reduced_lublin_grid(self, small_workload, k, s_prop):
        pw = pack_workload(small_workload)
        m = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(s_prop)
        assert_des_equal(simulate_packet(pw, k, s, m),
                         simulate_packet_reference(pw, k, s, m))

    def test_hetero_workload(self, hetero_workload):
        pw = pack_workload(hetero_workload)
        m = hetero_workload.params.nodes
        s = hetero_workload.init_time_for_proportion(0.2)
        for k in (0.5, 8.0, 100.0):
            assert_des_equal(simulate_packet(pw, k, s, m),
                             simulate_packet_reference(pw, k, s, m))

    def test_ring_size_does_not_change_results(self, small_workload):
        """The derived ring is a capacity, not a policy: any ring large
        enough to hold the concurrent groups yields identical results."""
        pw = pack_workload(small_workload)
        m = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(0.3)
        small = simulate_packet(pw, 2.0, s, m)          # ring = min(M, N)
        big = simulate_packet(pw, 2.0, s, m, ring=512)  # seed's fixed ring
        assert resolve_ring(m, pw.n_jobs) == min(m, pw.n_jobs)
        assert_des_equal(small, big)

    def test_float64_equivalence(self, small_workload):
        """The group-log rewrite is dtype-agnostic: under the float64
        opt-in it must still match the reference implementation, and to a
        much tighter tolerance than float32 allows."""
        m = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(0.3)
        with precision.dtype_scope(np.float64):
            pw = pack_workload(small_workload, np.float64)
            assert_des_equal(simulate_packet(pw, 2.0, s, m),
                             simulate_packet_reference(pw, 2.0, s, m),
                             rtol=1e-12, atol=1e-9)

    def test_priorities_preserved(self, small_workload):
        """The group-log path must honour priority/t_max like the seed."""
        pw = pack_workload(small_workload)
        m = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(0.3)
        h = pw.n_types
        pri = np.linspace(2.0, 0.5, h)
        tmx = np.full(h, 600.0)
        assert_des_equal(
            simulate_packet(pw, 4.0, s, m, priority=pri, t_max=tmx),
            simulate_packet_reference(pw, 4.0, s, m, priority=pri, t_max=tmx))


class TestScanEngineEquivalence:
    """The event-budget scan engine is the same simulator, re-laid-out."""

    @pytest.mark.parametrize("case", HAND_CASES)
    def test_hand_constructed(self, case):
        submit, runtime, nodes, jtype, h, m, k, s = case
        wl = make_workload(submit, runtime, nodes, jtype, h, m)
        pw = pack_workload(wl)
        assert_des_equal(simulate_packet_scan(pw, k, s, m),
                         simulate_packet_reference(pw, k, s, m))

    @pytest.mark.parametrize("k", [0.3, 2.0, 20.0, 500.0])
    @pytest.mark.parametrize("s_prop", [0.05, 0.5])
    def test_reduced_lublin_grid(self, small_workload, k, s_prop):
        pw = pack_workload(small_workload)
        m = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(s_prop)
        assert_des_equal(simulate_packet_scan(pw, k, s, m),
                         simulate_packet(pw, k, s, m))

    def test_hetero_workload(self, hetero_workload):
        pw = pack_workload(hetero_workload)
        m = hetero_workload.params.nodes
        s = hetero_workload.init_time_for_proportion(0.2)
        for k in (0.5, 8.0, 100.0):
            assert_des_equal(simulate_packet_scan(pw, k, s, m),
                             simulate_packet(pw, k, s, m))

    def test_float64_equivalence(self, small_workload):
        m = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(0.3)
        with precision.dtype_scope(np.float64):
            pw = pack_workload(small_workload, np.float64)
            assert_des_equal(simulate_packet_scan(pw, 2.0, s, m),
                             simulate_packet(pw, 2.0, s, m),
                             rtol=1e-12, atol=1e-9)

    def test_priorities_preserved(self, small_workload):
        pw = pack_workload(small_workload)
        m = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(0.3)
        h = pw.n_types
        pri = np.linspace(2.0, 0.5, h)
        tmx = np.full(h, 600.0)
        assert_des_equal(
            simulate_packet_scan(pw, 4.0, s, m, priority=pri, t_max=tmx),
            simulate_packet(pw, 4.0, s, m, priority=pri, t_max=tmx))

    def test_budget_is_sufficient_and_capacity_only(self, small_workload):
        """event_budget(N) always drains; a bigger budget changes nothing;
        a starved budget reports ok=False instead of lying."""
        pw = pack_workload(small_workload)
        m = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(0.3)
        base = simulate_packet_scan(pw, 2.0, s, m)
        assert np.asarray(base.ok)
        roomy = simulate_packet_scan(pw, 2.0, s, m,
                                     budget=2 * event_budget(pw.n_jobs))
        assert_des_equal(base, roomy)
        # segment length is a scheduling knob, not a policy
        segged = simulate_packet_scan(pw, 2.0, s, m, seg=64)
        assert_des_equal(base, segged)
        # (budget rounds up to a segment multiple, so pin seg too)
        starved = simulate_packet_scan(pw, 2.0, s, m, budget=8, seg=8)
        assert not np.asarray(starved.ok)


class TestFusedSweepEquivalence:
    def test_fused_grid_matches_per_experiment(self, small_workload):
        """The fused (k x S) lane engine == one simulate_packet per cell."""
        wl = small_workload
        ks = [0.5, 2.0, 8.0, 50.0, 300.0]
        s_props = [0.05, 0.2, 0.5]
        grid = run_packet_grid(wl, ks=ks, s_props=s_props, mode="fused")
        pw = pack_workload(wl)
        m = wl.params.nodes
        for i, k in enumerate(ks):
            for j, p in enumerate(s_props):
                s = wl.init_time_for_proportion(p)
                res = simulate_packet(pw, k, s, m)
                cell = efficiency_metrics(pw.submit, res, m, pw.t_last_submit)
                cell = jax.tree.map(np.asarray, cell)
                for f in ("avg_wait", "med_wait", "avg_qlen", "full_util",
                          "useful_util", "n_groups", "ok"):
                    np.testing.assert_allclose(
                        np.asarray(getattr(grid, f))[i, j], getattr(cell, f),
                        rtol=1e-5, atol=1e-5, err_msg=f"{f} k={k} s={p}")
        assert np.asarray(grid.ok).all()

    def test_all_modes_agree(self, small_workload):
        """seq / chunked / fused / vmap_k / vmap_s are dispatch layouts,
        not policies."""
        kw = dict(ks=[0.5, 8.0, 100.0], s_props=[0.05, 0.5])
        grids = {
            "seq": run_packet_grid(small_workload, mode="seq", **kw),
            "chunked": run_packet_grid(small_workload, mode="chunked", **kw),
            "fused": run_packet_grid(small_workload, mode="fused", **kw),
            "vmap_k": run_packet_grid(small_workload, vmap_k=True, **kw),
            "vmap_s": run_packet_grid(small_workload, vmap_s=True, **kw),
        }
        base = grids.pop("seq")
        for name, g in grids.items():
            for f in ("avg_wait", "med_wait", "avg_qlen", "full_util",
                      "useful_util", "avg_run_wait"):
                np.testing.assert_allclose(
                    getattr(base, f), getattr(g, f), rtol=1e-5,
                    err_msg=f"{name}:{f}")
            assert np.asarray(g.ok).all(), name

    def test_chunked_unsorts_lanes_correctly(self, small_workload):
        """Chunking sorts lanes by predicted event count and pads the last
        chunk; cells must come back in grid order regardless of the chunk
        width (1-lane chunks = maximal permutation + padding churn)."""
        kw = dict(ks=[0.5, 8.0, 100.0], s_props=[0.05, 0.5])
        base = run_packet_grid(small_workload, mode="seq", **kw)
        for chunk in (1, 2, 4, 64):
            g = run_packet_grid(small_workload, mode="chunked",
                                chunk_lanes=chunk, **kw)
            np.testing.assert_allclose(base.avg_wait, g.avg_wait,
                                       rtol=1e-5, err_msg=f"chunk={chunk}")
            np.testing.assert_allclose(base.n_groups, g.n_groups,
                                       err_msg=f"chunk={chunk}")

    @pytest.mark.parametrize("mode", ["chunked", "fused"])
    def test_float64_modes_agree_tightly(self, small_workload, mode):
        """Under the float64 opt-in, seq and the batched layouts are the
        same arithmetic per lane — they must agree far below float32
        resolution."""
        kw = dict(ks=[0.5, 8.0, 100.0], s_props=[0.05, 0.5],
                  dtype=np.float64)
        a = run_packet_grid(small_workload, mode="seq", **kw)
        b = run_packet_grid(small_workload, mode=mode, **kw)
        for f in ("avg_wait", "med_wait", "avg_qlen", "full_util",
                  "useful_util", "avg_run_wait"):
            np.testing.assert_allclose(getattr(a, f), getattr(b, f),
                                       rtol=1e-12, err_msg=f)
        assert a.avg_wait.dtype == np.float64
        assert b.avg_wait.dtype == np.float64

    @pytest.mark.slow
    def test_fused_grid_full_s_axis(self, small_workload):
        """Full paper init-proportion axis through the fused engine."""
        from repro.core import PAPER_INIT_PROPS
        grid = run_packet_grid(small_workload, ks=[1.0, 10.0],
                               s_props=PAPER_INIT_PROPS)
        assert np.asarray(grid.ok).all()
        assert np.asarray(grid.avg_wait).shape == (2, len(PAPER_INIT_PROPS))
