import os

# Keep tests on the single real CPU device; the 512-device placeholder
# environment is reserved for the dry-run (launched as a subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

from repro.workload.lublin import Workload, WorkloadParams, generate_workload


def make_workload(submit, runtime, nodes, jtype, n_types, m_nodes) -> Workload:
    """Hand-constructed Workload for behaviour/equivalence tests."""
    submit = np.asarray(submit, np.float64)
    runtime = np.asarray(runtime, np.float64)
    nodes = np.asarray(nodes, np.int64)
    jtype = np.asarray(jtype, np.int64)
    order = np.argsort(submit, kind="stable")
    p = WorkloadParams(n_jobs=len(submit), nodes=m_nodes, n_types=n_types,
                       horizon=float(submit.max()) if len(submit) else 1.0)
    return Workload(submit=submit[order], runtime=runtime[order],
                    nodes=nodes[order], work=(runtime * nodes)[order],
                    jtype=jtype[order], params=p)


@pytest.fixture(scope="session")
def small_workload():
    return generate_workload(WorkloadParams(
        n_jobs=300, nodes=64, load=0.9, homogeneous=True, seed=7))


@pytest.fixture(scope="session")
def hetero_workload():
    return generate_workload(WorkloadParams(
        n_jobs=300, nodes=128, load=0.85, homogeneous=False, seed=3))
