import os

# Keep tests on the single real CPU device; the 512-device placeholder
# environment is reserved for the dry-run (launched as a subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

from repro.workload.lublin import WorkloadParams, generate_workload


@pytest.fixture(scope="session")
def small_workload():
    return generate_workload(WorkloadParams(
        n_jobs=300, nodes=64, load=0.9, homogeneous=True, seed=7))


@pytest.fixture(scope="session")
def hetero_workload():
    return generate_workload(WorkloadParams(
        n_jobs=300, nodes=128, load=0.85, homogeneous=False, seed=3))
