"""Training-substrate tests: loss/optimizer/microbatching/data pipeline.

Whole module is `slow` (model-layer compiles, not simulation core):
deselected from tier-1 by the default ``-m "not slow"`` addopts; run with
``pytest -m ""`` for the full matrix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.layers import unbox
from repro.models.registry import get_family
from repro.sharding.policy import single_device_policy
from repro.train import data as data_lib
from repro.train import optim as optim_lib
from repro.train.loss import chunked_ce
from repro.train.step import init_state, make_train_step

KEY = jax.random.PRNGKey(0)
OCFG = optim_lib.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)

pytestmark = pytest.mark.slow


def test_chunked_ce_matches_dense():
    cfg = smoke_config("granite-3-2b")
    pol = single_device_policy(cfg)
    B, S, d, Vp = 2, 40, cfg.d_model, 256
    h = jax.random.normal(KEY, (B, S, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (Vp, d)) * 0.1
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    labels = labels.at[:, :5].set(-1)          # ignored positions
    loss, mets = chunked_ce(cfg, pol, h, w, labels, chunk=16)
    # dense oracle
    logits = (h @ w.T).astype(jnp.float32)
    logits = jnp.where(jnp.arange(Vp) < cfg.vocab_size, logits, -1e30)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, jnp.clip(labels, 0)[..., None],
                               -1)[..., 0]
    valid = labels != -1
    ref = jnp.where(valid, lse - gold, 0).sum() / valid.sum()
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5)
    assert int(mets["tokens"]) == int(valid.sum())


def test_loss_decreases_on_synthetic_stream():
    cfg = smoke_config("granite-3-2b", n_layers=2)
    pol = single_device_policy(cfg)
    state, _ = init_state(cfg, pol, jax.random.PRNGKey(1), OCFG)
    step = jax.jit(make_train_step(cfg, pol, OCFG))
    it = data_lib.batches(cfg, data_lib.DataConfig(batch=8, seq=64))
    losses = []
    for _ in range(30):
        state, mets = step(state, next(it))
        losses.append(float(mets["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.85, (losses[0], losses[-1])


def test_microbatch_equals_full_batch_grads():
    """n_micro=4 must produce the same update as n_micro=1 (up to fp error)."""
    cfg = smoke_config("yi-6b", n_layers=1)
    pol = single_device_policy(cfg)
    state, _ = init_state(cfg, pol, jax.random.PRNGKey(2), OCFG)
    it = data_lib.batches(cfg, data_lib.DataConfig(batch=8, seq=32))
    batch = next(it)
    s1, m1 = jax.jit(make_train_step(cfg, pol, OCFG, n_micro=1))(state, batch)
    s4, m4 = jax.jit(make_train_step(cfg, pol, OCFG, n_micro=4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    a = jax.tree.leaves(s1.params)
    b = jax.tree.leaves(s4.params)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-4, atol=2e-6)


def test_adamw_moments_and_decay():
    ocfg = optim_lib.AdamWConfig(lr=1e-2, weight_decay=0.5, grad_clip=0.0,
                                 warmup_steps=0, total_steps=10,
                                 min_lr_frac=1.0)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    grads = {"w": jnp.full((4, 4), 0.1), "b": jnp.full((4,), 0.1)}
    st = optim_lib.init(ocfg, params)
    p1, st1, mets = optim_lib.apply(ocfg, st, params, grads)
    # rank-1 "b" gets no weight decay; "w" does
    assert float(p1["b"][0]) > float(p1["w"][0, 0])
    assert int(st1.step) == 1
    assert np.isfinite(float(mets["grad_norm"]))


def test_grad_clip():
    ocfg = optim_lib.AdamWConfig(lr=1e-2, grad_clip=1e-3, warmup_steps=0)
    params = {"w": jnp.ones((8, 8))}
    grads = {"w": jnp.full((8, 8), 100.0)}
    st = optim_lib.init(ocfg, params)
    p1, _, mets = optim_lib.apply(ocfg, st, params, grads)
    assert float(mets["grad_norm"]) == pytest.approx(800.0)
    assert np.all(np.isfinite(np.asarray(p1["w"])))


def test_lr_schedule():
    ocfg = optim_lib.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                                 min_lr_frac=0.1)
    lrs = [float(optim_lib.lr_at(ocfg, jnp.asarray(s))) for s in
           (0, 5, 10, 60, 110, 200)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)    # clamped past the end


def test_data_host_sharding_disjoint_and_deterministic():
    cfg = smoke_config("granite-3-2b")
    a = next(data_lib.batches(cfg, data_lib.DataConfig(batch=8, seq=32,
                                                       host_id=0, n_hosts=2)))
    a2 = next(data_lib.batches(cfg, data_lib.DataConfig(batch=8, seq=32,
                                                        host_id=0, n_hosts=2)))
    b = next(data_lib.batches(cfg, data_lib.DataConfig(batch=8, seq=32,
                                                       host_id=1, n_hosts=2)))
    assert a["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(a["tokens"], a2["tokens"])   # deterministic
    assert not np.array_equal(a["tokens"], b["tokens"])        # disjoint
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()   # shifted


def test_vlm_prefix_labels_masked():
    cfg = smoke_config("pixtral-12b")
    batch = next(data_lib.batches(cfg, data_lib.DataConfig(batch=2, seq=32)))
    assert (batch["labels"][:, :cfg.n_prefix] == -1).all()
    assert batch["embeds"].shape == (2, cfg.n_prefix, cfg.d_model)
