"""Pallas event-step engine == XLA scan engine, bitwise.

The fused event-step kernel (`repro.kernels.packet_step`) vectorizes the
module-level `packet_scan_step` over a lane-minor [*, T] state layout.
Because every float op in the step is elementwise and every reduction is
integer/boolean/arg-indexed, the kernel-resident sweep must reproduce the
XLA scan engine EXACTLY — not just schedules and integer counters (the
acceptance bar) but every DesResult field, in float32 and float64, chaos
on and off, across the seq/chunked/fused dispatch layouts. These tests
pin that contract on CPU via the interpret-mode fallback, which is the
same discharged-XLA program the compiled kernel must match on device.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ChaosConfig, pack_workload, precision,
                        resolve_mode, run_packet_grid, run_window_oracle,
                        simulate_packet_scan, simulate_packet_scan_lanes,
                        sweep_plan)
from repro.kernels.packet_step.ref import packet_step_ref
from repro.workload.lublin import WorkloadParams, generate_workload

KS = [0.5, 2.0, 8.0, 50.0]
SS = [0.05, 0.5]


def assert_bitwise(a, b):
    """Every field of two DesResult/Metrics pytrees, exactly equal."""
    for f in a._fields:
        x = np.asarray(getattr(a, f))
        y = np.asarray(getattr(b, f))
        assert np.array_equal(x, y, equal_nan=True), (
            f"{f}: max|Δ|={np.max(np.abs(x.astype(np.float64) - y.astype(np.float64)))}")


def chaos_cfg(n_lanes, seed=11, max_requeues=None):
    return ChaosConfig(mtbf_chip_hours=2.0, ckpt_period=120.0,
                       straggler_prob=0.3, straggler_factor=2.0,
                       straggler_deadline=1.5, lane=jnp.arange(n_lanes),
                       seed=seed, max_requeues=max_requeues)


@pytest.fixture(scope="module")
def wl():
    return generate_workload(WorkloadParams(n_jobs=80, nodes=64, load=0.9,
                                            homogeneous=False, seed=5))


def run_lanes(pw, ks, ss, m_nodes, chaos=None, step_impl="xla", **kw):
    k = jnp.asarray(ks, pw.submit.dtype)
    s = jnp.asarray(ss, pw.submit.dtype)
    fn = jax.jit(lambda kk, s_: simulate_packet_scan_lanes(
        pw, kk, s_, m_nodes, chaos=chaos, step_impl=step_impl, **kw))
    return jax.tree.map(np.asarray, fn(k, s))


class TestEngineBitwise:
    """simulate_packet_scan_lanes: pallas vs xla, all DesResult fields."""

    @pytest.mark.parametrize("with_chaos", [False, True],
                             ids=["faultfree", "chaos"])
    def test_float32(self, wl, with_chaos):
        pw = pack_workload(wl)
        ks = jnp.repeat(jnp.asarray(KS), len(SS))
        ss = jnp.tile(jnp.asarray(
            [wl.init_time_for_proportion(p) for p in SS]), len(KS))
        chaos = chaos_cfg(ks.shape[0]) if with_chaos else None
        assert_bitwise(
            run_lanes(pw, ks, ss, 64, chaos, "xla"),
            run_lanes(pw, ks, ss, 64, chaos, "pallas"))

    @pytest.mark.parametrize("with_chaos", [False, True],
                             ids=["faultfree", "chaos"])
    def test_float64(self, wl, with_chaos):
        with precision.dtype_scope(jnp.float64):
            pw = pack_workload(wl, jnp.float64)
            ks = jnp.asarray(KS, jnp.float64)
            ss = jnp.asarray(
                [wl.init_time_for_proportion(p) for p in SS[:1]] * len(KS),
                jnp.float64)
            chaos = chaos_cfg(ks.shape[0]) if with_chaos else None
            assert_bitwise(
                run_lanes(pw, ks, ss, 64, chaos, "xla"),
                run_lanes(pw, ks, ss, 64, chaos, "pallas"))

    def test_requeue_cap_hits(self, wl):
        """A finite max_requeues that lanes actually exhaust: the credit
        bookkeeping (the packed-span merge path) stays bitwise."""
        pw = pack_workload(wl)
        chaos = chaos_cfg(4, seed=3, max_requeues=2)
        ks = jnp.asarray(KS)
        ss = jnp.full((4,), wl.init_time_for_proportion(0.2))
        a = run_lanes(pw, ks, ss, 64, chaos, "xla")
        b = run_lanes(pw, ks, ss, 64, chaos, "pallas")
        assert np.max(a.requeues) > 0      # the fault path genuinely ran
        assert_bitwise(a, b)

    def test_scalar_entry_delegates(self, wl):
        """simulate_packet_scan(step_impl='pallas') returns scalar-shaped
        results bitwise-equal to the xla scan engine."""
        pw = pack_workload(wl)
        s = wl.init_time_for_proportion(0.3)
        a = jax.jit(lambda: simulate_packet_scan(pw, 2.0, s, 64))()
        b = jax.jit(lambda: simulate_packet_scan(pw, 2.0, s, 64,
                                                 step_impl="pallas"))()
        assert np.asarray(b.start_t).shape == np.asarray(a.start_t).shape
        assert np.asarray(b.makespan).ndim == 0   # scalar, not [1]
        assert_bitwise(jax.tree.map(np.asarray, a),
                       jax.tree.map(np.asarray, b))


class TestDispatchModes:
    """run_packet_grid / run_window_oracle with step_impl='pallas' match
    the xla scan engine in every dispatch layout."""

    @pytest.mark.parametrize("mode", ["seq", "chunked", "fused"])
    @pytest.mark.parametrize("with_chaos", [False, True],
                             ids=["faultfree", "chaos"])
    def test_grid_modes(self, wl, mode, with_chaos):
        chaos = chaos_cfg(2) if with_chaos else None
        gp = run_packet_grid(wl, KS, SS, mode=mode, chaos=chaos,
                             chunk_lanes=4, on_budget_exhausted="ignore",
                             step_impl="pallas")
        # xla reference: the scan engine. mode='seq' without chaos runs
        # the legacy while-engine (float accumulates differ by ulps
        # cross-engine), so the scan-engine reference there is 'chunked'.
        ref_mode = "chunked" if (mode == "seq" and chaos is None) else mode
        gx = run_packet_grid(wl, KS, SS, mode=ref_mode, chaos=chaos,
                             chunk_lanes=4, on_budget_exhausted="ignore")
        assert_bitwise(gx, gp)

    @pytest.mark.parametrize("mode", ["seq", "chunked", "fused"])
    def test_window_oracle_modes(self, wl, mode):
        pw = pack_workload(wl)
        chaos = chaos_cfg(2)
        kw = dict(mode=mode, chaos=chaos, chunk_lanes=2,
                  on_budget_exhausted="ignore")
        assert_bitwise(
            run_window_oracle(pw, KS, 200.0, 64, **kw),
            run_window_oracle(pw, KS, 200.0, 64, step_impl="pallas", **kw))

    def test_vmap_layouts_rejected(self, wl):
        with pytest.raises(ValueError, match="XLA-only"):
            run_packet_grid(wl, KS, SS, vmap_k=True, step_impl="pallas")
        with pytest.raises(ValueError, match="legacy XLA-only layout"):
            resolve_mode("vmap_s", 8, step_impl="pallas")

    def test_unknown_step_impl_rejected(self, wl):
        with pytest.raises(ValueError, match="step_impl"):
            run_packet_grid(wl, KS, SS, step_impl="triton")

    def test_sweep_plan_records_engine(self):
        p = sweep_plan("auto", 8, step_impl="pallas")
        assert p["step_impl"] == "pallas"
        assert p["step_interpret"] is True     # CPU backend in CI
        q = sweep_plan("auto", 8)
        assert q["step_impl"] == "xla" and q["step_interpret"] is False


class TestBudgetExhaustion:
    def test_truncation_is_identical(self, wl):
        """An undersized event budget truncates both engines at the same
        event, with identical ok/budget_exhausted semantics."""
        pw = pack_workload(wl)
        ks = jnp.asarray(KS)
        ss = jnp.full((len(KS),), wl.init_time_for_proportion(0.3))
        # budget tiles up to whole seg segments, so pin seg too
        a = run_lanes(pw, ks, ss, 64, None, "xla", budget=24, seg=8)
        b = run_lanes(pw, ks, ss, 64, None, "pallas", budget=24, seg=8)
        assert not np.all(a.ok)               # the budget genuinely bit
        assert_bitwise(a, b)

    def test_seg_boundary_is_invisible(self, wl):
        """A seg width that does not divide the budget still matches."""
        pw = pack_workload(wl)
        ks = jnp.asarray(KS[:2])
        ss = jnp.full((2,), wl.init_time_for_proportion(0.3))
        assert_bitwise(
            run_lanes(pw, ks, ss, 64, None, "xla"),
            run_lanes(pw, ks, ss, 64, None, "pallas", seg=37))


def test_ref_is_the_production_step():
    """The kernel package's ref IS the engine step — no drift possible."""
    from repro.core.des import packet_scan_step
    assert packet_step_ref is packet_scan_step


try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # optional dev dependency, as in the other
    given = None           # kernels' property suites

if given is not None:
    @settings(max_examples=6, deadline=None)
    @given(n_jobs=st.sampled_from([40, 60]),
           n_lanes=st.integers(1, 5),
           seed=st.integers(0, 2**16),
           with_chaos=st.booleans())
    def test_random_lane_batches(n_jobs, n_lanes, seed, with_chaos):
        """Property: any random lane batch (workload, lane count, k/s
        draws, chaos on/off) is bitwise identical across engines."""
        w = generate_workload(WorkloadParams(
            n_jobs=n_jobs, nodes=32, load=0.85,
            homogeneous=seed % 2 == 0, seed=seed % 97))
        pw = pack_workload(w)
        kk = jax.random.split(jax.random.PRNGKey(seed), 2)
        ks = 10.0 ** jax.random.uniform(kk[0], (n_lanes,),
                                        minval=-1.0, maxval=2.5)
        ss = jax.random.uniform(kk[1], (n_lanes,), minval=1.0,
                                maxval=float(w.init_time_for_proportion(0.9)))
        chaos = chaos_cfg(n_lanes, seed=seed % 1024) if with_chaos else None
        assert_bitwise(run_lanes(pw, ks, ss, 32, chaos, "xla"),
                       run_lanes(pw, ks, ss, 32, chaos, "pallas"))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_lane_batches():
        pass
