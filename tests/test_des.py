"""Behaviour tests for the Packet DES and baseline schedulers.

Property-based tests live in ``test_des_properties.py`` behind an optional
``hypothesis`` dev dependency; this module must import cleanly in a minimal
environment so tier-1 collection never fails.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (efficiency_metrics, pack_workload, simulate_backfill,
                        simulate_fcfs, simulate_packet)
from repro.workload.lublin import Workload, WorkloadParams, generate_workload

from conftest import make_workload as _mk_workload


class TestPacketHandConstructed:
    def test_single_job_starts_immediately(self):
        wl = _mk_workload([0.0], [100.0], [1], [0], 2, 10)
        pw = pack_workload(wl)
        res = simulate_packet(pw, 1.0, 50.0, 10)
        assert bool(res.ok)
        assert float(res.start_t[0]) == 0.0
        # work=100, k=1, s=50 -> m=2, exec 50s, done at 100
        assert float(res.makespan) == pytest.approx(100.0)

    def test_group_amortizes_init(self):
        # two same-type jobs queued while node busy -> one group, one init
        wl = _mk_workload([0.0, 1.0, 2.0], [100.0, 40.0, 60.0],
                          [1, 1, 1], [0, 0, 0], 1, 1)
        pw = pack_workload(wl)
        res = simulate_packet(pw, 1000.0, 10.0, 1)  # huge k -> 1 node
        assert bool(res.ok)
        # job0 group: init 10 + 100 exec -> ends 110
        # jobs 1,2 form ONE group at t=110: init 10, then 40 + 60
        assert float(res.start_t[1]) == pytest.approx(110.0)
        assert float(res.start_t[2]) == pytest.approx(110.0)
        assert float(res.run_start_t[1]) == pytest.approx(120.0)
        assert float(res.run_start_t[2]) == pytest.approx(160.0)
        assert float(res.makespan) == pytest.approx(220.0)
        assert int(res.n_groups) == 2

    def test_scale_ratio_sets_group_width(self):
        # paper Fig 3: s=60, work=240 -> k=0.5 gives 8 nodes
        wl = _mk_workload([0.0, 0.0], [120.0, 120.0], [1, 1], [0, 0], 1, 100)
        pw = pack_workload(wl)
        res = simulate_packet(pw, 0.5, 60.0, 100)
        assert bool(res.ok)
        # 8 nodes -> exec 30s, makespan 90
        assert float(res.makespan) == pytest.approx(90.0)

    def test_types_get_separate_groups(self):
        wl = _mk_workload([0.0, 0.0], [100.0, 100.0], [1, 1], [0, 1], 2, 100)
        pw = pack_workload(wl)
        res = simulate_packet(pw, 1.0, 100.0, 100)
        assert bool(res.ok)
        assert int(res.n_groups) == 2  # different types never merge
        # both can start at t=0 (enough nodes)
        np.testing.assert_allclose(np.asarray(res.start_t), 0.0, atol=1e-5)

    def test_not_enough_free_nodes_uses_all_free(self):
        # paper step 4: m_group = min(m_threshold, m_free)
        wl = _mk_workload([0.0], [100.0], [1], [0], 1, 2)
        pw = pack_workload(wl)
        res = simulate_packet(pw, 0.1, 10.0, 2)  # threshold 100 >> 2 free
        assert bool(res.ok)
        # runs on 2 nodes: init 10 + 100/2 -> makespan 60
        assert float(res.makespan) == pytest.approx(60.0)


class TestFcfsBackfill:
    def test_fcfs_blocks_behind_head(self):
        # head needs 4 nodes (busy), small job behind must wait under FCFS
        wl = _mk_workload([0.0, 1.0, 2.0], [100.0, 100.0, 10.0],
                          [4, 4, 1], [0, 0, 0], 1, 4)
        pw = pack_workload(wl)
        res = simulate_fcfs(pw, 0.0, 4)
        assert bool(res.ok)
        assert float(res.start_t[2]) >= float(res.start_t[1])

    def test_backfill_lets_small_job_jump(self):
        # M=5: job0 holds 4 nodes till t=100; head job1 needs 4 (blocked,
        # 1 free); job2 (1 node, 10s) ends before the shadow time (100)
        # -> backfills immediately at its submit t=2.
        wl = _mk_workload([0.0, 1.0, 2.0], [100.0, 100.0, 10.0],
                          [4, 4, 1], [0, 0, 0], 1, 5)
        pw = pack_workload(wl)
        res = simulate_backfill(pw, 0.0, 5)
        assert bool(res.ok)
        assert float(res.start_t[2]) == pytest.approx(2.0)

    def test_backfill_never_delays_head_reservation(self):
        # job2 runs past the shadow but fits in the `extra` node, so the
        # reserved head job must still start exactly at its FCFS time
        wl = _mk_workload([0.0, 1.0, 2.0], [100.0, 100.0, 200.0],
                          [4, 4, 1], [0, 0, 0], 1, 5)
        pw = pack_workload(wl)
        f = simulate_fcfs(pw, 0.0, 5)
        b = simulate_backfill(pw, 0.0, 5)
        assert float(b.start_t[2]) == pytest.approx(2.0)  # used extra node
        assert float(b.start_t[1]) <= float(f.start_t[1]) + 1e-5


class TestMetrics:
    def test_metrics_hand_computed(self):
        wl = _mk_workload([0.0, 10.0], [100.0, 100.0], [1, 1], [0, 0], 1, 2)
        pw = pack_workload(wl)
        # k huge -> each group 1 node; job0 at t0 (init 5 + 100);
        # job1 arrives t=10, one node still free -> starts immediately too.
        res = simulate_packet(pw, 1e6, 5.0, 2)
        m = jax.tree.map(float, efficiency_metrics(
            pw.submit, res, 2, pw.t_last_submit))
        assert m["avg_wait"] if isinstance(m, dict) else True
        assert m.avg_wait == pytest.approx(0.0, abs=1e-4)
        assert m.med_wait == pytest.approx(0.0, abs=1e-4)
        # window = 10s; job0 busy whole window on 1 of 2 nodes; job1 starts
        # at 10 (zero-length contribution). busy = 10, useful = 5 (init 5).
        assert m.full_util == pytest.approx(10.0 / 20.0)
        assert m.useful_util == pytest.approx(5.0 / 20.0)

    def test_queue_length_integral(self):
        # one node; job0 starts alone at t=0 (init 1 + 10 -> ends 11);
        # jobs 1,2 (submitted just after) wait 11s each, then run as ONE
        # group (init 1 + 20 -> ends 32); job3 at t=50 starts immediately.
        wl = _mk_workload([0.0, 0.0, 0.0, 50.0], [10.0, 10.0, 10.0, 10.0],
                          [1, 1, 1, 1], [0, 0, 0, 0], 1, 1)
        pw = pack_workload(wl)
        res = simulate_packet(pw, 1e6, 1.0, 1)
        assert int(res.n_groups) == 3
        m = jax.tree.map(float, efficiency_metrics(
            pw.submit, res, 1, pw.t_last_submit))
        # qlen integral = 2 jobs x 11 s; window = 50 s
        assert m.avg_qlen == pytest.approx(2 * 11.0 / 50.0, rel=1e-5)
        np.testing.assert_allclose(np.asarray(res.start_t),
                                   [0.0, 11.0, 11.0, 50.0], atol=1e-4)


class TestGeneratedWorkloadsEndToEnd:
    def test_full_small_workload(self, small_workload):
        pw = pack_workload(small_workload)
        s = small_workload.init_time_for_proportion(0.3)
        res = jax.tree.map(np.asarray, simulate_packet(
            pw, 2.0, s, small_workload.params.nodes))
        assert res.ok
        m = efficiency_metrics(pw.submit, jax.tree.map(jnp.asarray, res),
                               small_workload.params.nodes, pw.t_last_submit)
        m = jax.tree.map(float, m)
        assert 0.0 < m.full_util <= 1.0
        assert 0.0 < m.useful_util <= m.full_util + 1e-6

    def test_paper_trend_wait_decreases_with_k(self, small_workload):
        """Headline paper claim: queue time falls as k rises, then plateaus."""
        pw = pack_workload(small_workload)
        M = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(0.3)
        f = jax.jit(lambda k: efficiency_metrics(
            pw.submit, simulate_packet(pw, k, s, M), M, pw.t_last_submit))
        waits = [float(f(k).avg_wait) for k in (0.5, 2.0, 8.0, 50.0, 500.0, 1000.0)]
        assert waits[0] > waits[-1]          # overall decrease
        assert waits[2] > waits[-1] * 0.5 or waits[2] >= waits[-1]  # monotone-ish
        # plateau: k=500 vs k=1000 nearly identical
        assert waits[-2] == pytest.approx(waits[-1], rel=0.1, abs=5.0)

    def test_paper_trend_full_util_decreases_with_k(self, small_workload):
        pw = pack_workload(small_workload)
        M = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(0.3)
        f = jax.jit(lambda k: efficiency_metrics(
            pw.submit, simulate_packet(pw, k, s, M), M, pw.t_last_submit))
        full_low_k = float(f(0.3).full_util)
        full_high_k = float(f(200.0).full_util)
        assert full_low_k > full_high_k

    def test_grouping_beats_backfill_at_high_init(self, small_workload):
        """Predecessor-paper claim: at high init proportion, grouping
        outperforms per-job backfill on queue time."""
        pw = pack_workload(small_workload)
        M = small_workload.params.nodes
        s = small_workload.init_time_for_proportion(0.5)
        g = jax.tree.map(np.asarray, simulate_packet(pw, 10.0, s, M))
        b = jax.tree.map(np.asarray, simulate_backfill(pw, s, M))
        mg = efficiency_metrics(pw.submit, jax.tree.map(jnp.asarray, g), M, pw.t_last_submit)
        mb = efficiency_metrics(pw.submit, jax.tree.map(jnp.asarray, b), M, pw.t_last_submit)
        assert float(mg.avg_wait) < float(mb.avg_wait)


def test_vmap_k_sweep_matches_sequential(small_workload):
    """Batched scale-ratio sweep (one XLA program) == per-k execution."""
    import numpy as np
    from repro.core import run_packet_grid
    ks = [0.5, 2.0, 8.0, 50.0]
    a = run_packet_grid(small_workload, ks=ks, s_props=[0.05, 0.3])
    b = run_packet_grid(small_workload, ks=ks, s_props=[0.05, 0.3],
                        vmap_k=True)
    for f in ("avg_wait", "med_wait", "avg_qlen", "full_util",
              "useful_util"):
        np.testing.assert_allclose(getattr(a, f), getattr(b, f),
                                   rtol=1e-5, err_msg=f)
    assert np.asarray(b.ok).all()
