"""Streaming service: decide semantics, oracle parity, regret invariants.

The controller's plateau-hold rule is pinned on hand-built curves (no
simulation), the window oracle is pinned bitwise against the offline
grid driver on the same window, and the end-to-end loop is pinned on its
construction invariants: regret vs the per-tick optimum is >= 0, the
realized k always lags the commitment by one tick, and hysteresis never
switches more than the naive arg-best foil.
"""
import numpy as np
import pytest

from repro.core import pack_workload, precision, resolve_ring
from repro.core.sweep import run_window_oracle, run_packet_grid
from repro.service import (HysteresisController, NaiveController,
                           ServiceConfig, run_service)
from repro.service.driver import default_controllers
from repro.service.monitor import RollingMonitor, window_signals
from repro.workload.lublin import WorkloadParams, generate_workload
from repro.workload.windows import drift_workload, slice_window

KS = np.array([1.0, 2.0, 4.0, 8.0, 16.0])


class TestHysteresisDecide:
    def test_bootstrap_commits_argbest(self):
        c = HysteresisController()
        d = c.decide(KS, [100.0, 50.0, 10.0, 9.0, 10.0])
        assert d.k == 8.0 and d.moved and d.reason == "bootstrap"
        assert d.best_k == 8.0 and d.best_wait == 9.0

    def test_holds_inside_stable_plateau(self):
        """The arg-best hopping between near-tied plateau members must not
        move the committed k (the paper's plateau as the stability region)."""
        c = HysteresisController()
        c.decide(KS, [100.0, 50.0, 10.0, 9.0, 10.0])       # commits k=8
        d = c.decide(KS, [100.0, 50.0, 9.5, 10.0, 9.4])    # best hops to 16
        assert not d.moved and d.k == 8.0 and d.reason == "hold"
        assert c.k == 8.0
        # ... and stays held over many noisy re-ties
        for w in ([99.0, 48.0, 9.3, 9.6, 9.5], [101.0, 51.0, 9.9, 9.7, 9.6]):
            assert not c.decide(KS, w).moved

    def test_moves_when_leaving_plateau(self):
        c = HysteresisController()
        c.decide(KS, [100.0, 50.0, 10.0, 9.0, 10.0])       # commits k=8
        d = c.decide(KS, [100.0, 50.0, 30.0, 25.0, 5.0])   # k=8 left plateau
        assert d.moved and d.k == 16.0 and d.reason == "left-plateau"

    def test_grid_change_rebootstraps(self):
        c = HysteresisController()
        c.decide(KS, [5.0, 4.0, 3.0, 2.0, 1.0])
        d = c.decide(KS * 10, [5.0, 4.0, 3.0, 2.0, 1.0])
        assert d.reason == "bootstrap" and d.k == 160.0

    def test_validation(self):
        c = HysteresisController()
        with pytest.raises(ValueError):
            c.decide(KS, [1.0, 2.0])               # length mismatch
        with pytest.raises(ValueError):
            c.decide([], [])                        # empty curve
        with pytest.raises(ValueError):
            c.decide(KS, [1.0, 2.0, np.nan, 4.0, 5.0])
        with pytest.raises(ValueError):
            HysteresisController(rel_tol=-0.1)

    def test_zero_tolerance_degenerates_to_naive(self):
        strict = HysteresisController(rel_tol=0.0, abs_tol=0.0)
        naive = NaiveController()
        curves = ([3.0, 2.0, 1.0, 2.0, 3.0], [3.0, 2.0, 1.5, 1.0, 3.0],
                  [1.0, 2.0, 3.0, 4.0, 5.0])
        for w in curves:
            assert strict.decide(KS, w).k == naive.decide(KS, w).k


class TestNaiveDecide:
    def test_switches_whenever_argbest_moves(self):
        c = NaiveController()
        assert c.decide(KS, [3.0, 2.0, 1.0, 2.0, 3.0]).k == 4.0
        d = c.decide(KS, [3.0, 2.0, 1.01, 1.0, 3.0])
        assert d.moved and d.k == 8.0 and d.reason == "argbest"
        assert not c.decide(KS, [3.0, 2.0, 1.5, 1.0, 3.0]).moved


class TestMonitor:
    def test_window_signals(self):
        wl = generate_workload(WorkloadParams(
            n_jobs=300, nodes=100, load=0.9, homogeneous=True, seed=2))
        win = slice_window(wl, 50, 250)
        sig = window_signals(win, 0.05)
        assert sig.n_jobs == 200
        assert sig.span == pytest.approx(win.submit[-1] - win.submit[0])
        assert sig.arrival_rate == pytest.approx(200 / sig.span)
        assert sig.init_time == pytest.approx(
            win.init_time_for_proportion(0.05))
        assert sig.offered_load > 0

    def test_rolling_monitor_smooths_and_deltas(self):
        wl = generate_workload(WorkloadParams(
            n_jobs=300, nodes=100, load=0.9, homogeneous=True, seed=2))
        sig = window_signals(slice_window(wl, 0, 150), 0.05)
        m = RollingMonitor(alpha=0.5)
        first = m.observe(sig)
        assert first["ewm_offered_load"] == pytest.approx(sig.offered_load)
        assert first["delta_offered_load"] == 0.0
        sig2 = window_signals(slice_window(wl, 150, 300), 0.05)
        second = m.observe(sig2)
        assert second["ewm_offered_load"] == pytest.approx(
            0.5 * sig2.offered_load + 0.5 * sig.offered_load)
        with pytest.raises(ValueError):
            RollingMonitor(alpha=0.0)


class TestWindowOracle:
    def test_matches_offline_grid_bitwise(self):
        """One control tick == the offline sweep on the same window: the
        oracle through pre-packed operands must reproduce run_packet_grid's
        chunked column exactly (same engine, same lane ids)."""
        wl = generate_workload(WorkloadParams(
            n_jobs=250, nodes=100, load=0.9, homogeneous=True, seed=4))
        win = slice_window(wl, 0, 200)
        ks, s_prop = (0.5, 2.0, 8.0, 40.0), 0.05
        grid = run_packet_grid(win, ks=ks, s_props=[s_prop], mode="chunked")
        pw = pack_workload(win)
        m = run_window_oracle(pw, ks, win.init_time_for_proportion(s_prop),
                              win.params.nodes, mode="chunked")
        for f in ("avg_wait", "med_wait", "useful_util", "n_groups", "ok"):
            a, b = np.asarray(getattr(m, f)), np.asarray(getattr(grid, f))
            assert a.shape == (len(ks),)
            assert np.array_equal(a, b[:, 0]), f

    def test_rejects_grid_layouts_and_empty_ks(self):
        wl = generate_workload(WorkloadParams(
            n_jobs=50, nodes=20, load=0.9, homogeneous=True, seed=4))
        pw = pack_workload(wl)
        with pytest.raises(ValueError):
            run_window_oracle(pw, (1.0,), 10.0, 20, mode="vmap_k")
        with pytest.raises(ValueError):
            run_window_oracle(pw, (), 10.0, 20)


def _steady_trace(n_jobs=600):
    return drift_workload(
        WorkloadParams(n_jobs=n_jobs, nodes=100, load=0.9, homogeneous=True,
                       seed=9, daily_amplitude=0.3),
        loads=[0.9] * 3)


class TestRunService:
    @pytest.fixture(scope="class")
    def result(self):
        config = ServiceConfig(ks=(0.5, 2.0, 8.0, 40.0), window_jobs=200,
                               mode="chunked")
        return run_service(_steady_trace(), config,
                           default_controllers(config))

    def test_tick_count_and_shapes(self, result):
        assert result["n_ticks"] == 3
        assert len(result["oracle"]["best_k"]) == 3
        assert set(result["controllers"]) == {"hysteresis", "naive"}

    def test_regret_nonnegative_by_construction(self, result):
        """The realized k is always one of the oracle's candidates, so
        regret vs the per-tick arg-best can never go negative."""
        for name, s in result["controllers"].items():
            assert s["mean_regret_wait"] >= -1e-12, name
            assert s["mean_regret_useful"] >= -1e-12, name
            assert s["rel_regret_wait"] >= -1e-12, name

    def test_one_tick_actuation_delay(self, result):
        for name in result["controllers"]:
            for prev, cur in zip(result["ticks"], result["ticks"][1:]):
                assert (cur["controllers"][name]["realized_k"]
                        == prev["controllers"][name]["committed_k"]), name

    def test_hysteresis_holds_inside_stable_plateau(self, result):
        """On a zero-drift trace the hysteresis controller must not thrash:
        it may switch at most once after bootstrap, and never more than
        the naive arg-best foil."""
        h = result["controllers"]["hysteresis"]
        n = result["controllers"]["naive"]
        assert h["switches"] <= 1
        assert h["switches"] <= n["switches"]

    def test_provenance_recorded(self, result):
        t = result["ticks"][0]
        assert {"signals", "oracle_ms", "best_k", "plateau_k"} <= set(t)
        assert t["signals"]["n_jobs"] == 200
        assert t["controllers"]["hysteresis"]["reason"] == "bootstrap"
        assert result["config"]["window_jobs"] == 200

    def test_too_short_trace_raises(self):
        config = ServiceConfig(window_jobs=10_000)
        with pytest.raises(ValueError):
            run_service(_steady_trace(), config)

    def test_duplicate_controller_names_rejected(self):
        config = ServiceConfig(ks=(1.0, 2.0), window_jobs=200)
        with pytest.raises(ValueError):
            run_service(_steady_trace(), config,
                        [NaiveController(), NaiveController()])
