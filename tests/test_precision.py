"""Unit tests for the scoped float64 opt-in (`repro.core.precision`).

The contract under test: float64 is available exactly inside
`dtype_scope(float64)`, misuse fails loudly instead of silently truncating,
and no scope — however nested or exited — flips the session's global x64
state (float32 sessions never change behaviour because a float64 study ran
earlier in the process).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pack_workload, precision, simulate_packet

from conftest import make_workload


def _tiny_workload():
    return make_workload([0.0, 1.0], [10.0, 20.0], [1, 1], [0, 0], 2, 4)


class TestCanonicalDtype:
    def test_float32_always_valid(self):
        assert precision.canonical_dtype(jnp.float32) == np.dtype(np.float32)
        assert precision.canonical_dtype("float32") == np.dtype(np.float32)

    def test_float64_outside_scope_raises(self):
        assert not precision.x64_enabled()
        with pytest.raises(ValueError, match="jax_enable_x64"):
            precision.canonical_dtype(np.float64)

    def test_float64_inside_scope_valid(self):
        with precision.dtype_scope(np.float64):
            assert precision.x64_enabled()
            assert precision.canonical_dtype(np.float64) == \
                np.dtype(np.float64)
        assert not precision.x64_enabled()

    @pytest.mark.parametrize("bad", [np.int32, np.float16, bool])
    def test_non_simulation_dtypes_rejected(self, bad):
        with pytest.raises(ValueError, match="float32 or float64"):
            precision.canonical_dtype(bad)
        with pytest.raises(ValueError, match="float32 or float64"):
            with precision.dtype_scope(bad):
                pass


class TestDtypeScope:
    def test_float32_scope_is_noop(self):
        before = jax.config.jax_enable_x64
        with precision.dtype_scope(np.float32) as d:
            assert d == np.dtype(np.float32)
            assert jax.config.jax_enable_x64 == before

    def test_nested_scopes_restore(self):
        with precision.dtype_scope(np.float64):
            with precision.dtype_scope(np.float32):
                # inner float32 scope must not tear down the outer opt-in
                assert precision.x64_enabled()
            with precision.dtype_scope(np.float64):
                assert precision.x64_enabled()
            assert precision.x64_enabled()
        assert not precision.x64_enabled()

    def test_exception_restores(self):
        with pytest.raises(RuntimeError):
            with precision.dtype_scope(np.float64):
                raise RuntimeError("boom")
        assert not precision.x64_enabled()

    def test_session_default_untouched_after_float64_work(self):
        with precision.dtype_scope(np.float64):
            x = jnp.asarray(1.5, jnp.float64)
            assert x.dtype == jnp.float64
        assert jnp.asarray(1.5).dtype == jnp.float32


class TestPackedDtypes:
    def test_pack_respects_dtype(self):
        wl = _tiny_workload()
        pw32 = pack_workload(wl)
        assert pw32.submit.dtype == jnp.float32
        assert pw32.tj_prefw.dtype == jnp.float32
        with precision.dtype_scope(np.float64):
            pw64 = pack_workload(wl, np.float64)
            for field in ("submit", "work", "cumw", "runtime", "tj_submit",
                          "tj_prefw", "t_last_submit"):
                assert getattr(pw64, field).dtype == jnp.float64, field
            # integer tables stay int32 regardless of precision mode
            assert pw64.jtype.dtype == jnp.int32
            assert pw64.nodes.dtype == jnp.int32

    def test_pack_float64_outside_scope_raises(self):
        with pytest.raises(ValueError, match="jax_enable_x64"):
            pack_workload(_tiny_workload(), np.float64)

    def test_simulate_float64_pw_outside_scope_raises(self):
        wl = _tiny_workload()
        with precision.dtype_scope(np.float64):
            pw64 = pack_workload(wl, np.float64)
        # the packed arrays survive the scope, but simulating them outside
        # it would silently mix precisions — must refuse instead
        with pytest.raises(ValueError, match="jax_enable_x64"):
            simulate_packet(pw64, 1.0, 5.0, 4)

    def test_result_dtype_follows_workload(self):
        wl = _tiny_workload()
        res32 = simulate_packet(pack_workload(wl), 1.0, 5.0, 4)
        assert res32.start_t.dtype == jnp.float32
        assert res32.busy_ns.dtype == jnp.float32
        with precision.dtype_scope(np.float64):
            res64 = simulate_packet(pack_workload(wl, np.float64),
                                    1.0, 5.0, 4)
            assert res64.start_t.dtype == jnp.float64
            assert res64.qlen_int.dtype == jnp.float64
        np.testing.assert_allclose(np.asarray(res32.start_t),
                                   np.asarray(res64.start_t), rtol=1e-6)
