"""Checkpointing (atomicity, rotation, elastic re-shard) and ML-cluster
scheduler (failures, stragglers, work conservation, scale-ratio effect)."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.ckpt.checkpoint import latest_step
from repro.cluster import (ClusterConfig, ClusterSim, JobType, MLJob,
                           slice_for)
from repro.cluster.scheduler import workload_from_arrival_rate


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.arange(3.0)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    p = str(tmp_path / "ck")
    save_checkpoint(p, 3, _state(1.5), {"note": "x"})
    st, meta = restore_checkpoint(p, jax.tree.map(np.zeros_like, _state()))
    assert meta["step"] == 3 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                  np.full((4, 4), 1.5))
    assert int(st["opt"]["step"]) == 7


def test_restore_shape_mismatch_rejected(tmp_path):
    p = str(tmp_path / "ck")
    save_checkpoint(p, 0, _state())
    bad = {"params": {"w": np.zeros((2, 2)), "b": np.zeros(3)},
           "opt": {"step": np.zeros((), np.int32)}}
    with pytest.raises(ValueError):
        restore_checkpoint(p, bad)


def test_manager_rotation_and_async(tmp_path):
    p = str(tmp_path / "ck")
    mgr = CheckpointManager(p, keep=2)
    for s in range(5):
        mgr.save(s, _state(float(s)))
    mgr.wait()
    assert latest_step(p) == 4
    files = sorted(os.listdir(p))
    assert len([f for f in files if f.endswith(".npz")]) == 2
    st, meta = mgr.restore_latest(_state())
    assert meta["step"] == 4
    np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                  np.full((4, 4), 4.0))


def test_elastic_reshard_restore(tmp_path):
    """Restore with new shardings (1-device mesh: degenerate but exercises
    the device_put path the elastic restart uses)."""
    p = str(tmp_path / "ck")
    save_checkpoint(p, 1, _state(2.0))
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, _state())
    st, _ = restore_checkpoint(p, _state(), shardings=shardings)
    assert st["params"]["w"].sharding == sh


# ------------------------------------------------------------ cluster sim

TYPES = [JobType("yi-6b:train_4k", init_time=120.0, tp_degree=16),
         JobType("qwen2-moe:train_4k", init_time=300.0, tp_degree=16),
         JobType("granite:eval", init_time=60.0, tp_degree=8)]


def _run(cfg, n_jobs=120, horizon=4 * 3600.0, mean_work=64 * 600.0, seed=0):
    sim = ClusterSim(TYPES, cfg)
    for j in workload_from_arrival_rate(TYPES, n_jobs, horizon, mean_work,
                                        seed=seed):
        sim.submit(j)
    return sim, sim.run()


def test_all_work_completes():
    sim, m = _run(ClusterConfig(n_chips=256, scale_ratio=2.0))
    assert m["unfinished"] == 0
    assert m["groups"] <= m["jobs"]           # grouping really groups
    assert 0 < m["useful_util"] <= m["full_util"] <= 1.0 + 1e-9


def test_grouping_amortizes_init():
    """Useful utilization must beat one-group-per-job accounting."""
    sim, m = _run(ClusterConfig(n_chips=256, scale_ratio=2.0))
    # at least some groups contain >1 job
    assert m["groups"] < m["jobs"]


def test_scale_ratio_tradeoff_matches_paper():
    """Paper's headline: higher k -> shorter queues impossible; higher k
    reduces init overhead share (useful/full ratio up), lower k uses more
    chips per group (full util up, queue time down up to a point)."""
    waits, ratio = {}, {}
    for k in (0.25, 4.0, 64.0):
        _, m = _run(ClusterConfig(n_chips=256, scale_ratio=k), seed=3)
        waits[k] = m["avg_wait"]
        ratio[k] = m["useful_util"] / max(m["full_util"], 1e-9)
    # init-overhead share shrinks as k grows
    assert ratio[64.0] >= ratio[0.25] - 1e-6
    assert m["unfinished"] == 0


def test_failures_requeue_and_finish():
    cfg = ClusterConfig(n_chips=256, scale_ratio=2.0, ckpt_period=120.0,
                        mtbf_chip_hours=50.0, seed=1)
    sim, m = _run(cfg, n_jobs=80)
    assert m["unfinished"] == 0               # failures never lose jobs
    assert m["failures"] > 0                  # failures actually happened
    assert m["requeues"] >= m["failures"]
    assert m["lost_chip_seconds"] >= 0.0


def test_ckpt_period_bounds_lost_work():
    """Shorter checkpoint period -> less lost work under failures."""
    lost = {}
    for period in (60.0, 1800.0):
        cfg = ClusterConfig(n_chips=256, scale_ratio=2.0,
                            ckpt_period=period, mtbf_chip_hours=30.0, seed=5)
        _, m = _run(cfg, n_jobs=100, seed=5)
        lost[period] = m["lost_chip_seconds"] / max(m["failures"], 1)
    assert lost[60.0] <= lost[1800.0] + 1e-6


def test_straggler_mitigation():
    cfg = ClusterConfig(n_chips=256, scale_ratio=2.0, straggler_prob=0.5,
                        straggler_factor=4.0, straggler_deadline=1.5, seed=2)
    sim, m = _run(cfg, n_jobs=60)
    assert m["straggler_kills"] > 0           # deadline re-dispatch fired
    assert m["unfinished"] == 0               # and the work still finished


def test_slice_granularity():
    assert slice_for(256, 16) == (16, 16)
    assert slice_for(100, 16) == (6, 16)
    assert slice_for(8, 16) == (1, 16)
    sim, m = _run(ClusterConfig(n_chips=64, scale_ratio=1.0))
    assert m["unfinished"] == 0


class _FixedRng:
    """Deterministic rng stub: scripted uniform + exponential streams."""

    def __init__(self, uniforms=(), exponentials=()):
        self.uniforms = list(uniforms)
        self.exponentials = list(exponentials)
        self.exp_scales = []

    def random(self):
        return self.uniforms.pop(0) if self.uniforms else 1.0

    def exponential(self, scale):
        self.exp_scales.append(scale)
        return self.exponentials.pop(0) * scale if self.exponentials \
            else math.inf


def _single_job_sim(cfg, work=6000.0, init_time=100.0):
    sim = ClusterSim([JobType("t", init_time=init_time, tp_degree=1)], cfg)
    sim.submit(MLJob(jid=0, jtype=0, submit=0.0, work=work))
    return sim


def test_failure_time_is_group_relative():
    """Regression: `_maybe_fail` must return t0 + t_fail, the draw offset
    from the GROUP START (an earlier revision left a dead `dur * 0` term
    in the sum, which happened to cancel but documented nothing). The
    failure resolves at group end with the chips held throughout, and the
    checkpointed prefix of the run decides the loss."""
    cfg = ClusterConfig(n_chips=4, scale_ratio=2.0, ckpt_period=300.0,
                        mtbf_chip_hours=1.0)
    sim = _single_job_sim(cfg)
    # one group: m = ceil(6000 / (2*100)) = 30 -> clamped to 4 free chips,
    # dur = 100 + 6000/4 = 1600; script the failure 0.75 of the way into
    # the exponential scale 1/(4/3600) = 900 -> t_fail = 675 < dur
    sim.rng = _FixedRng(exponentials=[0.75])
    m = sim.run()
    assert sim.rng.exp_scales == [900.0, 900.0]
    assert m["failures"] == 1 and m["requeues"] == 1
    # run_done = 675 - 100 = 575; ckpt_done = 300; lost = 275 * 4 chips
    assert m["lost_chip_seconds"] == pytest.approx(275.0 * 4)
    # chips stayed held for the full 1600 s, and the remainder group
    # (6000 - 300*4 = 4800 chip-s) starts only at t=1600
    assert m["makespan"] == pytest.approx(1600.0 + 100.0 + 4800.0 / 4)


def test_failure_past_duration_is_survival():
    """A draw beyond the group duration means the group completes."""
    cfg = ClusterConfig(n_chips=4, scale_ratio=2.0, ckpt_period=300.0,
                        mtbf_chip_hours=1.0)
    sim = _single_job_sim(cfg)
    sim.rng = _FixedRng(exponentials=[5.0])   # 4500 s > dur 1600 s
    m = sim.run()
    assert m["failures"] == 0 and m["requeues"] == 0
    assert m["lost_chip_seconds"] == 0.0
    assert m["makespan"] == pytest.approx(1600.0)


def test_requeued_job_reports_last_completion():
    """Regression: `_finish` must stamp a completing member's finish with
    THIS group's end (an earlier revision took max() with the stale value,
    which could never pick anything else). A job that failed, requeued,
    and completed in a later group reports the later group's end."""
    cfg = ClusterConfig(n_chips=4, scale_ratio=2.0, ckpt_period=300.0,
                        mtbf_chip_hours=1.0)
    sim = _single_job_sim(cfg)
    sim.rng = _FixedRng(exponentials=[0.75])  # fail group 1 at t=675
    m = sim.run()
    assert m["unfinished"] == 0
    end = 1600.0 + 100.0 + 4800.0 / 4
    assert sim.jobs[0].finish == pytest.approx(end)
    assert sim.jobs[0].start == 0.0           # start keeps the FIRST group
    assert m["makespan"] == pytest.approx(end)
