"""Closed λ loop: FaultAwareController.adapt_lambda on hand-built telemetry.

The adaptive mode re-prices lost work online from the realized wait/lost
trade: `observe_realized` EWMAs the two magnitudes and `live_lambda`
returns clip(λ0 · ewm_wait / ewm_lost, λ0/span, λ0·span). These tests
pin the EWMA arithmetic on hand sequences, the span clip at both ends,
NaN carry-forward, the bitwise fixed-λ guarantee when adapt_lambda=False
(the default — the PR-9 controller must be byte-for-byte reproducible),
and the driver integration (ServiceConfig.adapt_lambda threads through
`default_controllers` and every fault-aware tick records the live λ its
decide actually used).
"""
import numpy as np
import pytest

from repro.service import (FaultAwareController, ServiceConfig,
                           default_controllers, run_service)
from repro.core.des import ChaosConfig
from repro.workload.lublin import WorkloadParams
from repro.workload.windows import drift_workload

KS = np.array([1.0, 4.0, 8.0, 16.0])
WAIT2 = np.array([[100.0, 110.0], [10.0, 11.0],
                  [10.2, 11.2], [10.4, 11.4]])
LOST2 = np.array([[900.0, 1800.0], [40.0, 80.0],
                  [20.0, 40.0], [1.0, 2.0]])
W = np.array([0.5, 0.5])


class TestLambdaEwma:
    def test_hand_sequence(self):
        """ewm ← (1-α)·ewm + α·x, seeded by the first sample."""
        fa = FaultAwareController(risk_lambda=0.5, adapt_lambda=True,
                                  lambda_alpha=0.25, lambda_span=100.0)
        assert fa.live_lambda == 0.5          # no telemetry yet: fixed λ0
        fa.observe_realized(200.0, 40.0)
        assert fa.ewm_wait == 200.0 and fa.ewm_lost == 40.0
        assert fa.live_lambda == pytest.approx(0.5 * 200.0 / 40.0)
        fa.observe_realized(100.0, 80.0)
        assert fa.ewm_wait == pytest.approx(0.75 * 200.0 + 0.25 * 100.0)
        assert fa.ewm_lost == pytest.approx(0.75 * 40.0 + 0.25 * 80.0)
        assert fa.live_lambda == pytest.approx(
            0.5 * fa.ewm_wait / fa.ewm_lost)

    def test_span_clip_both_ends(self):
        fa = FaultAwareController(risk_lambda=2.0, adapt_lambda=True,
                                  lambda_span=5.0)
        fa.observe_realized(1000.0, 0.0)      # loss-free regime: price caps
        assert fa.live_lambda == 2.0 * 5.0
        fa2 = FaultAwareController(risk_lambda=2.0, adapt_lambda=True,
                                   lambda_span=5.0)
        fa2.observe_realized(1.0, 1e6)        # loss-drenched: price floors
        assert fa2.live_lambda == 2.0 / 5.0

    def test_nan_telemetry_carries_forward(self):
        fa = FaultAwareController(adapt_lambda=True, lambda_alpha=0.5)
        fa.observe_realized(100.0, 10.0)
        lam = fa.live_lambda
        fa.observe_realized(float("nan"), float("nan"))
        assert fa.live_lambda == lam          # both EWMAs held
        fa.observe_realized(float("inf"), 10.0)
        assert fa.ewm_wait == 100.0           # inf dropped, lost folded
        assert fa.ewm_lost == 10.0

    def test_adaptation_flips_a_decision(self):
        """Same curve, different realized history, different commit: a
        loss-heavy history cheapens λ until wait dominates the cost."""
        quiet = FaultAwareController(risk_lambda=1.0, adapt_lambda=True,
                                     lambda_span=1000.0)
        drenched = FaultAwareController(risk_lambda=1.0, adapt_lambda=True,
                                        lambda_span=1000.0)
        quiet.observe_realized(10.0, 10.0)      # ratio 1: λ stays 1.0
        drenched.observe_realized(1.0, 500.0)   # ratio 0.002: λ → 0.002
        # at λ=1 the lost term makes k=16 cost-best; at λ=0.002 the wait
        # curve (arg-best k=4) decides
        assert quiet.decide(KS, WAIT2, lost=LOST2, weights=W).k == 16.0
        assert drenched.decide(KS, WAIT2, lost=LOST2, weights=W).k == 4.0

    def test_validation(self):
        with pytest.raises(ValueError, match="lambda_alpha"):
            FaultAwareController(lambda_alpha=0.0)
        with pytest.raises(ValueError, match="lambda_span"):
            FaultAwareController(lambda_span=0.5)


class TestFixedLambdaPreserved:
    def test_default_ignores_telemetry_bitwise(self):
        """adapt_lambda=False (the default): observe_realized may stream
        telemetry, live_lambda never moves, and every Decision matches a
        telemetry-blind twin exactly."""
        fixed = FaultAwareController(risk_lambda=0.1)
        fed = FaultAwareController(risk_lambda=0.1)
        rng = np.random.default_rng(3)
        for i in range(6):
            scale = 1.0 + 0.3 * float(rng.standard_normal())
            da = fixed.decide(KS, WAIT2 * scale, lost=LOST2, weights=W)
            db = fed.decide(KS, WAIT2 * scale, lost=LOST2, weights=W)
            assert da == db                   # full NamedTuple equality
            assert fed.live_lambda == 0.1
            fed.observe_realized(float(rng.uniform(1, 1e4)),
                                 float(rng.uniform(0, 1e4)))


CHAOS2 = ChaosConfig(mtbf_chip_hours=np.array([25.0, 800.0]),
                     ckpt_period=300.0, straggler_prob=0.1,
                     straggler_factor=np.array([4.0, 1.5]), seed=7)


def _trace(n_jobs=800):
    return drift_workload(
        WorkloadParams(n_jobs=n_jobs, nodes=100, load=0.9, homogeneous=True,
                       seed=9, daily_amplitude=0.3),
        loads=[0.9] * 4)


class TestDriverIntegration:
    def _run(self, **kw):
        config = ServiceConfig(ks=(0.5, 2.0, 8.0, 40.0), window_jobs=200,
                               mode="chunked", chaos=CHAOS2,
                               risk_lambda=0.1, **kw)
        return config, run_service(_trace(), config,
                                   controllers=default_controllers(config))

    def test_fixed_lambda_records_constant_price(self):
        config, out = self._run()
        lams = [t["controllers"]["fault_aware"]["risk_lambda"]
                for t in out["ticks"]]
        assert lams == [0.1] * out["n_ticks"]
        assert out["config"]["chaos"]["adapt_lambda"] is False

    def test_adaptive_lambda_moves_and_is_recorded(self):
        config, out = self._run(adapt_lambda=True, lambda_span=50.0)
        lams = [t["controllers"]["fault_aware"]["risk_lambda"]
                for t in out["ticks"]]
        assert lams[0] == 0.1                 # first decide: no telemetry yet
        assert len(set(lams)) > 1             # the loop actually re-priced
        lo, hi = 0.1 / 50.0, 0.1 * 50.0
        assert all(lo <= l <= hi for l in lams)
        assert out["config"]["chaos"]["adapt_lambda"] is True

    def test_fixed_run_matches_pre_loop_trajectories(self):
        """adapt_lambda=False service output: identical k trajectories and
        regrets whether or not the λ-loop plumbing observes telemetry —
        i.e. the PR-9 fixed-λ behavior is preserved."""
        _, a = self._run()
        _, b = self._run(lambda_alpha=0.9, lambda_span=2.0)  # inert knobs
        for name in a["controllers"]:
            assert (a["controllers"][name]["k_trajectory"]
                    == b["controllers"][name]["k_trajectory"])
            assert (a["controllers"][name]["total_regret_wait"]
                    == b["controllers"][name]["total_regret_wait"])
