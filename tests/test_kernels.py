"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles,
executed in interpret mode on CPU (the kernels target TPU).

Property-based sweeps live in ``test_kernels_properties.py`` behind the
optional ``hypothesis`` dev dependency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.packet_select.ops import fused_packet_select
from repro.kernels.packet_select.ref import packet_select_ref
from repro.kernels.rglru_scan.kernel import lru_chunked
from repro.kernels.rglru_scan.ref import lru_ref

KEY = jax.random.PRNGKey(7)


# ------------------------------------------------------------ flash attn

FLASH_CASES = [
    # B, Sq, Skv, H, KV, hd, causal, window
    (2, 64, 64, 4, 2, 32, True, 0),
    (1, 128, 128, 8, 8, 64, True, 0),
    (2, 48, 48, 4, 1, 32, True, 16),      # MQA + local window
    (1, 32, 96, 4, 2, 32, True, 0),       # prefix offset (Skv > Sq)
    (2, 64, 64, 4, 4, 32, False, 0),      # bidirectional (encoder)
    (1, 40, 40, 2, 2, 16, True, 0),       # non-multiple of block
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Skv, H, KV, hd, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=32, bkv=32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_softcap():
    q = jax.random.normal(KEY, (1, 64, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 4, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 4, 32))
    out = flash_attention(q, k, v, softcap=20.0, bq=32, bkv=32)
    ref = attention_ref(q, k, v, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ rglru scan

LRU_CASES = [
    # B, S, D, chunk, with_h0
    (2, 64, 128, 16, False),
    (1, 128, 256, 32, True),
    (2, 50, 100, 16, True),     # non-multiples: padding path
    (1, 8, 512, 128, False),    # chunk > S
]


@pytest.mark.parametrize("case", LRU_CASES)
def test_lru_chunked_matches_ref(case):
    B, S, D, chunk, with_h0 = case
    ks = jax.random.split(KEY, 3)
    log_a = -jnp.exp(jax.random.normal(ks[0], (B, S, D)) * 0.5) * 0.1
    b = jax.random.normal(ks[1], (B, S, D))
    h0 = jax.random.normal(ks[2], (B, D)) if with_h0 else None
    h, hlast = lru_chunked(log_a, b, h0, chunk=chunk, bd=128, interpret=True)
    href, hlast_ref = lru_ref(log_a, b, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(href),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hlast), np.asarray(hlast_ref),
                               rtol=2e-4, atol=2e-5)


def test_lru_decay_bounds():
    """Stability: with |b|<=1 and a<1 the state stays bounded by 1/(1-a)."""
    S, D = 256, 64
    log_a = jnp.full((1, S, D), jnp.log(0.9))
    b = jnp.ones((1, S, D)) * 0.5
    h, _ = lru_chunked(log_a, b, chunk=64, interpret=True)
    assert float(jnp.abs(h).max()) <= 0.5 / (1 - 0.9) + 1e-3


# ------------------------------------------------------------ packet select

def _rand_queues(key, N, H):
    ks = jax.random.split(key, 6)
    sum_w = jnp.abs(jax.random.normal(ks[0], (N, H))) * 1e4
    s_j = jnp.abs(jax.random.normal(ks[1], (N, H))) * 10 + 1
    p_j = jnp.ones((N, H))
    oldest = jnp.abs(jax.random.normal(ks[2], (N, H))) * 100
    t_max = jnp.full((N, H), 3600.0)
    nonempty = (jax.random.uniform(ks[3], (N, H)) > 0.3).astype(jnp.float32)
    nonempty = nonempty.at[:, 0].set(1.0)            # at least one nonempty
    now = jnp.abs(jax.random.normal(ks[4], (N,))) * 1000 + 200
    k = jnp.abs(jax.random.normal(ks[5], (N,))) * 5 + 0.1
    m_free = jnp.round(jnp.abs(jax.random.normal(ks[0], (N,))) * 100 + 1)
    return sum_w, s_j, p_j, oldest, t_max, nonempty, now, k, m_free


@pytest.mark.parametrize("H", [8, 64, 128, 130])
def test_packet_select_matches_policy(H):
    args = _rand_queues(KEY, 16, H)
    j, m, dur, work = fused_packet_select(*args)
    jr, mr, durr, workr = packet_select_ref(*args)
    np.testing.assert_array_equal(np.asarray(j), np.asarray(jr))
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dur), np.asarray(durr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(work), np.asarray(workr),
                               rtol=1e-6)


def test_packet_select_paper_example():
    """Paper Fig. 3: s=1min, work=4 node-min: k=0.5 -> 8 nodes, 0.5 min."""
    one = lambda v: jnp.asarray([v], jnp.float32)
    H = 1
    for k, m_exp, dur_exp in [(0.5, 8, 1.5), (1.0, 4, 2.0), (2.0, 2, 3.0),
                              (4.0, 1, 5.0)]:
        j, m, dur, work = fused_packet_select(
            jnp.full((1, H), 4.0), jnp.ones((1, H)), jnp.ones((1, H)),
            jnp.zeros((1, H)), jnp.full((1, H), 3600.0), jnp.ones((1, H)),
            one(0.0), one(k), one(100.0))
        assert int(m[0]) == m_exp, (k, m)
        assert float(dur[0]) == pytest.approx(dur_exp)  # init 1 + exec 4/m
