"""Windowing layer: bitwise-slice guarantee, bounds, drift scenarios.

The streaming service's reproducibility story rests on two facts pinned
here: (1) window w of seed s is *bitwise* a slice of the full trace —
no regeneration, no rounding — in the raw arrays and in the packed
per-job tables of BOTH simulation dtypes; (2) the drift scenarios are
seed-stable (sha256 golden digests, same scheme as
`test_workload_golden.py` — regenerate intentional changes with
``PYTHONPATH=src python tests/test_windows.py``).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import pack_workload, precision
from repro.workload.lublin import (WorkloadParams, generate_workload,
                                   generate_workload_batch, workload_statics)
from repro.workload.windows import (WindowSpec, drift_scenarios,
                                    drift_workload, iter_windows,
                                    iter_windows_batch, n_dropped,
                                    slice_window, window_bounds)

PARAMS = WorkloadParams(n_jobs=600, nodes=100, load=0.9, homogeneous=True,
                        seed=5)

# drift_scenarios(n_jobs=320, nodes=100, n_segments=4); regen via __main__.
# Note the structure the digests expose: intensity drift recalibrates
# RUNTIMES (the arrival process and node/type draws are seed-determined,
# so submit/nodes/jtype match steady bit-for-bit), while homogeneity-mode
# drift changes every draw of the heterogeneous segments.
GOLDEN = {
    "steady": {
        "submit": "484664cfa46c63c70a9fe7b2f30124e7cb01292827b6adcbb432bd5fd625828a",
        "runtime": "b21858f93a76eb595474ac10ca578bbe84b48300b42454373761605989d263f8",
        "nodes": "ed16e9ba74a6809655cb8629519c6c2fa6f8c32a6a05566d01ee0552a005fd16",
        "jtype": "511ce8a53ba5ef6f7f0cfd9a9fcb134faa11e45ffe363322afbaea3ed235d83b",
    },
    "intensity_ramp": {
        "submit": "484664cfa46c63c70a9fe7b2f30124e7cb01292827b6adcbb432bd5fd625828a",
        "runtime": "3effd4602039af071a872fb7af1316155b4dd7fba2e492fdb3c8f0075f027d1b",
        "nodes": "ed16e9ba74a6809655cb8629519c6c2fa6f8c32a6a05566d01ee0552a005fd16",
        "jtype": "511ce8a53ba5ef6f7f0cfd9a9fcb134faa11e45ffe363322afbaea3ed235d83b",
    },
    "intensity_step": {
        "submit": "484664cfa46c63c70a9fe7b2f30124e7cb01292827b6adcbb432bd5fd625828a",
        "runtime": "bd18cd4d03d2f63eb926579c4c4adc1409ce21ac084225617f1330f61d3ec2fd",
        "nodes": "ed16e9ba74a6809655cb8629519c6c2fa6f8c32a6a05566d01ee0552a005fd16",
        "jtype": "511ce8a53ba5ef6f7f0cfd9a9fcb134faa11e45ffe363322afbaea3ed235d83b",
    },
    "homogeneity_ramp": {
        "submit": "484664cfa46c63c70a9fe7b2f30124e7cb01292827b6adcbb432bd5fd625828a",
        "runtime": "b8805bc46af9e05e3adbbf56bdf2ff1169e8c86de422521c5a9ec5fd72d1f265",
        "nodes": "ed16e9ba74a6809655cb8629519c6c2fa6f8c32a6a05566d01ee0552a005fd16",
        "jtype": "511ce8a53ba5ef6f7f0cfd9a9fcb134faa11e45ffe363322afbaea3ed235d83b",
    },
    "homogeneity_step": {
        "submit": "05e5566675be4515bdf6e22efc2b5acfa4cc832603651184b9b929b7564cb435",
        "runtime": "2ff08aeccdc4c93a42b264f141af8f1278077985e15c75261409cafb4e355c65",
        "nodes": "2d3aca7b5c64d5afff5f9b9b40dc1999e4c0691b7f8f218beffaf701e52c5cac",
        "jtype": "6165026fb1746ef1d82f249744aa6f6493da07b05eb555c91941d412a526be18",
    },
}


def _scenarios():
    return drift_scenarios(n_jobs=320, nodes=100, n_segments=4)


class TestSliceWindow:
    def test_raw_arrays_are_bitwise_views(self):
        wl = generate_workload(PARAMS)
        w = slice_window(wl, 100, 300, rebase=False)
        for f in ("submit", "runtime", "nodes", "work", "jtype"):
            full = getattr(wl, f)
            assert np.shares_memory(getattr(w, f), full)
            assert np.array_equal(getattr(w, f), full[100:300])
        assert w.params.n_jobs == 200

    def test_rebase_shifts_only_submit(self):
        wl = generate_workload(PARAMS)
        w = slice_window(wl, 100, 300)
        assert np.array_equal(w.submit, wl.submit[100:300] - wl.submit[100])
        assert w.submit[0] == 0.0
        assert np.shares_memory(w.runtime, wl.runtime)
        # the shift is a deterministic float64 op: slicing twice agrees
        w2 = slice_window(generate_workload(PARAMS), 100, 300)
        for f in ("submit", "runtime", "nodes", "work", "jtype"):
            assert np.array_equal(getattr(w, f), getattr(w2, f))

    @pytest.mark.parametrize("dtype", ["float32", "float64"])
    def test_packed_window_is_bitwise_slice_both_dtypes(self, dtype):
        """The per-job packed tables of a window equal slices of the full
        trace's packed tables, bit for bit, in either simulation dtype
        (per-type tables are rank-relative and legitimately differ)."""
        wl = generate_workload(PARAMS)
        with precision.dtype_scope(np.dtype(dtype)):
            pw_full = pack_workload(wl, np.dtype(dtype))
            w = slice_window(wl, 150, 350, rebase=False)
            pw_win = pack_workload(w, np.dtype(dtype))
            for f in ("work", "runtime", "nodes", "jtype"):
                a = np.asarray(getattr(pw_win, f))
                b = np.asarray(getattr(pw_full, f))[150:350]
                assert a.dtype == b.dtype
                assert np.array_equal(a, b), f
            # and packing is deterministic across regenerations
            w2 = slice_window(generate_workload(PARAMS), 150, 350,
                              rebase=False)
            pw_win2 = pack_workload(w2, np.dtype(dtype))
            for f in ("submit", "work", "tj_submit", "tj_prefw", "cumw"):
                assert np.array_equal(np.asarray(getattr(pw_win, f)),
                                      np.asarray(getattr(pw_win2, f))), f

    def test_out_of_range_raises(self):
        wl = generate_workload(PARAMS)
        for lo, hi in ((-1, 10), (10, 10), (590, 601), (300, 200)):
            with pytest.raises(ValueError):
                slice_window(wl, lo, hi)


class TestWindowBounds:
    def test_tumbling_and_rolling(self):
        assert window_bounds(600, WindowSpec(200)) == [
            (0, 200), (200, 400), (400, 600)]
        assert window_bounds(600, WindowSpec(200, stride_jobs=100)) == [
            (0, 200), (100, 300), (200, 400), (300, 500), (400, 600)]
        assert window_bounds(600, WindowSpec(250, stride_jobs=300)) == [
            (0, 250), (300, 550)]

    def test_partial_tail_dropped(self):
        assert window_bounds(590, WindowSpec(200)) == [(0, 200), (200, 400)]
        assert n_dropped(590, WindowSpec(200)) == 190
        assert window_bounds(100, WindowSpec(200)) == []
        assert n_dropped(100, WindowSpec(200)) == 100
        assert n_dropped(600, WindowSpec(200)) == 0

    def test_invalid_spec(self):
        with pytest.raises(ValueError):
            WindowSpec(0)
        with pytest.raises(ValueError):
            WindowSpec(10, stride_jobs=0)

    def test_iter_windows_fixed_shape(self):
        wl = generate_workload(PARAMS)
        wins = list(iter_windows(wl, WindowSpec(200, stride_jobs=150)))
        assert [(lo, hi) for lo, hi, _ in wins] == window_bounds(
            600, WindowSpec(200, stride_jobs=150))
        # every window shares the statics signature -> one jit cache
        statics = {workload_statics(w) for _, _, w in wins}
        assert len(statics) == 1

    def test_iter_windows_batch_replicas(self):
        flows = generate_workload_batch(
            dataclasses.replace(PARAMS, n_jobs=300), n_replicas=2,
            name_fmt="r{r}")
        rows = list(iter_windows_batch(flows, WindowSpec(150)))
        assert [(n, lo, hi) for n, lo, hi, _ in rows] == [
            ("r0", 0, 150), ("r0", 150, 300),
            ("r1", 0, 150), ("r1", 150, 300)]
        for name, lo, hi, win in rows:
            assert np.array_equal(win.runtime, flows[name].runtime[lo:hi])


class TestDriftScenarios:
    def test_golden_digests(self):
        got = {n: wl.golden_digest() for n, wl in _scenarios().items()}
        assert got == GOLDEN, (
            "drift scenarios drifted from their golden digests; if "
            "intentional, regenerate with "
            "`PYTHONPATH=src python tests/test_windows.py`")

    def test_submits_monotone_and_statics_shared(self):
        for name, wl in _scenarios().items():
            assert np.all(np.diff(wl.submit) >= 0), name
            assert wl.params.nodes == 100 and wl.params.n_types == 8, name
            assert len(wl.submit) == 320, name

    def test_intensity_ramp_actually_ramps(self):
        wl = _scenarios()["intensity_ramp"]
        seg = np.array_split(np.asarray(wl.work), 4)
        means = [s.mean() for s in seg]
        # offered load = work per wall-clock; horizon per segment is fixed,
        # so ramping load must ramp per-segment total work
        assert means[0] < means[-1]

    def test_homogeneity_step_widens_dispersion(self):
        wl = _scenarios()["homogeneity_step"]
        rt = np.asarray(wl.runtime)
        first, second = rt[:160], rt[160:]
        cv = lambda x: x.std() / x.mean()
        assert cv(second) > 1.5 * cv(first)

    def test_segment_count_validation(self):
        base = dataclasses.replace(PARAMS, n_jobs=100)
        with pytest.raises(ValueError):
            drift_workload(base)                      # no segment info
        with pytest.raises(ValueError):
            drift_workload(base, n_segments=4, loads=[0.9] * 3)
        with pytest.raises(ValueError):
            drift_workload(base, n_segments=200)      # < 1 job per segment


if __name__ == "__main__":
    for name, wl in _scenarios().items():
        print(f'    "{name}": {wl.golden_digest()!r},')
