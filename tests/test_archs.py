"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / decode step on CPU, asserting output shapes + finiteness (no NaNs).
The FULL configs are exercised only via the dry-run (see launch/dryrun.py).

Whole module is `slow` (minutes of XLA compiles across every architecture):
deselected from tier-1 by the default ``-m "not slow"`` addopts; run the
full matrix with ``pytest -m ""``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cells, get_config, smoke_config
from repro.models.layers import unbox, unembed
from repro.models.registry import get_family
from repro.sharding.policy import single_device_policy

KEY = jax.random.PRNGKey(0)

pytestmark = pytest.mark.slow


def _inputs(cfg, B, S):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    embeds = None
    if cfg.family == "encdec":
        embeds = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.02
    elif cfg.embeds_input and cfg.n_prefix:
        embeds = jax.random.normal(KEY, (B, cfg.n_prefix, cfg.d_model)) * 0.02
    return toks, embeds


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = smoke_config(arch)
    pol = single_device_policy(cfg)
    fam = get_family(cfg)
    params, _ = unbox(fam.init_params(cfg, pol, KEY))
    B, S = 2, 32
    toks, embeds = _inputs(cfg, B, S)
    hidden, aux = jax.jit(
        lambda p, t, e: fam.forward(cfg, pol, p, t, e))(params, toks, embeds)
    assert hidden.shape == (B, S, cfg.d_model)
    logits = unembed(cfg, pol, hidden, params["embed"])
    assert logits.shape[-1] % 16 == 0 and logits.shape[-1] >= cfg.vocab_size
    assert bool(jnp.isfinite(hidden).all())
    assert bool(jnp.isfinite(aux).all())
    # padded vocab entries must never win an argmax
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_smoke(arch):
    cfg = smoke_config(arch)
    pol = single_device_policy(cfg)
    fam = get_family(cfg)
    params, _ = unbox(fam.init_params(cfg, pol, KEY))
    B = 2
    cache = fam.init_cache(cfg, pol, B, 48)
    step = jax.jit(lambda p, c, t: fam.decode_step(cfg, pol, p, c, t))
    tok = jnp.ones((B, 1), jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert bool(jnp.isfinite(logits).all())
    assert int(cache.pos) == 3


def test_full_configs_match_assignment():
    """The full-size configs carry the exact assignment table dims."""
    table = {   # arch: (L, d_model, H, kv, d_ff, vocab)
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    for arch, (L, d, H, kv, ff, V) in table.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, kv, ff, V), arch
    assert get_config("qwen2-moe-a2.7b").n_experts == 60
    assert get_config("qwen2-moe-a2.7b").experts_per_token == 4
    assert get_config("arctic-480b").n_experts == 128
    assert get_config("arctic-480b").experts_per_token == 2
    assert get_config("arctic-480b").dense_residual


def test_cell_grid_is_40():
    assert len(cells()) == 40 - 8   # 10 archs x 4 shapes - 8 long_500k skips
    # the 8 skipped cells are explicitly recorded
    from repro.configs import skipped_cells
    assert len(skipped_cells()) == 8
    assert len(cells()) + len(skipped_cells()) == 40


def test_moe_route_exactness():
    """With huge capacity, MoE output must equal dense per-token expert mix."""
    cfg = smoke_config("qwen2-moe-a2.7b", capacity_factor=8.0,
                       shared_expert_d_ff=0)
    pol = single_device_policy(cfg)
    from repro.models import moe as moe_lib
    p, _ = unbox(moe_lib.moe_init(KEY, cfg, pol))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model)) * 0.5
    out, aux = moe_lib.moe_forward(p, cfg, pol, x)
    # oracle: per-token dense top-k mixture
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)

    def tok(xv, g, ix):
        o = 0
        for j in range(cfg.experts_per_token):
            h = (jax.nn.silu(xv @ p["wg"][ix[j]]) * (xv @ p["wi"][ix[j]]))
            o = o + g[j] * (h @ p["wo"][ix[j]])
        return o

    ref = jax.vmap(jax.vmap(tok))(x, gate, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_prefill_decode_consistency():
    """lm.prefill + decode must produce the same logits as full forward."""
    from repro.models import lm
    cfg = smoke_config("yi-6b")
    pol = single_device_policy(cfg)
    fam = get_family(cfg)
    params, _ = unbox(fam.init_params(cfg, pol, KEY))
    B, S = 2, 16
    toks, _ = _inputs(cfg, B, S)
    hidden, _ = fam.forward(cfg, pol, params, toks)
    full_logits = unembed(cfg, pol, hidden, params["embed"])

    # decode token-by-token from an empty cache (f32 cache: exactness)
    cache = fam.init_cache(cfg, pol, B, S + 4, dtype=jnp.float32)
    outs = []
    for i in range(S):
        lg, cache = fam.decode_step(cfg, pol, params, cache, toks[:, i:i + 1])
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=5e-3, atol=5e-3)

    # prefill path agrees too
    hid2, cache2 = lm.prefill(cfg, pol, params, toks, S + 4,
                              cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(hid2), np.asarray(hidden),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache2.k[:, :, :S]),
                               np.asarray(cache.k[:, :, :S]),
                               rtol=5e-3, atol=5e-3)


def test_recurrent_decode_matches_forward():
    """xLSTM/RG-LRU: token-by-token decode == full-sequence forward."""
    for arch in ("xlstm-1.3b", "recurrentgemma-2b"):
        cfg = smoke_config(arch)
        pol = single_device_policy(cfg)
        fam = get_family(cfg)
        params, _ = unbox(fam.init_params(cfg, pol, KEY))
        B, S = 1, 12
        toks, _ = _inputs(cfg, B, S)
        hidden, _ = fam.forward(cfg, pol, params, toks)
        full_logits = unembed(cfg, pol, hidden, params["embed"])
        cache = fam.init_cache(cfg, pol, B, S + 4)
        outs = []
        for i in range(S):
            lg, cache = fam.decode_step(cfg, pol, params, cache,
                                        toks[:, i:i + 1])
            outs.append(lg)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                                   rtol=2e-2, atol=2e-2, err_msg=arch)


def test_local_window_ring_cache():
    """RG local attention with a ring cache must match a full cache."""
    cfg = smoke_config("recurrentgemma-2b", local_window=8)
    pol = single_device_policy(cfg)
    fam = get_family(cfg)
    params, _ = unbox(fam.init_params(cfg, pol, KEY))
    B, S = 1, 20           # S > window: the ring wraps
    toks, _ = _inputs(cfg, B, S)
    hidden, _ = fam.forward(cfg, pol, params, toks)
    full_logits = unembed(cfg, pol, hidden, params["embed"])
    cache = fam.init_cache(cfg, pol, B, S)   # T=window=8 ring
    assert cache.k.shape[2] == 8
    outs = []
    for i in range(S):
        lg, cache = fam.decode_step(cfg, pol, params, cache, toks[:, i:i + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec[:, -4:]),
                               np.asarray(full_logits[:, -4:]),
                               rtol=2e-2, atol=2e-2)
