"""Differential suite: FCFS / EASY-backfill vs brute-force numpy references.

`test_des_equivalence.py` pins the Packet simulator against its seed
implementation; this module gives the two rigid baselines the same
treatment. The references below re-implement the exact event-loop semantics
of `repro.core.schedulers` with plain Python/numpy data structures — list
walks instead of fixed-shape `lax.while_loop` state — so a bug in the JAX
formulation (slot bookkeeping, shadow-time reservation, window clipping)
cannot hide in both implementations at once.

Tie-breaking is part of the contract and is mirrored deliberately:
first-minimal event slot (`argmin`), first-free ring slot (`argmax` over
isinf), submit-before-finish on equal timestamps, and a *stable* sort of
running groups by end time in the backfill reservation pass.

Randomized workloads use quarter-integer times (multiples of 0.25 well
below 2**22), which are exactly representable in float32, so the float32
simulators are compared against the float64 references with zero tolerance
for decision flips. A reduced Lublin workload additionally exercises the
float64 simulation path through the `precision` opt-in with tight
tolerances (identical operation order => agreement to ~ulp).
"""
import numpy as np
import pytest

from repro.core import (pack_workload, precision, resolve_ring,
                        simulate_backfill, simulate_fcfs)
from repro.workload.lublin import WorkloadParams, generate_workload

from conftest import make_workload


def _overlap(a, b, t_end):
    return max(min(b, t_end) - min(a, t_end), 0.0)


class _RefSim:
    """Shared submit/finish event skeleton (mirrors `_event_skeleton`)."""

    def __init__(self, submit, runtime, nodes, s_init, m_nodes, ring):
        self.submit = np.asarray(submit, np.float64)
        self.runtime = np.asarray(runtime, np.float64)
        self.nodes = np.asarray(nodes, np.int64)
        self.s = float(s_init)
        self.N = len(self.submit)
        self.t_end = float(self.submit[-1])
        self.t = 0.0
        self.next_sub = 0
        self.head_ptr = 0
        self.started = np.zeros(self.N, bool)
        self.m_free = int(m_nodes)
        self.grp_end = np.full(ring, np.inf)
        self.grp_m = np.zeros(ring, np.int64)
        self.start_t = np.full(self.N, np.inf)
        self.qlen_int = 0.0
        self.busy = 0.0
        self.useful = 0.0
        self.n_started = 0

    def slot_free(self):
        return bool(np.isinf(self.grp_end).any())

    def start_job(self, i):
        t_fin = self.t + self.s + self.runtime[i]
        slot = int(np.argmax(np.isinf(self.grp_end)))
        m = int(self.nodes[i])
        self.busy += m * _overlap(self.t, t_fin, self.t_end)
        self.useful += m * _overlap(self.t + self.s, t_fin, self.t_end)
        self.started[i] = True
        self.m_free -= m
        self.grp_end[slot] = t_fin
        self.grp_m[slot] = m
        self.start_t[i] = self.t
        self.n_started += 1

    def run(self, sched_pass, max_iters):
        iters = 0
        while ((self.next_sub < self.N or np.isfinite(self.grp_end).any())
               and iters < max_iters):
            t_sub = (self.submit[self.next_sub]
                     if self.next_sub < self.N else np.inf)
            slot = int(np.argmin(self.grp_end))
            t_fin = self.grp_end[slot]
            take_sub = t_sub <= t_fin
            t_new = t_sub if take_sub else t_fin
            n_wait = self.next_sub - self.n_started
            self.qlen_int += n_wait * _overlap(self.t, t_new, self.t_end)
            self.t = t_new
            if take_sub:
                self.next_sub += 1
            else:
                self.m_free += int(self.grp_m[slot])
                self.grp_end[slot] = np.inf
                self.grp_m[slot] = 0
            sched_pass(self)
            iters += 1
        ok = (self.next_sub >= self.N and not np.isfinite(self.grp_end).any()
              and self.started.all())
        return {
            "start_t": self.start_t, "run_start_t": self.start_t + self.s,
            "qlen_int": self.qlen_int, "busy_ns": self.busy,
            "useful_ns": self.useful, "n_groups": self.n_started,
            "makespan": self.t, "ok": ok,
        }


def ref_fcfs(submit, runtime, nodes, s_init, m_nodes, ring):
    sim = _RefSim(submit, runtime, nodes, s_init, m_nodes, ring)

    def sched(sim):
        while (sim.head_ptr < sim.next_sub
               and sim.nodes[sim.head_ptr] <= sim.m_free and sim.slot_free()):
            sim.start_job(sim.head_ptr)
            sim.head_ptr += 1

    return sim.run(sched, 4 * sim.N + 64)


def ref_backfill(submit, runtime, nodes, s_init, m_nodes, ring,
                 backfill_depth=64):
    sim = _RefSim(submit, runtime, nodes, s_init, m_nodes, ring)

    def waiting_idx(sim):
        return [i for i in range(sim.next_sub) if not sim.started[i]]

    def sched(sim):
        # 1) start from the head while it fits
        while True:
            w = waiting_idx(sim)
            if not (w and sim.nodes[w[0]] <= sim.m_free and sim.slot_free()):
                break
            sim.start_job(w[0])

        # 2) reservation for a blocked head: shadow time + extra nodes
        w = waiting_idx(sim)
        any_wait = bool(w)
        head = w[0] if any_wait else 0
        n_head = int(sim.nodes[head]) if any_wait else 1
        order = np.argsort(sim.grp_end, kind="stable")
        ends = sim.grp_end[order]
        frees = np.cumsum(sim.grp_m[order]) + sim.m_free
        enough = frees >= n_head
        if enough.any():
            shadow_i = int(np.argmax(enough))
            shadow, free_at_shadow = ends[shadow_i], int(frees[shadow_i])
        else:
            shadow, free_at_shadow = np.inf, sim.m_free
        extra = max(free_at_shadow - n_head, 0)

        # 3) up to backfill_depth candidates behind the head, in index order
        for i in [j for j in w if j != head][:backfill_depth]:
            fits_now = sim.nodes[i] <= sim.m_free
            ends_before = sim.t + sim.s + sim.runtime[i] <= shadow
            within_extra = sim.nodes[i] <= extra
            if (fits_now and (ends_before or within_extra)
                    and sim.slot_free() and any_wait):
                sim.start_job(i)

    return sim.run(sched, 4 * sim.N + 64)


def random_quarter_workload(seed):
    """Exact-in-float32 rigid workload: all times are multiples of 0.25."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 60))
    m = int(rng.choice([4, 8, 32]))
    submit = np.cumsum(rng.integers(0, 40, n)) / 4.0
    runtime = rng.integers(1, 400, n) / 4.0
    nodes = rng.integers(1, m + 1, n)
    jtype = rng.integers(0, 4, n)
    s_init = float(rng.choice([0.0, 2.5, 7.25]))
    wl = make_workload(submit, runtime, nodes, jtype, 4, m)
    return wl, s_init, m


def assert_matches_reference(res, ref, rtol=1e-6, atol=1e-6):
    res = {f: np.asarray(getattr(res, f)) for f in ref}
    assert bool(res["ok"]) == ref["ok"]
    for f in ("start_t", "run_start_t", "qlen_int", "busy_ns", "useful_ns",
              "n_groups", "makespan"):
        np.testing.assert_allclose(res[f], ref[f], rtol=rtol, atol=atol,
                                   err_msg=f)


class TestFcfsDifferential:
    @pytest.mark.parametrize("seed", range(8))
    def test_randomized_exact(self, seed):
        wl, s, m = random_quarter_workload(seed)
        pw = pack_workload(wl)
        ring = resolve_ring(m, pw.n_jobs)
        ref = ref_fcfs(wl.submit, wl.runtime, wl.nodes, s, m, ring)
        assert ref["ok"]
        assert_matches_reference(simulate_fcfs(pw, s, m), ref)

    def test_lublin_float64(self, small_workload):
        wl = small_workload
        m = wl.params.nodes
        s = wl.init_time_for_proportion(0.3)
        ring = resolve_ring(m, wl.n_jobs)
        ref = ref_fcfs(wl.submit, wl.runtime, wl.nodes, s, m, ring)
        with precision.dtype_scope(np.float64):
            res = simulate_fcfs(pack_workload(wl, np.float64), s, m)
            assert_matches_reference(res, ref, rtol=1e-9, atol=1e-9)


class TestBackfillDifferential:
    @pytest.mark.parametrize("seed", range(8))
    def test_randomized_exact(self, seed):
        wl, s, m = random_quarter_workload(seed + 100)
        pw = pack_workload(wl)
        ring = resolve_ring(m, pw.n_jobs)
        ref = ref_backfill(wl.submit, wl.runtime, wl.nodes, s, m, ring)
        assert ref["ok"]
        assert_matches_reference(simulate_backfill(pw, s, m), ref)

    def test_lublin_float64(self, small_workload):
        wl = small_workload
        m = wl.params.nodes
        s = wl.init_time_for_proportion(0.2)
        ring = resolve_ring(m, wl.n_jobs)
        ref = ref_backfill(wl.submit, wl.runtime, wl.nodes, s, m, ring)
        with precision.dtype_scope(np.float64):
            res = simulate_backfill(pack_workload(wl, np.float64), s, m)
            assert_matches_reference(res, ref, rtol=1e-9, atol=1e-9)

    def test_backfill_no_worse_than_fcfs_on_avg_start(self):
        """Sanity cross-check between the two references themselves."""
        for seed in range(4):
            wl, s, m = random_quarter_workload(seed + 200)
            ring = resolve_ring(m, wl.n_jobs)
            f = ref_fcfs(wl.submit, wl.runtime, wl.nodes, s, m, ring)
            b = ref_backfill(wl.submit, wl.runtime, wl.nodes, s, m, ring)
            assert b["start_t"].mean() <= f["start_t"].mean() + 1e-9
