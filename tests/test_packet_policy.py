"""Unit tests for the pure Packet policy functions (paper §5)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packet


class TestPaperExample:
    """Paper Fig. 3: s = 1 min, group work = 4 node-minutes."""

    @pytest.mark.parametrize("k,expected_nodes,expected_exec", [
        (0.5, 8, 0.5), (1.0, 4, 1.0), (2.0, 2, 2.0), (4.0, 1, 4.0)])
    def test_node_count_and_exec_time(self, k, expected_nodes, expected_exec):
        s, work = 60.0, 4 * 60.0
        m = packet.m_threshold(jnp.asarray(work), k, s)
        assert int(m) == expected_nodes
        dur = packet.group_duration(jnp.asarray(work), s, m)
        assert float(dur) == pytest.approx(s + expected_exec * 60.0)

    def test_exec_time_is_k_times_init(self):
        # the defining property of the scale ratio
        s, work = 60.0, 4 * 60.0
        for k in (0.5, 1.0, 2.0, 4.0):
            m = packet.m_threshold(jnp.asarray(work), k, s)
            exec_time = work / float(m)
            assert exec_time == pytest.approx(k * s)


class TestGroupNodes:
    def test_capped_by_free_nodes(self):
        m = packet.group_nodes(jnp.asarray(240.0), 0.5, 60.0, 3)
        assert int(m) == 3  # threshold would be 8

    def test_ceil_guarantees_exec_le_k_init(self):
        # non-exact division: ceil gives exec time <= k * s
        work, k, s = 250.0, 1.0, 60.0
        m = int(packet.m_threshold(jnp.asarray(work), k, s))
        assert m == 5
        assert work / m <= k * s + 1e-9

    def test_at_least_one_node(self):
        assert int(packet.m_threshold(jnp.asarray(1.0), 1000.0, 60.0)) == 1


class TestQueueWeights:
    def test_empty_queue_masked(self):
        w = packet.queue_weights(
            jnp.asarray([100.0, 0.0]), jnp.asarray([10.0, 10.0]),
            jnp.ones(2), jnp.asarray([0.0, 0.0]), 50.0,
            jnp.full((2,), 3600.0), jnp.asarray([True, False]))
        assert np.isneginf(np.asarray(w)[1])
        assert np.asarray(w)[0] > 0

    def test_advisability_scales_with_work_over_init(self):
        # C_j = sum(e)/s: doubling work doubles the weight (at equal waits)
        args = dict(priority=jnp.ones(1), oldest_submit=jnp.asarray([0.0]),
                    now=0.0, t_max=jnp.full((1,), 3600.0),
                    nonempty=jnp.asarray([True]))
        w1 = packet.queue_weights(jnp.asarray([100.0]), jnp.asarray([10.0]), **args)
        w2 = packet.queue_weights(jnp.asarray([200.0]), jnp.asarray([10.0]), **args)
        assert float(w2[0]) == pytest.approx(2 * float(w1[0]))

    def test_waiting_raises_weight(self):
        args = dict(sum_work=jnp.asarray([100.0]), s_j=jnp.asarray([10.0]),
                    priority=jnp.ones(1), t_max=jnp.full((1,), 100.0),
                    nonempty=jnp.asarray([True]))
        w_now = packet.queue_weights(oldest_submit=jnp.asarray([0.0]), now=0.0, **args)
        w_later = packet.queue_weights(oldest_submit=jnp.asarray([0.0]), now=100.0, **args)
        assert float(w_later[0]) == pytest.approx(2 * float(w_now[0]))

    def test_priority_multiplies(self):
        base = dict(sum_work=jnp.asarray([100.0, 100.0]),
                    s_j=jnp.asarray([10.0, 10.0]),
                    oldest_submit=jnp.zeros(2), now=0.0,
                    t_max=jnp.full((2,), 3600.0),
                    nonempty=jnp.asarray([True, True]))
        w = packet.queue_weights(priority=jnp.asarray([1.0, 3.0]), **base)
        assert float(w[1]) == pytest.approx(3 * float(w[0]))
